"""Tests for the Walker-Star constellation geometry + coverage windows."""
import numpy as np

from repro.core.constellation import (R_EARTH, WalkerStar, access_intervals,
                                      elevation_angles, serving_sequence)


def test_orbit_radius_constant():
    ws = WalkerStar()
    t = np.linspace(0, 3600, 10)
    pos = ws.positions_eci(t)
    r = np.linalg.norm(pos, axis=-1)
    assert np.allclose(r, ws.semi_major, rtol=1e-9)
    assert pos.shape == (10, 80, 3)


def test_orbital_period():
    ws = WalkerStar()
    period = 2 * np.pi / ws.mean_motion
    # 800 km LEO period ~ 101 minutes
    assert 95 * 60 < period < 110 * 60


def test_coverage_windows_exist_and_are_bounded():
    ws = WalkerStar()
    ivs = access_intervals(ws, t_end=2 * 3600.0, dt=10.0)
    assert len(ivs) > 0
    for iv in ivs:
        assert 0 < iv.duration < 20 * 60  # LEO passes are minutes, not hours
    # intervals sorted by start
    starts = [iv.start for iv in ivs]
    assert starts == sorted(starts)


def test_serving_sequence_continuity():
    ws = WalkerStar()
    ivs = access_intervals(ws, t_end=4 * 3600.0, dt=10.0)
    chain = serving_sequence(ivs, 0.0, max_sats=6)
    assert len(chain) >= 2
    for a, b in zip(chain, chain[1:]):
        # next serving satellite picked at the previous one's setting time
        assert b.end > a.end  # strictly progresses


def test_elevation_symmetry():
    ws = WalkerStar(n_sats=10, n_planes=2)
    t = np.array([0.0])
    elev = elevation_angles(ws, 40.0, -86.0, t)
    assert elev.shape == (1, 10)
    assert np.all(elev <= np.pi / 2 + 1e-9)
    assert np.all(elev >= -np.pi / 2 - 1e-9)
