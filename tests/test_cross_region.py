"""Cross-region hierarchical FL tests: RegionTrainer trajectory
preservation, unified region RNG streams, event-heap determinism,
staleness-aware global merges over ISLs, and registry hygiene."""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core.latency import (global_merge_latency, isl_merge_hops,
                                tx_time)
from repro.fl import (FLConfig, RegionTrainer, fedavg, run_fl,
                      staleness_merge_weights, staleness_weighted_merge)
from repro.fl.client import evaluate, stacked_evaluate
from repro.fl.federation import FederationConfig
from repro.models.cnn import build_model
from repro.scenarios import SCENARIOS, Scenario, get_scenario, register
from repro.sim import (DynamicsConfig, Region, SAGINEngine, region_seed,
                       region_streams, run_fl_all_regions)

TINY = dict(dataset="mnist", n_rounds=3, n_devices=4, n_air=1, h_local=2,
            train_fraction=0.005, eval_size=64, seed=0)

# two-region scenario for fast merge tests (unregistered on purpose: the
# engine and RegionTrainer take Scenario objects directly)
XR2 = Scenario(
    name="_xr2", description="two-region merge test scenario",
    regions=(Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8)),
    n_devices=4, n_air=1,
    federation=FederationConfig(policy="synchronous", every=1,
                                topology="star", half_life=600.0),
    horizon=6 * 3600.0)


def tiny_cfg(**overrides):
    kw = dict(TINY)
    kw.update(overrides)
    return FLConfig(**kw)


# ---------------------------------------------------------------------------
# Tentpole regression: the RegionTrainer refactor preserves trajectories ----
# ---------------------------------------------------------------------------
# Golden values captured from the pre-refactor run_fl loop (commit
# 6a7e07a) at this exact TINY configuration; the refactor contract is
# bit-identical reproduction at equal seeds.
GOLDEN = {
    "paper": {
        "accuracies": [0.109375, 0.3125, 0.546875],
        "latencies": [765.5785577775307, 765.5785577775287,
                      765.5785577775287],
        "times": [765.5785577775307, 1531.1571155550594,
                  2296.735673332588],
    },
    "device_churn": {
        "accuracies": [0.078125, 0.171875, 0.21875],
        "latencies": [765.5785577775307, 765.5785577775287,
                      765.5785577775287],
        "times": [765.5785577775307, 1531.1571155550594,
                  2296.735673332588],
    },
}


@pytest.mark.parametrize("scenario", sorted(GOLDEN))
def test_run_fl_reproduces_pre_refactor_trajectories(scenario):
    res = run_fl(tiny_cfg(scenario=scenario))
    gold = GOLDEN[scenario]
    assert res.accuracies == gold["accuracies"]
    assert res.latencies == gold["latencies"]
    assert res.times == gold["times"]


# Golden values captured from the pre-refactor SAGINEngine FL merge path
# (commit 68ae01a) at XR2/TINY and the multi_region preset: the federation
# API contract is that the `synchronous` policy reproduces the old
# hard-coded barrier bit-identically at equal seeds.
MERGE_GOLDEN_XR2 = {
    "accuracies": {"indiana": [0.109375, 0.203125, 0.25],
                   "nairobi": [0.109375, 0.171875, 0.21875]},
    "times": {"indiana": [765.5785577775307, 1531.1571155550594,
                          2304.5340183934213],
              "nairobi": [764.7416746783683, 1538.955460615893,
                          2312.332363454255]},
    "merge0_weights": (0.5002417012981076, 0.49975829870189226),
    "merge0_staleness": (0.0, 0.8368830991623781),
    "merge0_isl_costs": (0.0, 8.63522816),
    "merge0_accuracies": (0.109375, 0.046875),
    "global_param_sum": -887.1842271846483,
}
MERGE_GOLDEN_MULTI = {
    "indiana_times": [765.5785577775307, 1531.1571155550594],
    "merge0_weights": (0.2500292871814325, 0.24994872361969697,
                       0.250040785454496, 0.24998120374437455),
    "merge0_isl_costs": (0.0, 8.63522816, 17.27045632, 8.63522816),
    "global_param_sum": -965.3456731848983,
}


def _param_sum(params) -> float:
    return float(sum(float(np.asarray(leaf, np.float64).sum())
                     for leaf in jax.tree_util.tree_leaves(params)))


def test_synchronous_policy_reproduces_pre_refactor_engine_golden():
    """Tentpole lock: the extracted `synchronous` federation policy is
    bit-identical to the pre-refactor hard-coded barrier merge."""
    eng = SAGINEngine(XR2, fl=tiny_cfg(scenario=None))
    eng.run(3)
    gold = MERGE_GOLDEN_XR2
    for name, res in eng.fl_results.items():
        assert res.accuracies == gold["accuracies"][name]
        assert res.times == gold["times"][name]
    m = eng.merges[0]
    assert m.policy == "synchronous" and m.hub == 0
    assert m.participants == (0, 1) and m.recipients == (0, 1)
    assert m.weights == gold["merge0_weights"]
    assert m.staleness == gold["merge0_staleness"]
    assert m.isl_costs == gold["merge0_isl_costs"]
    assert m.accuracies == gold["merge0_accuracies"]
    # The float64 checksum over every float32 parameter is sensitive to
    # XLA's reduction order inside the training steps, which shifts
    # across XLA/BLAS releases (~1e-7 relative) while every trajectory
    # field above (accuracies, times, weights, staleness, ISL costs)
    # stays exact.  Tolerate only that backend noise.
    assert _param_sum(eng.global_params) == pytest.approx(
        gold["global_param_sum"], rel=1e-6)


def test_synchronous_policy_reproduces_multi_region_preset_golden():
    eng = SAGINEngine("multi_region",
                      fl=tiny_cfg(scenario=None, n_rounds=2))
    eng.run(2)
    gold = MERGE_GOLDEN_MULTI
    assert eng.fl_results["indiana"].times == gold["indiana_times"]
    m = eng.merges[0]
    assert m.weights == gold["merge0_weights"]
    assert m.isl_costs == gold["merge0_isl_costs"]
    # see the reduction-order note in the XR2 golden test above
    assert _param_sum(eng.global_params) == pytest.approx(
        gold["global_param_sum"], rel=1e-6)


def test_region_trainer_stepping_is_run_fl():
    """run_fl is literally a stepped RegionTrainer: same object path."""
    cfg = tiny_cfg(scenario="paper")
    trainer = RegionTrainer(cfg)
    for r in range(cfg.n_rounds):
        trainer.step(r)
    ref = run_fl(cfg)
    assert trainer.result.accuracies == ref.accuracies
    assert trainer.result.latencies == ref.latencies
    assert trainer.result.times == ref.times


# ---------------------------------------------------------------------------
# Unified per-region RNG streams --------------------------------------------
# ---------------------------------------------------------------------------
def test_region_seed_fold_is_region_addressable():
    assert region_seed(7, 0) == 7
    assert region_seed(7, 3) == 7 + 3000


def test_engine_and_run_fl_draw_identical_region_streams():
    """The PR-2 mismatch: the engine spawned per-region streams from one
    root generator while run_fl seeded its own — at the same seed, a
    single-region job and engine region 0 saw different outage/churn
    draws.  Both now derive from region_streams(); lock the initial
    generator states together."""
    scn = get_scenario("device_churn")
    eng = SAGINEngine("device_churn", seed=3, n_devices=4, n_air=1)
    rng, dyn = region_streams(3, 0, scn.dynamics)
    orch = eng.orchestrators[0]
    assert (orch._rng.bit_generator.state
            == rng.bit_generator.state)
    assert (orch.dynamics.rng.bit_generator.state
            == dyn.rng.bit_generator.state)

    trainer = RegionTrainer(tiny_cfg(scenario="device_churn", seed=3))
    assert (trainer.orch._rng.bit_generator.state
            == rng.bit_generator.state)
    assert (trainer.orch.dynamics.rng.bit_generator.state
            == dyn.rng.bit_generator.state)


def test_region_streams_differ_across_regions_and_match_engine():
    eng = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    states = []
    for i in range(len(eng.scenario.regions)):
        rng, dynamics = region_streams(0, i, None)
        assert dynamics is None
        assert (eng.orchestrators[i]._rng.bit_generator.state
                == rng.bit_generator.state)
        states.append(str(rng.bit_generator.state))
    assert len(set(states)) == len(states)


# ---------------------------------------------------------------------------
# Event-heap determinism ----------------------------------------------------
# ---------------------------------------------------------------------------
def test_engine_heap_tie_break_is_region_index_order():
    """All regions start at wall clock 0: the first |regions| pops are a
    pure tie, resolved by region index; the full pop sequence is
    deterministic across identical engines."""
    eng = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    eng.run(3)
    n = len(eng.scenario.regions)
    assert eng.step_order[:n] == [(i, 0) for i in range(n)]
    assert len(eng.step_order) == 3 * n
    # per-region round sequence is strictly increasing
    for i in range(n):
        rounds = [r for j, r in eng.step_order if j == i]
        assert rounds == [0, 1, 2]
    eng2 = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    eng2.run(3)
    assert eng.step_order == eng2.step_order


def test_run_fl_all_regions_unregisters_transient_scenario_on_error():
    before = set(SCENARIOS)
    adhoc = dataclasses.replace(get_scenario("paper"))  # name collision
    with pytest.raises(ValueError, match="execution"):
        run_fl_all_regions(tiny_cfg(execution="bogus"), adhoc)
    assert set(SCENARIOS) == before


# ---------------------------------------------------------------------------
# FLResult.losses semantics -------------------------------------------------
# ---------------------------------------------------------------------------
def test_losses_nan_when_no_node_trains():
    """With every device churned out and nothing yet offloaded to
    air/space, a round trains no node: the round must record NaN (not
    silently the eval loss)."""
    scn = Scenario(name="_all_churned", description="x",
                   dynamics=DynamicsConfig(churn_prob=1.0))
    register(scn)
    try:
        res = run_fl(tiny_cfg(scenario="_all_churned", n_rounds=1))
    finally:
        SCENARIOS.pop("_all_churned", None)
    assert math.isnan(res.losses[0])
    assert np.isfinite(res.accuracies[0])
    assert np.isfinite(res.latencies[0])


# ---------------------------------------------------------------------------
# Staleness-aware merge weights and aggregation -----------------------------
# ---------------------------------------------------------------------------
def test_merge_weights_pure_data_share_without_half_life():
    w = staleness_merge_weights([100, 300], [0.0, 1e9], half_life=None)
    np.testing.assert_allclose(w, [0.25, 0.75])


def test_merge_weights_halve_per_half_life():
    w = staleness_merge_weights([1.0, 1.0], [0.0, 600.0], half_life=600.0)
    np.testing.assert_allclose(w, [2 / 3, 1 / 3])
    assert w.sum() == pytest.approx(1.0)


def test_merge_weights_validation():
    with pytest.raises(ValueError, match="sizes"):
        staleness_merge_weights([0, 0], [0, 0])
    with pytest.raises(ValueError, match="staleness"):
        staleness_merge_weights([1, 1], [-1.0, 0.0])
    with pytest.raises(ValueError, match="half_life"):
        staleness_merge_weights([1, 1], [0.0, 0.0], half_life=-5.0)
    with pytest.raises(ValueError, match="mismatch"):
        staleness_merge_weights([1, 1], [0.0])


def test_staleness_weighted_merge_matches_fedavg():
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    models = [jax.tree_util.tree_map(
        lambda x, i=i: x + 0.01 * (i + 1), params) for i in range(3)]
    sizes, stale, hl = [100, 200, 100], [0.0, 300.0, 600.0], 300.0
    merged = staleness_weighted_merge(models, sizes, stale, half_life=hl)
    ref = fedavg(models, list(staleness_merge_weights(sizes, stale, hl)))
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_single_region_merge_is_identity():
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    merged = staleness_weighted_merge([params], [10], [0.0])
    assert merged is params
    merged, w = staleness_weighted_merge([params], [10], [0.0],
                                         return_weights=True)
    assert merged is params
    np.testing.assert_allclose(w, [1.0])


def test_engine_run_zero_rounds_is_noop():
    eng = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    traces = eng.run(0)
    assert all(not t.records for t in traces)
    assert eng.step_order == []
    fl_eng = SAGINEngine(XR2, fl=tiny_cfg(scenario=None))
    fl_eng.run(0)
    assert not fl_eng.merges
    assert all(not t.result.accuracies for t in fl_eng.trainers)
    assert all(t.wall_clock == 0.0 for t in fl_eng.trainers)


# ---------------------------------------------------------------------------
# ISL merge pricing ---------------------------------------------------------
# ---------------------------------------------------------------------------
def test_isl_merge_hops_topologies():
    # hub never pays; star is a flat 2-hop round trip
    assert isl_merge_hops("star", 0, 4) == 0
    assert all(isl_merge_hops("star", i, 4) == 2 for i in (1, 2, 3))
    # ring distance is circular
    assert [isl_merge_hops("ring", i, 4) for i in range(4)] == [0, 2, 4, 2]
    assert isl_merge_hops("ring", 5, 6) == 2
    assert isl_merge_hops("ring", 0, 1) == 0
    with pytest.raises(ValueError, match="topology"):
        isl_merge_hops("mesh", 1, 4)
    with pytest.raises(ValueError, match="out of range"):
        isl_merge_hops("ring", 4, 4)


def test_global_merge_latency_prices_model_hops():
    bits, z = 32e6, 3.125e6
    assert global_merge_latency(bits, z, "star", 0, 4) == 0.0
    assert global_merge_latency(bits, z, "star", 2, 4) == pytest.approx(
        2 * tx_time(bits, z))
    assert global_merge_latency(bits, z, "ring", 2, 4) == pytest.approx(
        4 * tx_time(bits, z))


def test_scenario_merge_field_validation():
    with pytest.raises(ValueError, match="merge_every"):
        Scenario(name="_bad_cadence", description="x", merge_every=0)
    with pytest.raises(ValueError, match="merge_topology"):
        Scenario(name="_bad_topo", description="x", merge_topology="mesh")
    fed = get_scenario("multi_region").resolved_federation()
    assert fed is not None and fed.every == 2
    assert fed.policy == "synchronous"


# ---------------------------------------------------------------------------
# Engine FL mode: event-stepped training + global merges --------------------
# ---------------------------------------------------------------------------
def test_engine_fl_mode_merges_into_one_global_model():
    eng = SAGINEngine(XR2, fl=tiny_cfg(scenario=None))
    eng.run(2)
    assert len(eng.merges) == 2  # merge_every=1
    assert eng.global_params is not None
    last = eng.merges[-1]
    assert last.barrier_round == 2
    np.testing.assert_allclose(sum(last.weights), 1.0)
    assert min(last.staleness) == 0.0 and all(s >= 0
                                              for s in last.staleness)
    # star topology: the hub region pays no ISL toll, the other a 2-hop
    # round trip; both clocks end at merge time + their toll
    t0, t1 = eng.trainers
    assert last.isl_costs[0] == 0.0
    assert last.isl_costs[1] == pytest.approx(
        2 * t1.sagin.model_bits / t1.sagin.z_isl)
    assert t0.wall_clock == pytest.approx(last.time)
    assert t1.wall_clock == pytest.approx(last.time + last.isl_costs[1])
    # every region ends on the SAME global model
    for trainer in eng.trainers:
        for a, b in zip(jax.tree_util.tree_leaves(trainer.params),
                        jax.tree_util.tree_leaves(eng.global_params)):
            np.testing.assert_array_equal(a, b)
    # merged-model eval recorded per region
    assert len(last.accuracies) == 2


def test_engine_fl_merge_none_equals_independent_run_fl():
    """Cadence None must exactly reproduce independent per-region
    trajectories — the engine's shared propagation pass and event
    interleaving change nothing about a region's own stream."""
    scn = dataclasses.replace(XR2, federation=None)
    cfg = tiny_cfg(scenario=None, n_rounds=2)
    eng = SAGINEngine(scn, fl=cfg)
    eng.run(2)
    assert eng.global_params is None
    assert not eng.merges
    for i, region in enumerate(scn.regions):
        solo = RegionTrainer(dataclasses.replace(cfg, region_index=i),
                             scenario=scn)
        for r in range(2):
            solo.step(r)
        got = eng.fl_results[region.name]
        assert got.accuracies == solo.result.accuracies
        assert got.latencies == solo.result.latencies
        assert got.times == solo.result.times


def test_engine_fl_mode_is_deterministic():
    a = SAGINEngine(XR2, fl=tiny_cfg(scenario=None))
    a.run(2)
    b = SAGINEngine(XR2, fl=tiny_cfg(scenario=None))
    b.run(2)
    assert a.step_order == b.step_order
    assert [m.weights for m in a.merges] == [m.weights for m in b.merges]
    for x, y in zip(jax.tree_util.tree_leaves(a.global_params),
                    jax.tree_util.tree_leaves(b.global_params)):
        np.testing.assert_array_equal(x, y)


def test_regions_share_task_and_init_but_not_samples():
    """Mergeability contract: same class prototypes and initial model
    across regions, different sample draws."""
    cfg = tiny_cfg(scenario=None, n_rounds=1)
    eng = SAGINEngine(XR2, fl=cfg)
    t0, t1 = eng.trainers
    assert not np.array_equal(t0.ds.x_train, t1.ds.x_train)
    l0 = jax.tree_util.tree_leaves(
        RegionTrainer(dataclasses.replace(cfg, region_index=0),
                      scenario=XR2).params)
    # note: trainers above already stepped 0 rounds; params are inits
    for a, b in zip(jax.tree_util.tree_leaves(t0.params),
                    jax.tree_util.tree_leaves(t1.params)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree_util.tree_leaves(t0.params), l0):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_multi_region_global_model_beats_independent():
    """Acceptance: the merged global model's shared-eval accuracy is at
    least the best independently trained region model's."""
    import jax.numpy as jnp

    from repro.data import make_dataset

    cfg = FLConfig(dataset="mnist", n_devices=4, n_air=1, h_local=2,
                   train_fraction=0.01, eval_size=256, seed=0)
    scn = get_scenario("multi_region")
    rounds = 6
    merged_eng = SAGINEngine(scn, fl=cfg)
    merged_eng.run(rounds)
    indep_eng = SAGINEngine(dataclasses.replace(scn, federation=None),
                            fl=cfg)
    indep_eng.run(rounds)

    # shared eval set: a fresh draw of the same task, unseen by anyone
    ds = make_dataset("mnist", seed=cfg.seed, train_fraction=0.02,
                      sample_seed=999)
    x, y = jnp.asarray(ds.x_test[:1024]), jnp.asarray(ds.y_test[:1024])
    apply_fn = merged_eng.trainers[0].apply_fn
    _, g_acc = evaluate(apply_fn, merged_eng.global_params, x, y)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[t.params for t in indep_eng.trainers])
    _, ind_accs = stacked_evaluate(apply_fn, stacked, x, y)
    assert float(g_acc) >= float(jnp.max(ind_accs))
