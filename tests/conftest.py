import os

# Keep smoke tests on ONE device: the 512-device XLA flag is set only by
# repro.launch.dryrun (never globally, per the dry-run contract).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
