"""Checkpoint + handover-state serialization tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import handover_state, load_pytree, save_pytree
from repro.models.cnn import build_model


def test_roundtrip(tmp_path):
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    save_pytree(params, path)
    loaded = load_pytree(params, path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_handover_blob_size_matches_eq7_inputs():
    params, _ = build_model("fmnist", jax.random.PRNGKey(0))
    opt_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    blob, bits = handover_state(params, opt_state,
                                {"remaining_samples": 1234, "round": 7})
    assert bits == 8 * len(blob)
    # at least as large as the raw parameters (fp32) twice (params + opt)
    from repro.models.cnn import param_count
    assert bits >= 2 * 32 * param_count(params) * 0.9


def test_roundtrip_nested_state(tmp_path):
    tree = {"a": jnp.arange(5), "b": [jnp.ones((2, 3)),
                                      {"c": jnp.zeros(1)}]}
    path = str(tmp_path / "nested.npz")
    save_pytree(tree, path)
    loaded = load_pytree(tree, path)
    np.testing.assert_array_equal(np.asarray(loaded["b"][0]), np.ones((2, 3)))


# ---------------------------------------------------------------------------
# hardening: key validation, .tree sidecar, atomic writes --------------------
# ---------------------------------------------------------------------------
def test_save_writes_tree_sidecar_and_no_temp_litter(tmp_path):
    tree = {"w": jnp.ones(3), "b": jnp.zeros(2)}
    path = str(tmp_path / "m")           # suffix-less spelling
    save_pytree(tree, path)
    assert os.path.exists(str(tmp_path / "m.npz"))
    assert os.path.exists(str(tmp_path / "m.npz.tree"))
    # atomic writes leave no *.tmp behind
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    loaded = load_pytree(tree, path)     # both spellings load
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.ones(3))


def test_load_rejects_key_mismatch(tmp_path):
    path = str(tmp_path / "a.npz")
    save_pytree({"w": jnp.ones(3)}, path)
    with pytest.raises(ValueError, match="structure mismatch"):
        load_pytree({"w": jnp.ones(3), "extra": jnp.zeros(1)}, path)
    with pytest.raises(ValueError, match="structure mismatch"):
        load_pytree({"renamed": jnp.ones(3)}, path)


def test_load_rejects_treedef_sidecar_mismatch(tmp_path):
    # same flattened keys, different container structure: only the
    # .tree sidecar can tell them apart
    path = str(tmp_path / "s.npz")
    save_pytree({"a": {"b": jnp.ones(2)}}, path)
    with pytest.raises(ValueError, match="treedef mismatch"):
        load_pytree({"a/b": jnp.ones(2)}, path)


def test_load_without_sidecar_stays_compatible(tmp_path):
    # pre-hardening checkpoints have no .tree file; key check still runs
    path = str(tmp_path / "old.npz")
    save_pytree({"w": jnp.arange(4)}, path)
    os.unlink(path + ".tree")
    loaded = load_pytree({"w": jnp.zeros(4, dtype=jnp.int32)}, path)
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.arange(4))
