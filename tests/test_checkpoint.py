"""Checkpoint + handover-state serialization tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import handover_state, load_pytree, save_pytree
from repro.models.cnn import build_model


def test_roundtrip(tmp_path):
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    save_pytree(params, path)
    loaded = load_pytree(params, path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_handover_blob_size_matches_eq7_inputs():
    params, _ = build_model("fmnist", jax.random.PRNGKey(0))
    opt_state = jax.tree_util.tree_map(jnp.zeros_like, params)
    blob, bits = handover_state(params, opt_state,
                                {"remaining_samples": 1234, "round": 7})
    assert bits == 8 * len(blob)
    # at least as large as the raw parameters (fp32) twice (params + opt)
    from repro.models.cnn import param_count
    assert bits >= 2 * 32 * param_count(params) * 0.9


def test_roundtrip_nested_state(tmp_path):
    tree = {"a": jnp.arange(5), "b": [jnp.ones((2, 3)),
                                      {"c": jnp.zeros(1)}]}
    path = str(tmp_path / "nested.npz")
    save_pytree(tree, path)
    loaded = load_pytree(tree, path)
    np.testing.assert_array_equal(np.asarray(loaded["b"][0]), np.ones((2, 3)))
