"""Tests for the space-layer handover schedule (eqs. 7-12)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip when hypothesis is absent

from repro.core import build_default_sagin, space_latency, space_schedule
from repro.core.latency import comp_time, handover_delay
from repro.core.network import Satellite


def sagin_with(sats, seed=0):
    s = build_default_sagin(n_devices=4, n_air=1, seed=seed)
    s.satellites = sats
    return s


def test_single_satellite_closed_form():
    """eq. (8): tau = m |D| / f when the first satellite finishes."""
    s = sagin_with([Satellite(0, f=5e9, coverage_end=np.inf)])
    n = 1000
    expected = comp_time(3e9, n, 5e9)
    assert abs(space_latency(n, s) - expected) < 1e-9


def test_two_satellite_closed_form():
    """eq. (9): T1 + handover + remaining work at satellite 2."""
    f1, f2, t1 = 2e9, 8e9, 100.0
    s = sagin_with([Satellite(0, f=f1, coverage_end=t1),
                    Satellite(1, f=f2, coverage_end=np.inf)])
    n = 1000
    done1 = (f1 / 3e9) * t1
    assert done1 < n
    hand = handover_delay(s.model_bits, s.q_bits, n - done1, s.z_isl)
    expected = t1 + hand + 3e9 * (n - done1) / f2
    assert abs(space_latency(n, s) - expected) < 1e-6


def test_three_satellite_chain():
    """eq. (11)-(12) generalization: three coverage windows."""
    s = sagin_with([Satellite(0, f=1e9, coverage_end=50.0),
                    Satellite(1, f=1e9, coverage_end=120.0),
                    Satellite(2, f=9e9, coverage_end=np.inf)])
    sch = space_schedule(5000, s)
    assert sch.completed
    assert len(sch.legs) == 3
    assert sch.n_handovers == 2
    # legs are time-ordered and non-overlapping
    for a, b in zip(sch.legs, sch.legs[1:]):
        assert b.start_time >= a.end_time - 1e-9
    # all samples processed
    assert abs(sum(l.samples_processed for l in sch.legs) - 5000) < 1e-6


def test_zero_samples():
    s = sagin_with([Satellite(0, f=1e9, coverage_end=10.0)])
    assert space_latency(0, s) == 0.0


# ---------------------------------------------------------------------------
# Edge cases -----------------------------------------------------------------
# ---------------------------------------------------------------------------
def test_zero_length_coverage_window():
    """A satellite whose window has already closed processes nothing and
    hands everything straight on."""
    s = sagin_with([Satellite(0, f=5e9, coverage_end=0.0),
                    Satellite(1, f=5e9, coverage_end=np.inf)])
    sch = space_schedule(1000, s)
    assert sch.completed
    assert sch.legs[0].samples_processed == 0.0
    assert sch.legs[0].end_time == 0.0
    assert abs(sch.legs[1].samples_processed - 1000) < 1e-9
    # the full dataset pays the eq.-(7) handover to satellite 1
    expected = handover_delay(s.model_bits, s.q_bits, 1000, s.z_isl)
    assert abs(sch.legs[1].handover_delay - expected) < 1e-9


def test_chain_never_completes_extrapolates_virtual_satellite():
    """When every known satellite's window closes before the work is done,
    the schedule finishes on the unbounded virtual satellite (index -1)
    so the optimizer always sees a finite, monotone latency."""
    s = sagin_with([Satellite(0, f=1e9, coverage_end=10.0),
                    Satellite(1, f=1e9, coverage_end=20.0)])
    n = 10_000_000  # far more than both windows can process
    sch = space_schedule(n, s)
    assert sch.completed
    assert sch.legs[-1].sat_index == -1
    assert np.isfinite(sch.total_latency)
    assert abs(sum(l.samples_processed for l in sch.legs) - n) < 1e-6
    # real satellites stopped at their coverage ends
    for leg, sat in zip(sch.legs[:-1], s.satellites):
        assert leg.end_time <= sat.coverage_end + 1e-9
    # still monotone in n at the extrapolated tail
    assert space_latency(n + 1000, s) >= sch.total_latency - 1e-9


def test_single_satellite_schedule_has_no_handover():
    s = sagin_with([Satellite(0, f=5e9, coverage_end=np.inf)])
    sch = space_schedule(1000, s)
    assert sch.completed
    assert len(sch.legs) == 1
    assert sch.n_handovers == 0
    assert sch.legs[0].handover_delay == 0.0
    assert sch.legs[0].start_time == 0.0


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 50_000),
       f1=st.floats(1e9, 1e10), f2=st.floats(1e9, 1e10),
       t1=st.floats(10.0, 500.0))
def test_property_monotone_and_coverage_respected(n, f1, f2, t1):
    s = sagin_with([Satellite(0, f=f1, coverage_end=t1),
                    Satellite(1, f=f2, coverage_end=np.inf)])
    lat = space_latency(n, s)
    lat2 = space_latency(n + 100, s)
    # monotone in the dataset size
    assert lat2 >= lat - 1e-9
    sch = space_schedule(n, s)
    # a satellite never works past its coverage window
    for leg, sat in zip(sch.legs, s.satellites):
        assert leg.end_time <= sat.coverage_end + 1e-6
    # handover pays the ISL delay of eq. (7)
    if len(sch.legs) == 2:
        rem = sch.legs[1].samples_processed
        expected = handover_delay(s.model_bits, s.q_bits, rem, s.z_isl)
        assert abs(sch.legs[1].handover_delay - expected) < 1e-6
