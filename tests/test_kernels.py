"""Per-kernel interpret-mode validation against the pure-jnp oracles,
swept over shapes and dtypes (the deliverable-(c) kernel contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedavg_agg import kernel as agg_k, ref as agg_r
from repro.kernels.flash_attention import kernel as fa_k, ref as fa_r
from repro.kernels.wkv6 import kernel as wkv_k, ref as wkv_r


# ---------------------------------------------------------------------------
# fedavg_agg ------------------------------------------------------------------
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 7), (3, 100), (5, 128, 33),
                                   (2, 16384), (4, 3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_agg_sweep(shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=shape[0]), jnp.float32)
    w = w / jnp.sum(w)
    out = agg_k.weighted_aggregate(x, w, interpret=True)
    ref = agg_r.weighted_aggregate(x, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_fedavg_agg_convex_combination_bounds():
    """Property: the aggregate lies in the convex hull of the inputs."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 257)), jnp.float32)
    w = jnp.asarray([0.25, 0.25, 0.25, 0.25], jnp.float32)
    out = np.asarray(agg_k.weighted_aggregate(x, w, interpret=True))
    assert (out <= np.max(np.asarray(x), 0) + 1e-5).all()
    assert (out >= np.min(np.asarray(x), 0) - 1e-5).all()


# ---------------------------------------------------------------------------
# flash_attention --------------------------------------------------------------
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 128, 32),      # MHA
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 1, 128, 64),      # MQA
    (2, 4, 4, 512, 16),
])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(b, hq, hkv, s, d, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    out = fa_k.flash_attention(q, k, v, causal=True, window=window,
                               block_q=64, block_k=64, interpret=True)
    ref = fa_r.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    out = fa_k.flash_attention(q, k, v, block_q=64, block_k=64,
                               interpret=True)
    ref = fa_r.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_blocked_attention_matches_exact():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 4096, 32)), jnp.float32)
    out = fa_r.blocked_attention(q, k, v, causal=True, block=512)
    ref = fa_r.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_rows_attend_within_window_only():
    """Property: with window=1 each row attends only to itself."""
    rng = np.random.default_rng(3)
    s, d = 128, 16
    q = jnp.asarray(rng.normal(size=(1, 1, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, s, d)), jnp.float32)
    out = fa_k.flash_attention(q, k, v, causal=True, window=1,
                               block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[0, 0], np.asarray(v)[0, 0],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# wkv6 -------------------------------------------------------------------------
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,t,d,chunk", [
    (1, 1, 32, 8, 8), (2, 3, 64, 16, 16), (1, 2, 128, 64, 128),
    (2, 2, 96, 32, 32),
])
def test_wkv6_sweep(b, h, t, d, chunk):
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.7, 0.999, size=(b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32) * 0.1
    out = wkv_k.wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = wkv_r.wkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_decode_step_consistency():
    """Running T decode steps == the full-sequence recurrence."""
    rng = np.random.default_rng(1)
    b, h, t, d = 1, 2, 24, 8
    r, k, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.99, size=(b, h, t, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32) * 0.1
    ref = wkv_r.wkv(r, k, v, w, u)
    s = jnp.zeros((b, h, d, d), jnp.float32)
    outs = []
    for i in range(t):
        s, o = wkv_r.wkv_step(s, r[:, :, i], k[:, :, i], v[:, :, i],
                              w[:, :, i], u)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 2)),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_wkv6_decay_property():
    """Property: with w=0 (full decay) the state resets every step, so the
    output depends only on the current token: o_t = r_t @ (u*k_t v_t^T)."""
    rng = np.random.default_rng(2)
    b, h, t, d = 1, 1, 8, 4
    r, k, v = (jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
               for _ in range(3))
    w = jnp.zeros((b, h, t, d), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32)
    out = np.asarray(wkv_r.wkv(r, k, v, w, u))
    for i in range(1, t):
        expected = np.asarray(r)[0, 0, i] @ (
            np.asarray(u)[0][:, None] * np.outer(np.asarray(k)[0, 0, i],
                                                 np.asarray(v)[0, 0, i])
            + np.outer(np.asarray(k)[0, 0, i - 1], np.asarray(v)[0, 0, i - 1]))
        np.testing.assert_allclose(out[0, 0, i], expected, rtol=1e-4,
                                   atol=1e-4)
