"""Mesh-native FL pieces: hierarchical weighted psum (eq. 13 on the mesh)
and the multi-pod FL train step (subprocess with 8 host devices)."""
import subprocess
import sys
import textwrap

import pytest

PSUM_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map  # jax.shard_map moved across versions
    from repro.fl.aggregation import hierarchical_weighted_psum
    from repro.launch.train import make_replica_agg_step

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    # each (pod, data) shard holds its own "client model" scalar
    vals = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)

    def agg(v):
        lam = 1.0 / 8.0
        return hierarchical_weighted_psum({"w": v}, lam,
                                          ("data", "pod"))["w"]

    out = jax.jit(shard_map(agg, mesh=mesh, in_specs=P("pod", "data"),
                            out_specs=P("pod", "data")))(vals)
    expected = float(np.mean(np.arange(8)))
    assert np.allclose(np.asarray(out), expected), (out, expected)

    # same aggregation through the packaged shard_map helper
    lam = jnp.full((2, 4), 1.0 / 8.0)
    step = make_replica_agg_step(mesh, ("data", "pod"), P("pod", "data"))
    out2 = step({"w": vals}, lam)["w"]
    assert np.allclose(np.asarray(out2), expected), (out2, expected)
    print("PSUM_OK")
""")

FL_STEP_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.shapes import InputShape
    from repro.launch.train import make_fl_train_step, abstract_params
    from repro.models import transformer as T

    cfg = dataclasses.replace(
        get_config("olmo-1b").reduced(n_layers=2, d_model=128),
        param_dtype="float32")
    shape = InputShape("mini", 64, 8, "train")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    with mesh:
        step, rep_sh, batch_sh = make_fl_train_step(cfg, mesh, shape,
                                                    lr=1e-2, h_local=2)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        rep = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), params)
        rng = np.random.default_rng(0)
        batch = {
            "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (2, 4, 64)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (2, 4, 64)), jnp.int32),
        }
        rep = jax.device_put(rep, rep_sh)
        batch = {k: jax.device_put(v, batch_sh[k]) for k, v in batch.items()}
        new_rep, metrics = step(rep, batch)
    # aggregated replicas must be identical across the pod axis
    for leaf in jax.tree_util.tree_leaves(new_rep):
        a = np.asarray(leaf)
        assert np.allclose(a[0], a[1], atol=1e-5)
    assert np.isfinite(float(metrics["loss"]))
    print("FL_STEP_OK")
""")


@pytest.mark.slow
def test_hierarchical_psum_matches_mean():
    r = subprocess.run([sys.executable, "-c", PSUM_TEST],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PSUM_OK" in r.stdout


@pytest.mark.slow
def test_fl_train_step_aggregates_replicas():
    r = subprocess.run([sys.executable, "-c", FL_STEP_TEST],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "FL_STEP_OK" in r.stdout
