"""Unit tests for the loop-aware HLO cost analyzer (launch/hlo_analysis).

The analyzer underpins every §Roofline number, so its two key properties
are pinned here: (1) `while` bodies are multiplied by their trip count
(XLA's own cost_analysis counts them once); (2) collective bytes are
extracted per kind (checked in a multi-device subprocess).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _flops_of(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return H.analyze(txt), txt


def test_scan_flops_scaled_by_trip_count():
    n, d = 10, 256
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((n, d, d), jnp.float32)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    costs, txt = _flops_of(scanned, x, ws)
    expected = n * 2 * d ** 3
    assert abs(costs.flops - expected) / expected < 0.05, costs.flops
    # XLA's own count misses the trip factor (read through the repro.compat
    # normalizer: cost_analysis() is a dict or a list-of-dict by version)
    xla = H.xla_cost(jax.jit(scanned).lower(x, ws).compile())
    assert xla["flops"] < costs.flops / (n / 2)


def test_single_dot_flops_exact():
    d = 128
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def f(x):
        return x @ x

    costs, _ = _flops_of(f, x)
    assert costs.flops == pytest.approx(2 * d ** 3, rel=0.01)


def test_bytes_positive_and_bounded():
    d = 512
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    costs, _ = _flops_of(lambda x: jnp.tanh(x @ x), x)
    # at least: read x twice + write result; at most a few round trips
    assert 3 * d * d * 4 <= costs.bytes <= 40 * d * d * 4


COLLECTIVE_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_analysis as H

    mesh = jax.make_mesh((8,), ("data",))
    sh = NamedSharding(mesh, P(None, "data"))
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a):
        return jnp.sum(a @ a.T)          # contraction over the sharded dim

    with mesh:
        txt = jax.jit(f, in_shardings=sh).lower(x).compile().as_text()
    costs = H.analyze(txt)
    assert costs.collective_total > 0, costs.collectives
    assert any(k in costs.collectives
               for k in ("all-reduce", "reduce-scatter", "all-gather")), \\
        costs.collectives
    print("COLLECTIVES_OK", costs.collectives)
""")


@pytest.mark.slow
def test_collectives_detected_multidevice():
    r = subprocess.run([sys.executable, "-c", COLLECTIVE_TEST],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COLLECTIVES_OK" in r.stdout
