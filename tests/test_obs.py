"""repro.obs tests: trace schema round-trip, Perfetto export, the
disabled-mode no-op identity contract (bit-identical trajectories with
tracing on or off at equal seeds), metrics math, report aggregation,
and the ``python -m repro.obs report`` CLI exit codes."""
import dataclasses
import json

import pytest

from repro.fl import FLConfig, run_fl
from repro.fl.federation import FederationConfig
from repro.obs import (FEDERATION_TRACK, NULL_TRACER, Metrics, ObsConfig,
                       Span, Tracer, analyze, load_jsonl, perfetto_path,
                       resolve_obs, to_perfetto)
from repro.obs.__main__ import main as obs_main
from repro.obs.report import render
from repro.scenarios import Scenario
from repro.sim import Region, SAGINEngine

TINY = dict(dataset="mnist", n_rounds=2, n_devices=4, n_air=1, h_local=2,
            train_fraction=0.005, eval_size=64, seed=0)

XR2 = Scenario(
    name="_obs_xr2", description="two-region obs test scenario",
    regions=(Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8)),
    n_devices=4, n_air=1,
    federation=FederationConfig(policy="synchronous", every=1,
                                topology="star", half_life=600.0),
    horizon=6 * 3600.0)


def tiny_cfg(**overrides):
    kw = dict(TINY)
    kw.update(overrides)
    return FLConfig(**kw)


# ---------------------------------------------------------------------------
# Schema round-trip + Perfetto export ----------------------------------------
# ---------------------------------------------------------------------------
def test_span_schema_roundtrip(tmp_path):
    tr = Tracer(ObsConfig(path=str(tmp_path / "t.jsonl")))
    tr.set_context(region="indiana", round=0, t_sim=10.0)
    tr.span("round", "indiana/r0", dur_sim=5.0, case=2, acc=0.5)
    tr.event("outage", "uplink_c0", event="uplink", delay=3.0)
    tr.span("merge", "sync@r1", region=FEDERATION_TRACK, round=1,
            t_sim=20.0, dur_sim=1.0, participants=[0, 1])
    dest = tr.flush()
    assert dest == str(tmp_path / "t.jsonl")

    back = load_jsonl(dest)
    assert back == tr.spans
    # every line carries the schema tag
    with open(dest) as fh:
        for line in fh:
            assert json.loads(line)["schema"] == "repro-trace/1"

    # Perfetto sibling: valid strict JSON, one thread track per region,
    # X event for the duration span, instant event for the zero-dur one
    pf_file = perfetto_path(dest)
    with open(pf_file) as fh:
        pf = json.load(fh)
    events = pf["traceEvents"]
    names = {e["args"]["name"] for e in events if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {"indiana", FEDERATION_TRACK} <= names
    phases = {e["ph"] for e in events}
    assert {"X", "i", "M"} <= phases
    x = next(e for e in events
             if e["ph"] == "X" and "round" in e["cat"].split(","))
    assert x["ts"] == pytest.approx(10.0 * 1e6)
    assert x["dur"] == pytest.approx(5.0 * 1e6)


def test_span_kind_vocabulary_is_closed():
    tr = Tracer(ObsConfig())
    with pytest.raises(ValueError, match="unknown span kind"):
        tr.span("launch", "x")
    # disabled tracer never validates (and never records)
    assert NULL_TRACER.span("launch", "x") is None
    assert NULL_TRACER.spans == []


def test_resolve_obs_coercions(tmp_path):
    assert resolve_obs(None) is NULL_TRACER
    tr = Tracer(ObsConfig())
    assert resolve_obs(tr) is tr
    from_str = resolve_obs(str(tmp_path / "a.jsonl"))
    assert from_str.enabled and from_str.config.path.endswith("a.jsonl")
    assert resolve_obs(ObsConfig(enabled=False)) is NULL_TRACER
    assert resolve_obs(ObsConfig(device_timing=True)).device_timing
    with pytest.raises(TypeError, match="obs must be"):
        resolve_obs(42)


def test_metrics_registry_math():
    m = Metrics()
    m.counter("n").inc()
    m.counter("n").inc(4)
    m.gauge("g").set(2.5)
    h = m.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert m.counter("n").value == 5
    assert m.gauge("g").value == 2.5
    assert h.count == 4 and h.mean == pytest.approx(2.5)
    assert h.vmin == 1.0 and h.vmax == 4.0
    assert h.percentile(0) == 1.0 and h.percentile(100) == 4.0
    assert h.percentile(50) in (2.0, 3.0)
    snap = m.snapshot()
    assert snap["n"] == 5 and snap["g"] == 2.5
    assert isinstance(snap["h"], dict) and snap["h"]["count"] == 4
    # null registry: same surface, records nothing
    nm = NULL_TRACER.metrics
    nm.counter("x").inc()
    nm.histogram("x").observe(1.0)
    assert nm.snapshot() == {}


# ---------------------------------------------------------------------------
# Disabled-mode no-op identity ----------------------------------------------
# ---------------------------------------------------------------------------
def test_trajectories_bit_identical_obs_on_vs_off(tmp_path):
    """The tracer only observes: enabling it (device_timing included)
    must not change a single trajectory value at equal seeds."""
    base = run_fl(tiny_cfg(scenario="device_churn"))
    obs = ObsConfig(path=str(tmp_path / "t.jsonl"), device_timing=True)
    traced = run_fl(tiny_cfg(scenario="device_churn", obs=obs))
    assert traced.accuracies == base.accuracies
    assert traced.losses == base.losses
    assert traced.latencies == base.latencies
    assert traced.times == base.times
    # ...and the trace actually recorded the run
    spans = load_jsonl(str(tmp_path / "t.jsonl"))
    assert {s.kind for s in spans} >= {"round", "offload"}
    assert any(s.kind == "outage" for s in spans)  # churn dynamics


# ---------------------------------------------------------------------------
# End-to-end traced engine run + CLI -----------------------------------------
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def traced_engine_run(tmp_path_factory):
    """One traced two-region federated run; batched execution so bucket
    dispatches appear. Shared by the span-kind and CLI tests."""
    path = str(tmp_path_factory.mktemp("obs") / "engine.jsonl")
    cfg = tiny_cfg(scenario=None, execution="batched",
                   obs=ObsConfig(path=path))
    eng = SAGINEngine(XR2, fl=cfg)
    eng.run(2)
    return path, eng


def test_traced_engine_run_has_four_span_kinds(traced_engine_run):
    path, eng = traced_engine_run
    spans = load_jsonl(path)
    kinds = {s.kind for s in spans}
    assert {"round", "offload", "merge", "bucket_dispatch"} <= kinds
    # both region tracks plus the synthetic federation track rendered
    pf = to_perfetto(spans)
    tracks = {e["args"]["name"] for e in pf["traceEvents"]
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"indiana", "nairobi", FEDERATION_TRACK} <= tracks
    # the engine's shared tracer collected metrics along the way
    snap = eng.tracer.metrics.snapshot()
    assert snap["offload.bytes"] > 0
    assert snap["merge.count"] >= 1
    assert snap["cohort.bucket_dispatches"] > 0


def test_report_cli_exit_codes(traced_engine_run, tmp_path, capsys):
    path, _ = traced_engine_run
    # 0: good trace, tables mention both regions
    assert obs_main(["report", path]) == 0
    out = capsys.readouterr().out
    assert "indiana" in out and "nairobi" in out
    assert "latency breakdown" in out
    # 0: JSON mode is strict JSON
    assert obs_main(["report", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["n_spans"] == len(load_jsonl(path))
    # 1: empty trace
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert obs_main(["report", str(empty)]) == 1
    # 2: missing and corrupt traces, and usage errors
    assert obs_main(["report", str(tmp_path / "missing.jsonl")]) == 2
    bad = tmp_path / "bad.jsonl"
    bad.write_text("{not json}\n")
    assert obs_main(["report", str(bad)]) == 2
    assert obs_main([]) == 2


def test_perfetto_cli_subcommand(traced_engine_run, tmp_path, capsys):
    path, _ = traced_engine_run
    out = str(tmp_path / "conv.perfetto.json")
    assert obs_main(["perfetto", path, "--out", out]) == 0
    capsys.readouterr()
    with open(out) as fh:
        pf = json.load(fh)
    assert pf["otherData"]["schema"] == "repro-trace/1"
    assert len(pf["traceEvents"]) > 0


# ---------------------------------------------------------------------------
# Report aggregation on synthetic spans --------------------------------------
# ---------------------------------------------------------------------------
def test_analyze_flags_stragglers_and_quorum_misses():
    spans = [
        Span("round", "a/r0", region="a", round=0, t_sim=0, dur_sim=10.0),
        Span("round", "a/r1", region="a", round=1, t_sim=10, dur_sim=10.0,
             attrs={"n_handovers": 3, "acc": 0.4}),
        Span("round", "a/r2", region="a", round=2, t_sim=20, dur_sim=30.0),
        Span("handover", "h", region="a", round=1, t_sim=12, dur_sim=2.0),
        Span("merge", "sync@r2 skipped", region=FEDERATION_TRACK, round=2,
             t_sim=50.0, attrs={"skipped": True, "policy": "sync"}),
    ]
    rep = analyze(spans, top=10)
    assert [r.region for r in rep.regions] == ["a"]
    a = rep.regions[0]
    assert a.rounds == 3 and a.handovers == 1
    assert a.final_acc == 0.4
    kinds = {an.kind for an in rep.anomalies}
    assert {"straggler", "repeated_handover", "quorum_miss"} <= kinds
    # skipped merges sort above everything else
    assert rep.anomalies[0].kind == "quorum_miss"
    # breakdown components are non-negative and bounded by the run
    assert a.compute >= 0 and a.idle >= 0
    assert a.isl == pytest.approx(2.0)


def test_analyze_shard_dispatch_breakdown():
    from repro.obs.report import render
    spans = [
        # unsharded dispatch: no shard_real attr, must not create the section
        Span("bucket_dispatch", "C8xH4xB32", region="a", dur_wall=0.010,
             attrs={"real": 100, "mesh_shape": [1]}),
    ]
    assert analyze(spans).shard_dispatch is None
    spans += [
        Span("bucket_dispatch", "C8xH4xB32", region="a", dur_wall=0.008,
             attrs={"real": 120, "mesh_shape": [4],
                    "shard_real": [60, 30, 20, 10]}),
        Span("bucket_dispatch", "C16xH4xB64", region="a", dur_wall=0.012,
             attrs={"real": 200, "mesh_shape": [4],
                    "shard_real": [50, 50, 50, 50]}),
    ]
    sd = analyze(spans).shard_dispatch
    assert sd is not None
    assert sd.mesh_shape == [4] and sd.dispatches == 2
    assert sd.wall_s == pytest.approx(0.020)
    assert [r.real_elements for r in sd.shards] == [110, 80, 70, 60]
    # dur_wall apportioned by each shard's real-element share per span
    assert sd.shards[0].wall_s == pytest.approx(
        0.008 * 60 / 120 + 0.012 * 50 / 200)
    assert sum(r.wall_s for r in sd.shards) == pytest.approx(sd.wall_s)
    assert sd.imbalance == pytest.approx(110 * 4 / 320)
    text = render(analyze(spans))
    assert "sharded dispatch (mesh 4" in text
    assert "shard" in text and "wall_ms" in text


def test_obsconfig_replace_is_frozen_dataclass():
    cfg = ObsConfig(path="x.jsonl")
    assert dataclasses.replace(cfg, device_timing=True).device_timing
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.path = "y"


# ---------------------------------------------------------------------------
# Serving spans: closed vocabulary sync + report section ---------------------
# ---------------------------------------------------------------------------
def test_span_vocabulary_three_way_sync():
    """The closed span vocabulary must stay in sync across the tracer
    (SPAN_KINDS), the Perfetto exporter (PERFETTO_KINDS), and the report
    renderer (HANDLED_KINDS) — adding a kind to one place only must fail
    here, loudly, not silently drop spans from a view."""
    from repro.obs import HANDLED_KINDS, PERFETTO_KINDS, SPAN_KINDS
    from repro.obs.report import SERVING_KINDS
    assert set(SPAN_KINDS) == set(PERFETTO_KINDS.keys()) == set(HANDLED_KINDS)
    assert SERVING_KINDS <= HANDLED_KINDS
    assert {"request", "serve_batch"} <= SERVING_KINDS
    # every Perfetto display group is a non-empty label
    assert all(g for g in PERFETTO_KINDS.values())


def test_unmapped_perfetto_kind_fails_loudly(tmp_path):
    """A span kind missing from PERFETTO_KINDS must crash the exporter,
    not export with a silent default category."""
    from repro.obs import PERFETTO_KINDS, to_perfetto
    tr = Tracer(ObsConfig())
    tr.span("request", "req0", region="indiana", round=-1, t_sim=0.0,
            dur_sim=0.5)
    removed = PERFETTO_KINDS.pop("request")
    try:
        with pytest.raises(KeyError):
            to_perfetto(tr.spans)
    finally:
        PERFETTO_KINDS["request"] = removed


def test_report_serving_section():
    tr = Tracer(ObsConfig())
    tr.span("round", "indiana/r0", region="indiana", round=0,
            t_sim=0.0, dur_sim=100.0, case=2, acc=0.5)
    for k in range(10):
        tr.span("request", f"req{k}", region="indiana", round=-1,
                t_sim=float(k), dur_sim=0.5 + 0.01 * k,
                route="sat" if k % 2 else "isl", wait_s=0.1,
                correct=(k % 4 != 0))
    tr.span("serve_batch", "sat0/b1", region="indiana", round=-1,
            t_sim=10.0, dur_sim=0.2, node="sat0", n_real=10, n_pad=16,
            queue_after=0)
    rep = analyze(tr.spans)
    sv = rep.serving
    assert sv is not None
    assert sv.requests == 10 and sv.batches == 1
    assert sv.latency_p99 >= sv.latency_p50 > 0
    assert sv.wait_mean == pytest.approx(0.1)
    assert sv.served_accuracy == pytest.approx(0.7)
    assert sv.by_region == {"indiana": 10}
    assert sv.by_target == {"sat": 5, "isl": 5}
    assert sv.mean_batch == pytest.approx(10.0)
    assert sv.fill == pytest.approx(10 / 16)
    # serving spans stay out of the TRAINING tables and run_end
    assert rep.regions[0].rounds == 1
    text = render(rep)
    assert "serving" in text
    assert "p99_s" in text and "fill" in text and "routes:" in text


def test_report_without_serving_spans_has_no_section(traced_engine_run):
    path, _ = traced_engine_run
    rep = analyze(load_jsonl(path))
    assert rep.serving is None
    assert "serving (" not in render(rep)
