"""Degrade-to-skip guard for the optional ``hypothesis`` test dependency.

``hypothesis`` ships via ``pip install -e .[test]`` (see pyproject.toml)
but may be absent in minimal environments. Importing it unguarded made
four test modules ERROR at collection; this shim makes them degrade the
way ``pytest.importorskip`` would — except only the property-based tests
skip, while plain tests in the same modules still run.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from _hyp import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg stub so pytest doesn't treat the hypothesis
            # parameters as missing fixtures.
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Accepts any strategies.* call; values are never drawn."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _AnyStrategy()
