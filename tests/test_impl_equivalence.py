"""Property tests: every optimized implementation strategy must be
numerically equivalent to its naive reference (the §Perf contract)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip when hypothesis is absent

from repro.configs import get_config
from repro.kernels.wkv6 import ref as wkv_ref
from repro.models import layers as L


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       t=st.sampled_from([64, 128, 256]),
       chunk=st.sampled_from([16, 32, 64]))
def test_wkv_chunked_equals_oracle(seed, t, chunk):
    rng = np.random.default_rng(seed)
    b, h, d = 1, 2, 16
    r = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    # RWKV6-realistic decays: w = exp(-exp(x))
    w = jnp.exp(-jnp.exp(jnp.asarray(rng.normal(-2, 0.8, size=(b, h, t, d)),
                                     jnp.float32)))
    u = jnp.asarray(rng.normal(size=(h, d)), jnp.float32) * 0.1
    o1 = wkv_ref.wkv(r, k, v, w, u)
    o2 = wkv_ref.wkv_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([0, 8, 16]))
def test_mamba_chunked_scan_equals_naive(seed, chunk):
    cfg0 = get_config("jamba-1.5-large-398b").reduced()
    rng = np.random.default_rng(seed)
    p = L.mamba_init(cfg0, jax.random.PRNGKey(seed))
    x = jnp.asarray(rng.normal(size=(2, 32, cfg0.d_model)), jnp.float32)
    y0 = L.mamba_apply(p, x, dataclasses.replace(cfg0, mamba_scan_chunk=0))
    y1 = L.mamba_apply(p, x, dataclasses.replace(cfg0,
                                                 mamba_scan_chunk=chunk))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_moe_grouped_equals_flat_at_high_capacity(seed):
    """With capacity high enough that no token is dropped, grouped
    (scatter-free) and flat dispatch compute the same function."""
    cfg = dataclasses.replace(
        get_config("qwen3-moe-235b-a22b").reduced(), capacity_factor=8.0)
    p = L.moe_init(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    yg = L.moe_apply(p, x, dataclasses.replace(cfg, moe_grouped=True))
    yf = L.moe_apply(p, x, dataclasses.replace(cfg, moe_grouped=False))
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yf),
                               rtol=1e-4, atol=1e-4)


def test_moe_grouped_gradients_flow_to_all_param_kinds():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = L.moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 16, cfg.d_model)), jnp.float32)
    g = jax.grad(lambda p_: jnp.sum(L.moe_apply(p_, x, cfg) ** 2))(p)
    for name in ("router", "we1", "we2", "we3"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0, name
