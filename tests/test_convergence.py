"""Tests for the Theorem-1 bound evaluator."""
import numpy as np
import pytest

from repro.core.convergence import (ConvergenceConfig, bound_decays_to_zero,
                                    constant_lr, decaying_lr,
                                    max_learning_rate, theorem1_bound)


def cfg(R=100, c=1.0, delta=1.0):
    return ConvergenceConfig(smoothness=10.0, sigma_g=1.0,
                             c_r=[c] * R, delta_r=[delta] * R,
                             h_local=5, f0_minus_fstar=10.0)


def test_learning_rate_condition_eq37():
    c = cfg()
    lr = max_learning_rate(c, 0)
    assert lr == pytest.approx(1.0 / (2 * np.sqrt(2.0) * 5 * 10.0))


def test_bound_positive_and_finite():
    c = cfg()
    etas = [constant_lr(c.h_local, 100)] * 100
    b = theorem1_bound(c, etas, [0.1] * 100)
    assert np.isfinite(b) and b > 0


def test_bound_decays_with_R():
    """With eta = 1/sqrt(HR) the bound must go to 0 as R grows."""
    c = cfg(R=1)
    curve = bound_decays_to_zero(c, 200)
    assert curve[-1] < curve[10]
    assert curve[-1] < curve[50]


def test_heterogeneity_increases_bound():
    """Larger delta_r (data dissimilarity) => larger bound (last term)."""
    R = 50
    etas = [constant_lr(5, R)] * R
    lam = [0.1] * R
    b_small = theorem1_bound(cfg(R, delta=0.5), etas, lam)
    b_large = theorem1_bound(cfg(R, delta=5.0), etas, lam)
    assert b_large > b_small


def test_uniform_lambda_minimizes_variance_term():
    """sum lambda_i^2 is minimal when portions are equal, so the bound with
    concentrated data is larger (second term of eq. 38)."""
    R = 50
    etas = [constant_lr(5, R)] * R
    b_uniform = theorem1_bound(cfg(R), etas, [1.0 / 56] * R)  # 56 nodes equal
    b_skewed = theorem1_bound(cfg(R), etas, [0.5] * R)
    assert b_uniform < b_skewed


def test_decaying_lr_schedule():
    assert decaying_lr(0.1, 0) == pytest.approx(0.1)
    assert decaying_lr(0.1, 9) == pytest.approx(0.01)
