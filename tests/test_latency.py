"""Unit tests for the latency model (eqs. 5, 7, 14-19)."""
import numpy as np
import pytest

from repro.core import build_default_sagin
from repro.core import latency as lat
from repro.core.network import ChannelModel, Satellite


def test_comp_time():
    # eq. (5): 3e9 cycles/sample, 1200 samples, 1e8 Hz -> 36000 s
    assert lat.comp_time(3e9, 1200, 1e8) == pytest.approx(36000.0)


def test_handover_delay_eq7():
    q = lat.handover_delay(model_bits=3.2e7, q_bits=6272, n_samples=1000,
                           z_isl=3.125e6)
    assert q == pytest.approx((3.2e7 + 6.272e6) / 3.125e6)


def test_rate_monotonic_in_power():
    ch = ChannelModel(rayleigh=False)
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    dev = sagin.devices[0]
    air = sagin.air_nodes[0]
    r1 = ch.g2a_rate(dev, air)
    dev2 = type(dev)(index=dev.index, position=dev.position, p=dev.p * 10,
                     n_samples=dev.n_samples)
    r2 = ch.g2a_rate(dev2, air)
    assert r2 > r1


def test_rayleigh_expectation_below_awgn():
    """Jensen: E[log(1+pX)] <= log(1+pE[X]) for X ~ Exp(1)."""
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    dev, air = sagin.devices[0], sagin.air_nodes[0]
    ch_ray = ChannelModel(rayleigh=True, mc_samples=200_000)
    ch_los = ChannelModel(rayleigh=False)
    # same average gain: compare shapes only qualitatively
    r_ray = ch_ray.g2a_rate(dev, air)
    r_los = ch_los.g2a_rate(dev, air)
    assert r_ray <= r_los * (1 + 0.05)


def test_round_latency_no_offload_structure():
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    sagin.satellites = [Satellite(0, f=1e10, coverage_end=np.inf)]
    t = lat.round_latency_no_offload(sagin)
    # dominated by the slow ground devices (eq. 16/17)
    t_ground = max(
        lat.comp_time(d.m, d.n_samples, d.f) for d in sagin.devices)
    assert t >= t_ground


def test_free_space_faster_than_rayleigh_end_to_end():
    s_ray = build_default_sagin(n_devices=4, n_air=1, rayleigh=True, seed=0)
    s_los = build_default_sagin(n_devices=4, n_air=1, rayleigh=False, seed=0)
    for s in (s_ray, s_los):
        s.satellites = [Satellite(0, f=5e9, coverage_end=np.inf)]
    assert (lat.round_latency_no_offload(s_los)
            <= lat.round_latency_no_offload(s_ray) + 1e-6)
