"""Tests for the batched cohort execution engine.

Covers the three contracts of the engine:

1. ``build_cohort`` padding/masking correctness on ragged pools.
2. Masked cohort training == per-client sequential training, both at the
   client level (``cohort_local_update`` vs a ``local_update`` loop) and
   end-to-end (``run_fl`` with ``execution="batched"`` vs
   ``"sequential"`` at equal seeds).
3. ``fedavg_stacked`` through the interpret-mode Pallas ``fedavg_agg``
   kernel agrees with the host-side ``fedavg`` list loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (batch_for_local_steps, build_bucketed_cohort,
                                 build_cohort, next_geometric, plan_buckets)
from repro.fl import (CohortEngine, FLConfig, fedavg, fedavg_stacked,
                      fedavg_stacked_multi, run_fl)
from repro.fl.client import (cohort_local_update, cross_entropy,
                             local_update, masked_cross_entropy)


def _mlp_init(key, din=32, dh=16, nc=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (din, dh)) * 0.1,
            "b1": jnp.zeros(dh),
            "w2": jax.random.normal(k2, (dh, nc)) * 0.1,
            "b2": jnp.zeros(nc)}


def _mlp_apply(p, x):
    h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _toy_data(n=400, din=32, nc=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, nc, n).astype(np.int32)
    return x, y


# ---------------------------------------------------------------------------
# 1. cohort builder: padding + masking on ragged pools
# ---------------------------------------------------------------------------
def test_build_cohort_ragged_padding_and_masks():
    x, y = _toy_data()
    h = 4
    pools = [np.arange(0, 7), np.arange(7, 100), np.arange(100, 101),
             np.empty(0, dtype=np.int64), np.arange(101, 140)]
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(0),
                          max_batch=16, batch_align=8)
    # empty pool dropped; 4 real clients
    assert cohort.n_clients == 4
    c, hh, b = cohort.mask.shape
    assert (c, hh) == (4, h)
    assert b % 8 == 0
    # per-client batch sizes follow batch_for_local_steps' sizing rule,
    # checked through the mask (mask rows are a prefix of ones)
    for ci, idx in enumerate([p for p in pools if len(p)]):
        bc = int(np.clip(int(np.ceil(len(idx) / h)), 1, 16))
        assert cohort.sizes[ci] == len(idx)
        np.testing.assert_array_equal(cohort.mask[ci].sum(axis=1),
                                      np.full(h, bc))
        # padded slots are zero
        assert np.all(cohort.xs[ci, :, bc:] == 0)
        assert np.all(cohort.ys[ci, :, bc:] == 0)
        # real slots hold samples from this client's own pool
        sel_x = cohort.xs[ci, :, :bc].reshape(-1, x.shape[1])
        pool_x = x[idx]
        for row in sel_x[:8]:
            assert np.any(np.all(np.isclose(pool_x, row), axis=1))


def test_build_cohort_matches_sequential_rng_stream():
    """Same rng + same pool order => same batches as the per-node calls."""
    x, y = _toy_data(seed=1)
    h = 3
    pools = [np.arange(0, 50), np.arange(50, 120), np.arange(120, 200)]
    seq_rng = np.random.default_rng(42)
    seq = [batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=16)
           for idx in pools]
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(42),
                          max_batch=16)
    for ci, (bx, by) in enumerate(seq):
        b = bx.shape[1]
        np.testing.assert_array_equal(cohort.xs[ci, :, :b], bx)
        np.testing.assert_array_equal(cohort.ys[ci, :, :b], by)


def test_build_cohort_pad_clients_and_empty():
    x, y = _toy_data()
    cohort = build_cohort(x, y, [np.arange(10)], 2,
                          np.random.default_rng(0), pad_clients=7)
    assert cohort.xs.shape[0] == 7
    assert cohort.n_clients == 1
    assert np.all(cohort.mask[1:] == 0)
    assert np.all(cohort.sizes[1:] == 0)
    assert build_cohort(x, y, [], 2, np.random.default_rng(0)) is None


# ---------------------------------------------------------------------------
# 2. masked/batched training == sequential training
# ---------------------------------------------------------------------------
def test_masked_cross_entropy_reduces_to_unmasked():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, 6), jnp.int32)
    full = masked_cross_entropy(logits, labels, jnp.ones(6))
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(cross_entropy(logits, labels)),
                               rtol=1e-6)
    # zero mask: loss 0 (and, downstream, zero gradient)
    assert float(masked_cross_entropy(logits, labels, jnp.zeros(6))) == 0.0


def test_cohort_local_update_matches_sequential_loop():
    x, y = _toy_data()
    h, lr = 3, 0.1
    pools = [np.arange(0, 30), np.arange(30, 110), np.arange(110, 117)]
    params = _mlp_init(jax.random.PRNGKey(0))
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(7),
                          max_batch=16, pad_clients=5)
    stacked, losses = cohort_local_update(
        _mlp_apply, params, jnp.asarray(cohort.xs), jnp.asarray(cohort.ys),
        jnp.asarray(cohort.mask), lr)

    seq_rng = np.random.default_rng(7)
    for ci, idx in enumerate(pools):
        bx, by = batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=16)
        ref_params, ref_loss = local_update(_mlp_apply, params,
                                            jnp.asarray(bx),
                                            jnp.asarray(by), lr)
        for got, ref in zip(jax.tree_util.tree_leaves(
                                jax.tree_util.tree_map(lambda a: a[ci],
                                                       stacked)),
                            jax.tree_util.tree_leaves(ref_params)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5)
        np.testing.assert_allclose(float(losses[ci]), float(ref_loss),
                                   atol=1e-5)
    # padding clients: zero loss, unchanged params
    for got, ref in zip(jax.tree_util.tree_leaves(
                            jax.tree_util.tree_map(lambda a: a[4], stacked)),
                        jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6)
    assert float(losses[4]) == 0.0


@pytest.mark.slow
def test_run_fl_batched_matches_sequential_trajectory():
    """The numerical-equivalence guarantee of the execution knob."""
    common = dict(dataset="mnist", n_rounds=2, train_fraction=0.005,
                  n_devices=4, n_air=1, h_local=2, eval_size=64, seed=3)
    seq = run_fl(FLConfig(execution="sequential", **common))
    bat = run_fl(FLConfig(execution="batched", **common))
    np.testing.assert_allclose(bat.accuracies, seq.accuracies, atol=1e-3)
    np.testing.assert_allclose(bat.losses, seq.losses, atol=1e-3)
    # orchestration (latency/plan side) is engine-independent
    assert bat.cases == seq.cases
    np.testing.assert_allclose(bat.latencies, seq.latencies, rtol=1e-9)


# ---------------------------------------------------------------------------
# 2b. size-bucketed engine: planner geometry, equivalence, padding bound,
#     zero recompiles after warm-up
# ---------------------------------------------------------------------------
def test_plan_buckets_geometry():
    assert next_geometric(1, 8) == 8
    assert next_geometric(8, 8) == 8
    assert next_geometric(9, 8) == 16
    assert next_geometric(100, 8) == 128
    plans = plan_buckets([3, 8, 9, 64, 5], batch_align=8, client_align=4)
    # the 8- and 16-wide groups coalesce (joint layout 4x16 = 64 beats
    # separate 4x8 + 4x16 = 96); the 64-wide outlier stays its own bucket
    assert [p.b_bucket for p in plans] == [16, 64]
    assert [p.members for p in plans] == [(0, 1, 2, 4), (3,)]
    # client counts quantized to the geometric grid, floored at align
    assert [p.c_bucket for p in plans] == [4, 4]
    many = plan_buckets([4] * 11, batch_align=8, client_align=4)
    assert many[0].c_bucket == 16
    # a merge-hostile slack keeps the pure geometric partition (collapse
    # disabled too — both knobs off mean the untouched geometric plan)
    pure = plan_buckets([3, 8, 9, 64, 5], batch_align=8, client_align=4,
                        merge_slack=0.5, collapse_slack=0.0)
    assert [p.b_bucket for p in pure] == [8, 16, 64]
    assert [p.members for p in pure] == [(0, 1, 4), (2,), (3,)]


def test_plan_buckets_collapse_and_shard_multiple():
    # small-cohort collapse: near-uniform widths whose multi-bucket plan
    # saves little padding fold into ONE dispatch (the dispatch-bound
    # C=16 regime of BENCH_cohort.json)
    small = plan_buckets([6, 8, 10, 12, 9, 14, 7, 11], batch_align=8,
                         client_align=4)
    assert len(small) == 1
    assert small[0].b_bucket == 16
    assert small[0].members == tuple(range(8))
    # ... but a heavy-skew plan stays split: collapsing would multiply
    # the padding far beyond collapse_slack
    skew = plan_buckets([8] * 12 + [512], batch_align=8, client_align=4)
    assert len(skew) > 1
    # shard-aware mode: every client count divides across the mesh's
    # data axis, on a grid that is still geometric (drift-stable)
    for shards in (1, 2, 4, 8):
        plans = plan_buckets([8] * 12 + [512], batch_align=8,
                             client_align=4, client_multiple=shards)
        for p in plans:
            assert p.c_bucket % shards == 0
            assert p.c_bucket >= len(p.members)
    # lcm grid: client_align=4 with 8 shards quantizes to 8 * 2^k
    plans = plan_buckets([8, 8, 8], batch_align=8, client_align=4,
                         client_multiple=8)
    assert plans[0].c_bucket == 8


def test_bucketed_cohort_matches_sequential_rng_stream():
    """The union of the buckets holds exactly the batches the sequential
    loop (and the global-Bmax cohort) draws, in canonical order."""
    x, y = _toy_data(n=600, seed=1)
    h = 3
    pools = [np.arange(0, 50), np.arange(50, 120), np.arange(120, 440)]
    seq_rng = np.random.default_rng(42)
    seq = [batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=8)
           for idx in pools]
    cohort = build_bucketed_cohort(x, y, pools, h,
                                   np.random.default_rng(42), max_batch=8,
                                   batch_align=8)
    assert cohort.n_clients == 3
    np.testing.assert_array_equal(cohort.sizes,
                                  [len(p) for p in pools])
    located = 0
    for plan, cb in zip(cohort.plans, cohort.buckets):
        for slot, pos in enumerate(plan.members):
            bx, by = seq[pos]
            b = bx.shape[1]
            np.testing.assert_array_equal(cb.xs[slot, :, :b], bx)
            np.testing.assert_array_equal(cb.ys[slot, :, :b], by)
            assert np.all(cb.mask[slot, :, :b] == 1.0)
            assert np.all(cb.mask[slot, :, b:] == 0.0)
            located += 1
    assert located == 3
    assert build_bucketed_cohort(x, y, [], h,
                                 np.random.default_rng(0)) is None


def test_cohort_engine_round_matches_sequential_loop():
    """Bucketed execution == per-client local_update + host fedavg."""
    x, y = _toy_data(n=1200, seed=3)
    h, lr = 3, 0.1
    # enough narrow clients that coalescing them into the 10x pool's
    # bucket (or collapsing the whole plan into one) would multiply the
    # padding -> genuinely multi-bucket
    pools = [np.arange(k * 30, (k + 1) * 30) for k in range(12)]
    pools.append(np.arange(200, 1100))
    total = sum(len(p) for p in pools)
    params = _mlp_init(jax.random.PRNGKey(0))
    engine = CohortEngine(_mlp_apply, batch_align=8, client_align=4)
    cohort = engine.build(x, y, pools, h, np.random.default_rng(7),
                          max_batch=8)
    assert len(cohort.buckets) > 1
    new_params, losses = engine.round(params, cohort, lr, total)

    seq_rng = np.random.default_rng(7)
    ref_models, ref_weights, ref_losses = [], [], []
    for idx in pools:
        bx, by = batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=8)
        p, loss = local_update(_mlp_apply, params, jnp.asarray(bx),
                               jnp.asarray(by), lr)
        ref_models.append(p)
        ref_weights.append(len(idx) / total)
        ref_losses.append(float(loss))
    ref = fedavg(ref_models, ref_weights)
    for got, want in zip(jax.tree_util.tree_leaves(new_params),
                         jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
    np.testing.assert_allclose(losses, ref_losses, atol=1e-5)
    assert engine.stats.rounds == 1
    assert engine.stats.bucket_dispatches == len(cohort.buckets)


def test_cohort_engine_fused_donating_path_matches():
    """The single-bucket fused step (donate=True fast path) computes the
    same round as the split dispatch path.  Donation CONSUMES the params
    argument, so the fused engine gets its own copy."""
    x, y = _toy_data(n=400, seed=9)
    h, lr = 3, 0.1
    pools = [np.arange(0, 60), np.arange(60, 140), np.arange(140, 230)]
    total = sum(len(p) for p in pools)
    params = _mlp_init(jax.random.PRNGKey(4))
    fused = CohortEngine(_mlp_apply, batch_align=8, donate=True)
    split = CohortEngine(_mlp_apply, batch_align=8, donate=False)
    c1 = fused.build(x, y, pools, h, np.random.default_rng(3), max_batch=16)
    c2 = split.build(x, y, pools, h, np.random.default_rng(3), max_batch=16)
    assert len(c1.buckets) == 1
    own = jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True), params)
    p1, l1 = fused.round(own, c1, lr, total)
    p2, l2 = split.round(params, c2, lr, total)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(l1, l2, atol=1e-6)


def test_heavy_skew_padding_ratio_bounded():
    """One 10x pool among many small ones: the global-Bmax layout pays
    ~the widest client's batch for everyone, the bucketed layout stays
    within a constant factor of the real element count."""
    x, y = _toy_data(n=8000, seed=0)
    h = 5
    pools = [np.arange(k * 40, (k + 1) * 40) for k in range(31)]
    pools.append(np.arange(1300, 1300 + 4000))      # the 10x offload target
    cohort = build_bucketed_cohort(x, y, pools, h,
                                   np.random.default_rng(0), max_batch=8,
                                   batch_align=8)
    glob = build_cohort(x, y, pools, h, np.random.default_rng(0),
                        max_batch=8, batch_align=8, pad_clients=33)
    real = cohort.real_elements
    global_ratio = glob.mask.size / real
    assert cohort.padding_ratio < 2.5          # constant-factor bound
    assert global_ratio > 2 * cohort.padding_ratio
    # both layouts drew identical batches for identical RNG streams
    assert int(np.sum(glob.mask)) == real


def test_zero_recompiles_after_warmup():
    """Pool drift inside the geometric grid must not trigger recompiles:
    after a warm-up round per signature set, further rounds hit jax's
    jit cache exclusively.  Enforced through the shared
    ``analysis.contracts.no_recompile`` contract (the same guard
    ``CohortEngine(guard=True)`` arms per warm round)."""
    from repro.analysis import contracts

    x, y = _toy_data(n=4000, seed=5)
    h, lr = 3, 0.05
    params = _mlp_init(jax.random.PRNGKey(1))
    engine = CohortEngine(_mlp_apply, batch_align=8, client_align=4)

    def pools_for(r, rng):
        # drifting sizes: small pools wobble within one width bucket,
        # the big pool grows (offloading) but stays inside its bucket
        smalls = [np.asarray(rng.choice(3000, size=30 + 2 * ((r + k) % 3),
                                        replace=False))
                  for k in range(6)]
        big = np.asarray(rng.choice(4000, size=900 + 40 * r, replace=False))
        return smalls + [big]

    rng = np.random.default_rng(11)
    total = 5000
    for r in range(3):                                   # warm-up rounds
        cohort = engine.build(x, y, pools_for(r, rng), h,
                              np.random.default_rng(r), max_batch=8)
        params, _ = engine.round(params, cohort, lr, total)
    jax.block_until_ready(jax.tree_util.tree_leaves(params))
    sigs_after_warmup = set(engine.signatures)

    with contracts.no_recompile(label="cohort warm rounds") as rc:
        for r in range(3, 8):
            cohort = engine.build(x, y, pools_for(r, rng), h,
                                  np.random.default_rng(r), max_batch=8)
            params, _ = engine.round(params, cohort, lr, total)
        jax.block_until_ready(jax.tree_util.tree_leaves(params))
    if not rc.enforced:
        pytest.skip("jax lowering counters unavailable in this jax")
    assert rc.count == 0
    assert set(engine.signatures) == sigs_after_warmup


def test_guarded_engine_self_arms_on_warm_signatures():
    """``CohortEngine(guard=True)`` must (a) stay silent across warm
    rounds on a stable layout and (b) actually raise when the warm path
    recompiles — seeded here by evicting jax's jit cache between two
    rounds of the same signature."""
    from repro.analysis import contracts

    x, y = _toy_data(n=2000, seed=7)
    params = _mlp_init(jax.random.PRNGKey(3))
    engine = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                          guard=True)
    pools = [np.arange(k * 60, (k + 1) * 60) for k in range(5)]
    for r in range(4):      # round 1 cold (unguarded), 2-4 guarded warm
        cohort = engine.build(x, y, pools, 3, np.random.default_rng(r),
                              max_batch=8)
        params, _ = engine.round(params, cohort, 0.05, 300)
    assert len(engine.round_signatures) == 1

    jax.clear_caches()      # forces a recompile on the next warm round
    cohort = engine.build(x, y, pools, 3, np.random.default_rng(9),
                          max_batch=8)
    with pytest.raises(contracts.ContractViolation):
        engine.round(params, cohort, 0.05, 300)


def test_fedavg_stacked_multi_matches_single_stack():
    params = _mlp_init(jax.random.PRNGKey(2))
    models = []
    for i in range(6):
        key = jax.random.PRNGKey(20 + i)
        models.append(jax.tree_util.tree_map(
            lambda x: x + 0.05 * jax.random.normal(key, x.shape), params))
    w = jnp.asarray([0.1, 0.15, 0.2, 0.25, 0.2, 0.1])
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    ref = fedavg_stacked(stacked, w)
    parts = (jax.tree_util.tree_map(lambda a: a[:2], stacked),
             jax.tree_util.tree_map(lambda a: a[2:5], stacked),
             jax.tree_util.tree_map(lambda a: a[5:], stacked))
    got = fedavg_stacked_multi(parts, w)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.slow
def test_run_fl_bucketed_matches_global_and_sequential():
    """End-to-end: geometric bucketing preserves the numerical-
    equivalence contract of the execution knob."""
    common = dict(dataset="mnist", n_rounds=2, train_fraction=0.005,
                  n_devices=4, n_air=1, h_local=2, eval_size=64, seed=5)
    seq = run_fl(FLConfig(execution="sequential", **common))
    buck = run_fl(FLConfig(execution="batched",
                           cohort_bucketing="geometric", **common))
    glob = run_fl(FLConfig(execution="batched",
                           cohort_bucketing="global", **common))
    np.testing.assert_allclose(buck.accuracies, seq.accuracies, atol=1e-3)
    np.testing.assert_allclose(buck.losses, seq.losses, atol=1e-3)
    np.testing.assert_allclose(buck.accuracies, glob.accuracies, atol=1e-3)
    assert buck.cases == seq.cases


# ---------------------------------------------------------------------------
# 3. stacked aggregation: interpret-mode Pallas kernel vs host-side list loop
# ---------------------------------------------------------------------------
def test_fedavg_stacked_interpret_kernel_matches_fedavg():
    params = _mlp_init(jax.random.PRNGKey(1))
    models = []
    for i in range(4):
        key = jax.random.PRNGKey(10 + i)
        models.append(jax.tree_util.tree_map(
            lambda x: x + 0.05 * jax.random.normal(key, x.shape), params))
    w = [0.1, 0.4, 0.2, 0.3]
    ref = fedavg(models, w)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    out = fedavg_stacked(stacked, jnp.asarray(w), interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
