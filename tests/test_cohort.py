"""Tests for the batched cohort execution engine.

Covers the three contracts of the engine:

1. ``build_cohort`` padding/masking correctness on ragged pools.
2. Masked cohort training == per-client sequential training, both at the
   client level (``cohort_local_update`` vs a ``local_update`` loop) and
   end-to-end (``run_fl`` with ``execution="batched"`` vs
   ``"sequential"`` at equal seeds).
3. ``fedavg_stacked`` through the interpret-mode Pallas ``fedavg_agg``
   kernel agrees with the host-side ``fedavg`` list loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import batch_for_local_steps, build_cohort
from repro.fl import FLConfig, fedavg, fedavg_stacked, run_fl
from repro.fl.client import (cohort_local_update, cross_entropy,
                             local_update, masked_cross_entropy)


def _mlp_init(key, din=32, dh=16, nc=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (din, dh)) * 0.1,
            "b1": jnp.zeros(dh),
            "w2": jax.random.normal(k2, (dh, nc)) * 0.1,
            "b2": jnp.zeros(nc)}


def _mlp_apply(p, x):
    h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _toy_data(n=400, din=32, nc=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, nc, n).astype(np.int32)
    return x, y


# ---------------------------------------------------------------------------
# 1. cohort builder: padding + masking on ragged pools
# ---------------------------------------------------------------------------
def test_build_cohort_ragged_padding_and_masks():
    x, y = _toy_data()
    h = 4
    pools = [np.arange(0, 7), np.arange(7, 100), np.arange(100, 101),
             np.empty(0, dtype=np.int64), np.arange(101, 140)]
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(0),
                          max_batch=16, batch_align=8)
    # empty pool dropped; 4 real clients
    assert cohort.n_clients == 4
    c, hh, b = cohort.mask.shape
    assert (c, hh) == (4, h)
    assert b % 8 == 0
    # per-client batch sizes follow batch_for_local_steps' sizing rule,
    # checked through the mask (mask rows are a prefix of ones)
    for ci, idx in enumerate([p for p in pools if len(p)]):
        bc = int(np.clip(int(np.ceil(len(idx) / h)), 1, 16))
        assert cohort.sizes[ci] == len(idx)
        np.testing.assert_array_equal(cohort.mask[ci].sum(axis=1),
                                      np.full(h, bc))
        # padded slots are zero
        assert np.all(cohort.xs[ci, :, bc:] == 0)
        assert np.all(cohort.ys[ci, :, bc:] == 0)
        # real slots hold samples from this client's own pool
        sel_x = cohort.xs[ci, :, :bc].reshape(-1, x.shape[1])
        pool_x = x[idx]
        for row in sel_x[:8]:
            assert np.any(np.all(np.isclose(pool_x, row), axis=1))


def test_build_cohort_matches_sequential_rng_stream():
    """Same rng + same pool order => same batches as the per-node calls."""
    x, y = _toy_data(seed=1)
    h = 3
    pools = [np.arange(0, 50), np.arange(50, 120), np.arange(120, 200)]
    seq_rng = np.random.default_rng(42)
    seq = [batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=16)
           for idx in pools]
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(42),
                          max_batch=16)
    for ci, (bx, by) in enumerate(seq):
        b = bx.shape[1]
        np.testing.assert_array_equal(cohort.xs[ci, :, :b], bx)
        np.testing.assert_array_equal(cohort.ys[ci, :, :b], by)


def test_build_cohort_pad_clients_and_empty():
    x, y = _toy_data()
    cohort = build_cohort(x, y, [np.arange(10)], 2,
                          np.random.default_rng(0), pad_clients=7)
    assert cohort.xs.shape[0] == 7
    assert cohort.n_clients == 1
    assert np.all(cohort.mask[1:] == 0)
    assert np.all(cohort.sizes[1:] == 0)
    assert build_cohort(x, y, [], 2, np.random.default_rng(0)) is None


# ---------------------------------------------------------------------------
# 2. masked/batched training == sequential training
# ---------------------------------------------------------------------------
def test_masked_cross_entropy_reduces_to_unmasked():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, 6), jnp.int32)
    full = masked_cross_entropy(logits, labels, jnp.ones(6))
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(cross_entropy(logits, labels)),
                               rtol=1e-6)
    # zero mask: loss 0 (and, downstream, zero gradient)
    assert float(masked_cross_entropy(logits, labels, jnp.zeros(6))) == 0.0


def test_cohort_local_update_matches_sequential_loop():
    x, y = _toy_data()
    h, lr = 3, 0.1
    pools = [np.arange(0, 30), np.arange(30, 110), np.arange(110, 117)]
    params = _mlp_init(jax.random.PRNGKey(0))
    cohort = build_cohort(x, y, pools, h, np.random.default_rng(7),
                          max_batch=16, pad_clients=5)
    stacked, losses = cohort_local_update(
        _mlp_apply, params, jnp.asarray(cohort.xs), jnp.asarray(cohort.ys),
        jnp.asarray(cohort.mask), lr)

    seq_rng = np.random.default_rng(7)
    for ci, idx in enumerate(pools):
        bx, by = batch_for_local_steps(x, y, idx, h, seq_rng, max_batch=16)
        ref_params, ref_loss = local_update(_mlp_apply, params,
                                            jnp.asarray(bx),
                                            jnp.asarray(by), lr)
        for got, ref in zip(jax.tree_util.tree_leaves(
                                jax.tree_util.tree_map(lambda a: a[ci],
                                                       stacked)),
                            jax.tree_util.tree_leaves(ref_params)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5)
        np.testing.assert_allclose(float(losses[ci]), float(ref_loss),
                                   atol=1e-5)
    # padding clients: zero loss, unchanged params
    for got, ref in zip(jax.tree_util.tree_leaves(
                            jax.tree_util.tree_map(lambda a: a[4], stacked)),
                        jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-6)
    assert float(losses[4]) == 0.0


@pytest.mark.slow
def test_run_fl_batched_matches_sequential_trajectory():
    """The numerical-equivalence guarantee of the execution knob."""
    common = dict(dataset="mnist", n_rounds=2, train_fraction=0.005,
                  n_devices=4, n_air=1, h_local=2, eval_size=64, seed=3)
    seq = run_fl(FLConfig(execution="sequential", **common))
    bat = run_fl(FLConfig(execution="batched", **common))
    np.testing.assert_allclose(bat.accuracies, seq.accuracies, atol=1e-3)
    np.testing.assert_allclose(bat.losses, seq.losses, atol=1e-3)
    # orchestration (latency/plan side) is engine-independent
    assert bat.cases == seq.cases
    np.testing.assert_allclose(bat.latencies, seq.latencies, rtol=1e-9)


# ---------------------------------------------------------------------------
# 3. stacked aggregation: interpret-mode Pallas kernel vs host-side list loop
# ---------------------------------------------------------------------------
def test_fedavg_stacked_interpret_kernel_matches_fedavg():
    params = _mlp_init(jax.random.PRNGKey(1))
    models = []
    for i in range(4):
        key = jax.random.PRNGKey(10 + i)
        models.append(jax.tree_util.tree_map(
            lambda x: x + 0.05 * jax.random.normal(key, x.shape), params))
    w = [0.1, 0.4, 0.2, 0.3]
    ref = fedavg(models, w)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    out = fedavg_stacked(stacked, jnp.asarray(w), interpret=True)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
