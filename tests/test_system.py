"""End-to-end behaviour tests for the paper's system: multi-round
orchestration with constellation-driven coverage windows + real training."""
import numpy as np
import pytest

from repro.core import SAGINOrchestrator, WalkerStar, build_default_sagin


def test_orchestrator_multi_round_adaptive():
    sagin = build_default_sagin(n_devices=8, n_air=2, seed=0)
    orch = SAGINOrchestrator(sagin, strategy="adaptive")
    recs = orch.run(5)
    assert len(recs) == 5
    # wall clock advances by the realized latency of each round
    assert orch.wall_clock == pytest.approx(sum(r.latency for r in recs))
    for r in recs:
        assert r.latency > 0
        assert np.isfinite(r.latency)
        # conservation each round
        assert (sum(r.ground_sizes) + sum(r.air_sizes) + r.sat_size
                == sagin.total_samples)


def test_orchestrator_with_constellation():
    """Coverage windows come from the Walker-Star geometry; the handover
    schedule must respect them."""
    sagin = build_default_sagin(n_devices=6, n_air=2, seed=1)
    orch = SAGINOrchestrator(sagin, constellation=WalkerStar(),
                             horizon=12 * 3600.0, strategy="adaptive")
    recs = orch.run(3)
    for rec in recs:
        for leg, sat in zip(rec.schedule.legs, sagin.satellites):
            assert leg.end_time <= sat.coverage_end + 1e-6


def test_strategies_ordering():
    """Adaptive must beat no-offloading in per-round latency; static equals
    adaptive in round 0."""
    lat = {}
    for strat in ("adaptive", "none", "static", "proportional"):
        sagin = build_default_sagin(n_devices=8, n_air=2, seed=2)
        orch = SAGINOrchestrator(sagin, strategy=strat)
        recs = orch.run(3)
        lat[strat] = [r.latency for r in recs]
    assert lat["adaptive"][0] <= lat["none"][0] + 1e-6
    assert lat["adaptive"][0] == pytest.approx(lat["static"][0], rel=1e-6)
    assert np.mean(lat["adaptive"]) <= np.mean(lat["proportional"]) + 1e-6


def test_handover_count_increases_with_slow_satellites():
    from repro.core.network import Satellite
    sagin = build_default_sagin(n_devices=8, n_air=2, seed=3)
    sagin.n_sat_samples = 20000
    for d in sagin.devices:
        d.n_samples = d.n_sensitive = 10
    sagin.satellites = [Satellite(i, f=1e9, coverage_end=60.0 * (i + 1))
                        for i in range(5)] + [
        Satellite(9, f=1e9, coverage_end=np.inf)]
    from repro.core import space_schedule
    sch = space_schedule(sagin.n_sat_samples, sagin)
    assert sch.n_handovers >= 2
