"""Tests for the federated data pipeline (partitioner + pools)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip when hypothesis is absent

from repro.data import FederatedPools, make_dataset, partition


@pytest.fixture(scope="module")
def ds():
    return make_dataset("mnist", train_fraction=0.02, seed=0)


def test_partition_iid_covers_all(ds):
    parts = partition(ds, n_devices=10, iid=True)
    all_idx = np.concatenate([p.indices for p in parts])
    assert len(all_idx) == len(ds.x_train)
    assert len(np.unique(all_idx)) == len(all_idx)


def test_partition_noniid_is_skewed(ds):
    parts_iid = partition(ds, n_devices=10, iid=True, seed=0)
    parts_nid = partition(ds, n_devices=10, iid=False, seed=0)

    def label_entropy(parts):
        ents = []
        for p in parts:
            y = ds.y_train[p.indices]
            counts = np.bincount(y, minlength=10) / len(y)
            counts = counts[counts > 0]
            ents.append(-np.sum(counts * np.log(counts)))
        return np.mean(ents)

    assert label_entropy(parts_nid) < label_entropy(parts_iid) - 0.3


@settings(max_examples=15, deadline=None)
@given(alpha=st.floats(0.0, 1.0), n_devices=st.integers(2, 20))
def test_partition_alpha_property(alpha, n_devices):
    ds = make_dataset("fmnist", train_fraction=0.01, seed=1)
    parts = partition(ds, n_devices=n_devices, alpha=alpha, seed=2)
    for p in parts:
        expected = round((1 - alpha) * p.n_samples)
        assert abs(p.n_sensitive - expected) <= 1
        # sensitive + offloadable = all
        assert (len(p.sensitive_indices) + len(p.offloadable_indices)
                == p.n_samples)


def test_pools_conservation_and_sensitivity(ds):
    parts = partition(ds, n_devices=5, alpha=0.6, seed=0)
    pools = FederatedPools.from_partitions(parts, n_air=2)
    total0 = pools.total()
    sens0 = [len(s) for s in pools.ground_sensitive]
    moved = pools.move_ground_to_air(0, 1, 50)
    assert moved <= len(parts[0].offloadable_indices)
    pools.move_air_to_sat(1, 20)
    pools.move_sat_to_air(0, 10)
    pools.move_air_to_ground(0, 2, 5)
    assert pools.total() == total0
    # sensitive pools never move
    assert [len(s) for s in pools.ground_sensitive] == sens0


def test_pools_clip_to_available(ds):
    parts = partition(ds, n_devices=3, alpha=0.5, seed=0)
    pools = FederatedPools.from_partitions(parts, n_air=1)
    avail = len(pools.ground[0])
    moved = pools.move_ground_to_air(0, 0, avail + 1000)
    assert moved == avail
    assert len(pools.ground[0]) == 0
