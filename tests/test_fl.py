"""Integration tests for the FL layer: local training, aggregation, rounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import (FLConfig, aggregation_weights, fedavg, fedavg_stacked,
                      run_fl)
from repro.fl.client import evaluate, local_update
from repro.models.cnn import build_model


def test_fedavg_weights_sum_to_one():
    w = aggregation_weights([10, 20], [5], 15)
    assert float(jnp.sum(w)) == pytest.approx(1.0)
    assert w.shape == (4,)


def test_fedavg_identity():
    """Averaging identical models returns the same model."""
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    out = fedavg([params, params, params], [1.0, 2.0, 3.0])
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_fedavg_stacked_matches_list():
    params, _ = build_model("fmnist", jax.random.PRNGKey(0))
    models = []
    for i in range(3):
        key = jax.random.PRNGKey(i + 1)
        models.append(jax.tree_util.tree_map(
            lambda x: x + 0.01 * jax.random.normal(key, x.shape), params))
    w = jnp.asarray([0.2, 0.3, 0.5])
    ref = fedavg(models, list(np.asarray(w)))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *models)
    out = fedavg_stacked(stacked, w)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_local_update_reduces_loss():
    params, apply_fn = build_model("mnist", jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    from repro.data import make_dataset
    ds = make_dataset("mnist", train_fraction=0.01)
    xs = jnp.asarray(ds.x_train[:160].reshape(5, 32, 28, 28, 1))
    ys = jnp.asarray(ds.y_train[:160].reshape(5, 32))
    l0, _ = evaluate(apply_fn, params, xs.reshape(-1, 28, 28, 1),
                     ys.reshape(-1))
    new_params, _ = local_update(apply_fn, params, xs, ys, 0.05)
    l1, _ = evaluate(apply_fn, new_params, xs.reshape(-1, 28, 28, 1),
                     ys.reshape(-1))
    assert float(l1) < float(l0)


@pytest.mark.slow
def test_run_fl_end_to_end_accuracy_improves():
    cfg = FLConfig(dataset="mnist", n_rounds=6, train_fraction=0.02,
                   n_devices=8, n_air=2, h_local=3, eval_size=256, seed=0)
    res = run_fl(cfg)
    assert len(res.accuracies) == 6
    assert res.accuracies[-1] > res.accuracies[0]
    assert all(np.isfinite(res.losses))
    # training time strictly increases
    assert all(b > a for a, b in zip(res.times, res.times[1:]))
    # privacy: ground layer keeps at least the sensitive share
    assert res.layer_portions[-1]["ground"] >= 0.2 - 0.02


@pytest.mark.slow
def test_adaptive_beats_no_offloading_in_time_to_loss():
    common = dict(dataset="mnist", n_rounds=5, train_fraction=0.02,
                  n_devices=8, n_air=2, h_local=3, eval_size=256, seed=1)
    adaptive = run_fl(FLConfig(strategy="adaptive", **common))
    none = run_fl(FLConfig(strategy="none", **common))
    # per-round latency with offloading must be lower
    assert np.mean(adaptive.latencies) < np.mean(none.latencies)
