"""Resilience subsystem tests (PR 9): deterministic fault plans, the
recovery paths (unplanned handover, partition-tolerant merge, NaN
quarantine), engine checkpoint/resume bit-identity, and the chaos
scenario preset end to end."""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.checkpoint import restore_engine, save_engine
from repro.core.handover import replan_after_loss, space_schedule
from repro.core.network import build_default_sagin
from repro.fl import FLConfig
from repro.fl.federation import (FederationConfig, FederationState,
                                 RegionFedState, get_policy,
                                 plan_under_partition)
from repro.obs import ObsConfig, load_jsonl
from repro.obs.report import analyze
from repro.resilience import (DEFAULT_SEVERITY, FAULT_KINDS, FaultInjector,
                              FaultPlan, FaultSpec)
from repro.scenarios import SCENARIOS, Scenario, register
from repro.sim import DynamicsConfig, Region, SAGINEngine

RESUME_SCN = Scenario(
    name="_resume", description="checkpoint/resume fixture",
    regions=(Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8)),
    n_devices=5, n_air=1,
    dynamics=DynamicsConfig(isl_markov=(0.3, 0.5), uplink_markov=(0.2, 0.6),
                            churn_prob=0.1, weather_std=0.1),
    federation=FederationConfig(policy="synchronous", every=2,
                                half_life=3600.0),
    horizon=12 * 3600.0)


@pytest.fixture
def resume_scenario():
    register(RESUME_SCN)
    try:
        yield RESUME_SCN
    finally:
        SCENARIOS.pop(RESUME_SCN.name, None)


def tiny_cfg(**overrides):
    kw = dict(n_devices=5, n_air=1, train_fraction=0.005, eval_size=32,
              execution="sequential", seed=3)
    kw.update(overrides)
    return FLConfig(**kw)


def assert_same_trajectory(a: SAGINEngine, b: SAGINEngine):
    assert set(a.fl_results) == set(b.fl_results)
    for name in a.fl_results:
        ra, rb = a.fl_results[name], b.fl_results[name]
        assert ra.times == rb.times
        assert ra.accuracies == rb.accuracies
        # repr-compare: NaN loss sentinels must match positionally too
        assert [repr(x) for x in ra.losses] == [repr(x) for x in rb.losses]
        assert ra.latencies == rb.latencies
        assert ra.cases == rb.cases
        assert ra.participated == rb.participated
    assert a.merges == b.merges
    if a.global_params is None:
        assert b.global_params is None
    else:
        for x, y in zip(jax.tree_util.tree_leaves(a.global_params),
                        jax.tree_util.tree_leaves(b.global_params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec ------------------------------------------------------
# ---------------------------------------------------------------------------
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="gamma_ray", round=0, region=0)
    with pytest.raises(ValueError, match="round"):
        FaultSpec(kind="sat_loss", round=-1, region=0)
    with pytest.raises(ValueError, match="severity"):
        FaultSpec(kind="straggler", round=0, region=0, severity=0.0)


def test_fault_plan_generate_is_deterministic():
    kw = dict(n_rounds=8, n_regions=3,
              rates={"sat_loss": 0.3, "nan_update": 0.3})
    a = FaultPlan.generate(seed=11, **kw)
    b = FaultPlan.generate(seed=11, **kw)
    c = FaultPlan.generate(seed=12, **kw)
    assert a == b
    assert a != c
    assert len(a) > 0
    assert all(s.severity == DEFAULT_SEVERITY[s.kind] for s in a.faults)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.generate(seed=0, n_rounds=2, n_regions=2,
                           rates={"meteor": 1.0})


def test_fault_plan_addressing():
    plan = FaultPlan(faults=(
        FaultSpec("sat_loss", round=1, region=0),
        FaultSpec("straggler", round=1, region=0, severity=2.0),
        FaultSpec("isl_partition", round=1, region=0),
        FaultSpec("isl_partition", round=2, region=1),
    ))
    # in-round lookup excludes merge-boundary partitions
    assert [s.kind for s in plan.at(1, 0)] == ["sat_loss", "straggler"]
    assert plan.at(0, 0) == ()
    assert plan.partitioned_regions(1) == (0,)
    assert plan.partitioned_regions(2) == (1,)
    assert plan.partitioned_regions(3) == ()


def test_fault_injector_counters_and_state_roundtrip():
    inj = FaultInjector(FaultPlan())
    inj.record_injected("sat_loss", loss_time=10.0)
    inj.record_injected("nan_update")
    inj.record_recovered("sat_loss", delta_s=3.0)
    with pytest.raises(ValueError, match="unknown fault kind"):
        inj.record_injected("meteor")
    other = FaultInjector(FaultPlan())
    other.load_state_dict(inj.state_dict())
    assert other.injected == inj.injected
    assert other.recovered == inj.recovered
    assert other.injected["sat_loss"] == 1
    assert other.recovered["nan_update"] == 0


# ---------------------------------------------------------------------------
# recovery path: unplanned handover ------------------------------------------
# ---------------------------------------------------------------------------
def test_replan_after_loss_beats_restart():
    sagin = build_default_sagin(n_devices=8, n_air=2, seed=0)
    n = max(2000.0, float(sagin.n_sat_samples) or 2000.0)
    schedule = space_schedule(n, sagin)
    recovered, restart = replan_after_loss(
        schedule, 0.5 * schedule.total_latency, sagin)
    # handing the unprocessed remainder to the successor keeps the work
    # already done; restarting from scratch repeats it
    assert recovered.total_latency < restart
    assert recovered.total_latency >= 0.5 * schedule.total_latency


def test_replan_after_loss_completes_and_respects_loss_time():
    sagin = build_default_sagin(n_devices=8, n_air=2, seed=0)
    n = max(2000.0, float(sagin.n_sat_samples) or 2000.0)
    schedule = space_schedule(n, sagin)
    for frac in (0.2, 0.5, 0.8):
        recovered, restart = replan_after_loss(
            schedule, frac * schedule.total_latency, sagin)
        # the recovery finishes the work, never rewinds the clock below
        # the loss instant, and always beats restarting from scratch
        assert recovered.completed
        assert recovered.total_latency >= frac * schedule.total_latency
        assert recovered.total_latency < restart


# ---------------------------------------------------------------------------
# recovery path: merge under ISL partition -----------------------------------
# ---------------------------------------------------------------------------
def fed_state(n=3, policy="synchronous", quorum=0.5):
    cfg = FederationConfig(policy=policy, every=1, quorum=quorum,
                           half_life=3600.0)
    regions = tuple(RegionFedState(
        index=i, name=f"r{i}", wall_clock=100.0 * (i + 1),
        data_mass=1000.0, model_bits=32e6, z_isl=3.125e6,
        isl_scale=1.0, rounds_done=2) for i in range(n))
    return cfg, FederationState(config=cfg, regions=regions,
                                barrier_round=2, trigger=None)


def test_partition_synchronous_backs_off_then_degrades_to_partial():
    cfg, state = fed_state(policy="synchronous")
    plan, delay = plan_under_partition(get_policy(cfg), state, (1,))
    assert plan is not None
    assert plan.policy == "partial"
    assert 1 not in plan.participants
    # capped exponential backoff: 5 + 10 + 20 simulated seconds
    assert delay == pytest.approx(35.0)
    # the retry budget is folded into the merge instant
    assert plan.time >= max(r.wall_clock for r in state.regions
                            if r.index != 1) + delay - 1e-9


def test_partition_backoff_is_capped():
    cfg, state = fed_state(policy="synchronous")
    _, delay = plan_under_partition(get_policy(cfg), state, (1,),
                                    max_retries=6, backoff_base=5.0,
                                    backoff_cap=60.0)
    assert delay == pytest.approx(5 + 10 + 20 + 40 + 60 + 60)


def test_partition_tolerant_policy_pays_nothing():
    cfg, state = fed_state(policy="partial")
    plan, delay = plan_under_partition(get_policy(cfg), state, (2,))
    assert delay == 0.0
    assert plan is not None and 2 not in plan.participants


def test_partition_quorum_collapse_returns_none():
    cfg, state = fed_state(policy="synchronous")
    plan, delay = plan_under_partition(get_policy(cfg), state, (0, 1, 2))
    assert plan is None
    assert delay > 0.0


# ---------------------------------------------------------------------------
# engine checkpoint/resume ---------------------------------------------------
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("obs_on", [False, True], ids=["obs_off", "obs_on"])
def test_resume_is_bit_identical(resume_scenario, tmp_path, obs_on):
    """run(6) == run(3, final_merge=False) + checkpoint + resume + run(3),
    with obs off and on (tracing must never perturb the trajectory)."""
    def cfg(tag):
        obs = (ObsConfig(path=str(tmp_path / f"{tag}.jsonl"))
               if obs_on else None)
        return tiny_cfg(obs=obs)

    full = SAGINEngine(resume_scenario, fl=cfg("full"))
    full.run(6)

    seg = SAGINEngine(resume_scenario, fl=cfg("seg"))
    seg.run(3, final_merge=False)
    ckpt = str(tmp_path / "ckpt")
    save_engine(seg, ckpt)

    res = SAGINEngine(resume_scenario, fl=cfg("res"))
    restore_engine(res, ckpt)
    res.run(3)

    assert_same_trajectory(full, res)
    # synchronous every=2 over 6 rounds: merges key on the GLOBAL round
    assert [m.barrier_round for m in full.merges] == [2, 4, 6]


def test_resume_restores_markov_burst_state(resume_scenario, tmp_path):
    """The Gilbert-Elliott chain states survive the checkpoint exactly
    (bit-identical continuation is proven by the parametrized test
    above; this pins the mechanism)."""
    seg = SAGINEngine(resume_scenario, fl=tiny_cfg())
    seg.run(3, final_merge=False)
    save_engine(seg, str(tmp_path / "c"))
    res = SAGINEngine(resume_scenario, fl=tiny_cfg())
    restore_engine(res, str(tmp_path / "c"))
    for t_seg, t_res in zip(seg.trainers, res.trainers):
        mid = t_seg.orch.dynamics.state_dict()
        assert t_res.orch.dynamics.state_dict() == mid
        # mid-run state, not a fresh construction's
        fresh = type(t_res.orch.dynamics)(t_res.orch.dynamics.config,
                                          seed=0)
        assert mid["rng"] != fresh.state_dict()["rng"]


def test_restore_engine_validates_manifest(resume_scenario, tmp_path):
    eng = SAGINEngine(resume_scenario, fl=tiny_cfg())
    eng.run(2, final_merge=False)
    ckpt = str(tmp_path / "ckpt")
    save_engine(eng, ckpt)

    with pytest.raises(ValueError, match="manifest.json missing"):
        restore_engine(SAGINEngine(resume_scenario, fl=tiny_cfg()),
                       str(tmp_path / "nowhere"))

    other = dataclasses.replace(resume_scenario, name="_resume_other")
    register(other)
    try:
        with pytest.raises(ValueError, match="scenario"):
            restore_engine(SAGINEngine(other, fl=tiny_cfg()), ckpt)
    finally:
        SCENARIOS.pop(other.name, None)


def test_save_engine_rejects_non_fl_engine(resume_scenario):
    eng = SAGINEngine(resume_scenario)     # trace mode, no trainers
    with pytest.raises(ValueError, match="no region trainers"):
        save_engine(eng, "/tmp/_unused_ckpt_dir")


# ---------------------------------------------------------------------------
# chaos preset end to end ----------------------------------------------------
# ---------------------------------------------------------------------------
def test_chaos_preset_runs_to_finite_model_with_all_faults(tmp_path):
    trace = str(tmp_path / "chaos.jsonl")
    cfg = tiny_cfg(n_devices=12, n_air=2, train_fraction=0.01,
                   eval_size=64, seed=0, obs=ObsConfig(path=trace))
    engine = SAGINEngine("chaos", fl=cfg)
    engine.run(6)

    assert engine.global_params is not None
    for leaf in jax.tree_util.tree_leaves(engine.global_params):
        assert bool(np.all(np.isfinite(np.asarray(leaf))))

    inj = engine.fault_injector
    assert inj is not None
    # the handcrafted chaos plan exercises every fault kind in 6 rounds
    assert all(inj.injected[k] > 0 for k in FAULT_KINDS)
    # in-round faults are always absorbed; partition recovery may
    # legitimately fail when the quorum collapses
    for k in ("sat_loss", "straggler", "nan_update", "trainer_crash"):
        assert inj.recovered[k] >= inj.injected[k]
    # corrupted client updates were quarantined, and per-region curves
    # stayed on track (losses finite whenever the region trained)
    assert inj.recovered["nan_update"] > 0
    for res in engine.fl_results.values():
        for loss, part in zip(res.losses, res.participated):
            assert not part or math.isfinite(loss)

    engine.tracer.flush()
    report = analyze(load_jsonl(trace))
    assert report.faults and report.recoveries
    assert sum(report.faults.values()) == sum(inj.injected.values())
    assert sum(report.recoveries.values()) == sum(inj.recovered.values())
    assert report.quarantined > 0


def test_chaos_is_reproducible():
    def final_accs():
        engine = SAGINEngine("chaos", fl=tiny_cfg(
            n_devices=12, n_air=2, train_fraction=0.01, eval_size=64,
            seed=0))
        engine.run(3)
        return {n: r.accuracies for n, r in engine.fl_results.items()}
    assert final_accs() == final_accs()


def test_clean_scenario_has_no_injector_and_zero_overhead_path():
    eng = SAGINEngine("paper", fl=tiny_cfg(n_rounds=1))
    assert eng.fault_injector is None
    assert all(t.faults is None for t in eng.trainers)
