"""Federation-policy API tests: registry, the four built-in policies,
deprecation shims, FLResult.participated, and staleness-weight edge
cases (PR 5)."""
import dataclasses
import math
import warnings

import jax
import numpy as np
import pytest

from repro.core.latency import (global_merge_latency, isl_merge_hops,
                                isl_path_hops, tx_time)
from repro.fl import FLConfig, fedavg, run_fl, staleness_merge_weights
from repro.fl.federation import (FederationConfig, FederationState,
                                 MergePolicy, RegionFedState, get_policy,
                                 list_policies, register_policy,
                                 resolve_federation)
from repro.models.cnn import build_model
from repro.scenarios import SCENARIOS, Scenario, get_scenario, register
from repro.sim import DynamicsConfig, Region, SAGINEngine

TINY = dict(dataset="mnist", n_rounds=2, n_devices=4, n_air=1, h_local=2,
            train_fraction=0.005, eval_size=64, seed=0)

REGIONS3 = (Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8),
            Region("reykjavik", 64.1, -21.9))


def tiny_cfg(**overrides):
    kw = dict(TINY)
    kw.update(overrides)
    return FLConfig(**kw)


def make_state(masses, clocks, isl_scales=None, config=None, trigger=None,
               model_bits=32e6, z_isl=3.125e6):
    n = len(masses)
    isl_scales = isl_scales if isl_scales is not None else [1.0] * n
    regions = tuple(RegionFedState(
        index=i, name=f"r{i}", wall_clock=float(clocks[i]),
        data_mass=float(masses[i]), model_bits=model_bits, z_isl=z_isl,
        isl_scale=float(isl_scales[i]), rounds_done=1) for i in range(n))
    cfg = config if config is not None else FederationConfig(every=1)
    return FederationState(config=cfg, regions=regions, barrier_round=1,
                           trigger=trigger)


def scenario3(fed, dynamics=None, name="_fed3"):
    return Scenario(name=name, description="federation test",
                    regions=REGIONS3, n_devices=4, n_air=1,
                    federation=fed, dynamics=dynamics, horizon=6 * 3600.0)


# ---------------------------------------------------------------------------
# Registry + config validation ----------------------------------------------
# ---------------------------------------------------------------------------
def test_registry_has_the_four_builtins():
    assert {"synchronous", "soft_async", "partial",
            "elected_hub"} <= set(list_policies())


def test_get_policy_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown federation policy"):
        get_policy(FederationConfig(policy="gossip"))


def test_register_policy_rejects_duplicates_and_anonymous():
    with pytest.raises(ValueError, match="already registered"):
        @register_policy
        class Dup(MergePolicy):  # noqa: F811
            name = "synchronous"
    with pytest.raises(ValueError, match="non-empty name"):
        @register_policy
        class Anon(MergePolicy):
            pass


def test_federation_config_validation():
    with pytest.raises(ValueError, match="every"):
        FederationConfig(every=0)
    with pytest.raises(ValueError, match="topology"):
        FederationConfig(topology="mesh")
    with pytest.raises(ValueError, match="quorum"):
        FederationConfig(quorum=0.0)
    with pytest.raises(ValueError, match="elect_by"):
        FederationConfig(elect_by="alphabetical")


def test_resolve_federation_precedence():
    scn = scenario3(FederationConfig(policy="synchronous", every=2,
                                     half_life=60.0))
    # FLConfig None -> scenario's config
    assert resolve_federation(None, scn) is scn.federation
    # bare string swaps the policy, keeps the scenario knobs
    fed = resolve_federation("soft_async", scn)
    assert fed.policy == "soft_async" and fed.every == 2
    assert fed.half_life == 60.0
    # full config replaces wholesale
    mine = FederationConfig(policy="partial", every=5)
    assert resolve_federation(mine, scn) is mine
    with pytest.raises(TypeError, match="federation"):
        resolve_federation(3.14, scn)


# ---------------------------------------------------------------------------
# Policy planning -----------------------------------------------------------
# ---------------------------------------------------------------------------
def test_synchronous_plan_matches_legacy_barrier_semantics():
    cfg = FederationConfig(policy="synchronous", every=1, topology="ring",
                           half_life=600.0)
    state = make_state([100, 300, 100], [10.0, 40.0, 25.0], config=cfg)
    plan = get_policy(cfg).plan(state)
    assert plan.participants == (0, 1, 2) == plan.recipients
    assert plan.hub == 0
    assert plan.time == 40.0
    assert plan.staleness == (30.0, 0.0, 15.0)
    np.testing.assert_allclose(
        plan.weights, staleness_merge_weights([100, 300, 100],
                                              [30.0, 0.0, 15.0], 600.0))
    expected = tuple(global_merge_latency(32e6, 3.125e6, "ring", i, 3)
                     for i in range(3))
    assert plan.isl_costs == expected


def test_partial_plan_excludes_dead_isl_regions_and_renormalizes():
    cfg = FederationConfig(policy="partial", every=1, topology="ring",
                           quorum=0.5)
    state = make_state([100, 300, 100], [10.0, 40.0, 25.0],
                       isl_scales=[1.0, 0.25, 1.0], config=cfg)
    plan = get_policy(cfg).plan(state)
    assert plan.participants == (0, 2) == plan.recipients
    assert plan.hub == 0
    assert plan.time == 25.0               # max over PARTICIPANTS only
    assert plan.staleness == (15.0, 0.0)
    np.testing.assert_allclose(plan.weights, [0.5, 0.5])  # renormalized
    assert sum(plan.weights) == pytest.approx(1.0)


def test_partial_plan_hub_falls_back_to_lowest_live_region():
    cfg = FederationConfig(policy="partial", every=1, quorum=0.5)
    state = make_state([1, 1, 1], [0.0, 0.0, 0.0],
                       isl_scales=[0.25, 1.0, 1.0], config=cfg)
    plan = get_policy(cfg).plan(state)
    assert plan.hub == 1
    assert plan.participants == (1, 2)
    assert plan.isl_costs[0] == 0.0        # hub pays nothing


def test_partial_plan_skips_below_quorum():
    cfg = FederationConfig(policy="partial", every=1, quorum=0.75)
    state = make_state([1, 1, 1, 1], [0.0] * 4,
                       isl_scales=[1.0, 1.0, 0.25, 0.25], config=cfg)
    assert get_policy(cfg).plan(state) is None


def test_soft_async_plan_is_trigger_only_with_clamped_staleness():
    cfg = FederationConfig(policy="soft_async", every=1, topology="ring",
                           half_life=600.0)
    # trigger 1 at t=100; peer 0 behind (stale 60), peer 2 AHEAD (fresh)
    state = make_state([100, 100, 100], [40.0, 100.0, 130.0], config=cfg,
                       trigger=1)
    plan = get_policy(cfg).plan(state)
    assert plan.participants == (0, 1, 2)
    assert plan.recipients == (1,)
    assert plan.hub == 1
    assert plan.time == 100.0
    assert plan.staleness == (60.0, 0.0, 0.0)  # ahead-of-clock clamps to 0
    # toll: slowest parallel one-way fetch over the ring
    fetch = max(isl_path_hops("ring", j, 1, 3) * tx_time(32e6, 3.125e6)
                for j in (0, 2))
    assert plan.isl_costs == (fetch,)


def test_soft_async_plan_none_without_live_peers():
    cfg = FederationConfig(policy="soft_async", every=1)
    state = make_state([1, 1], [0.0, 0.0], isl_scales=[1.0, 0.25],
                       config=cfg, trigger=0)
    assert get_policy(cfg).plan(state) is None
    # trigger's own ISL down: keep training, no merge
    state = make_state([1, 1], [0.0, 0.0], isl_scales=[0.25, 1.0],
                       config=cfg, trigger=0)
    assert get_policy(cfg).plan(state) is None
    with pytest.raises(ValueError, match="trigger"):
        get_policy(cfg).plan(make_state([1, 1], [0.0, 0.0], config=cfg))


def test_elected_hub_by_data_mass_moves_the_toll():
    cfg = FederationConfig(policy="elected_hub", every=1, topology="star",
                           elect_by="data_mass")
    state = make_state([100, 500, 100], [0.0, 0.0, 0.0], config=cfg)
    plan = get_policy(cfg).plan(state)
    assert plan.hub == 1
    assert plan.isl_costs[1] == 0.0        # elected hub pays nothing
    assert plan.isl_costs[0] > 0 and plan.isl_costs[2] > 0
    assert plan.participants == (0, 1, 2) == plan.recipients


def test_elected_hub_by_centrality_prefers_connected_regions():
    cfg = FederationConfig(policy="elected_hub", every=1,
                           elect_by="centrality")
    # region 0 has the most data but its ISL is degraded; 1 and 2 tie on
    # degree, 2 holds more data
    state = make_state([900, 100, 200], [0.0, 0.0, 0.0],
                       isl_scales=[0.25, 1.0, 1.0], config=cfg)
    plan = get_policy(cfg).plan(state)
    assert plan.hub == 2


def test_isl_path_hops_primitive():
    assert isl_path_hops("ring", 0, 0, 4) == 0
    assert [isl_path_hops("ring", 0, j, 4) for j in range(4)] == [0, 1, 2, 1]
    assert isl_path_hops("star", 0, 3, 4) == 1
    assert isl_merge_hops("ring", 3, 4, hub=1) == \
        2 * isl_path_hops("ring", 3, 1, 4)
    with pytest.raises(ValueError, match="out of range"):
        isl_path_hops("ring", 4, 0, 4)
    with pytest.raises(ValueError, match="topology"):
        isl_path_hops("mesh", 0, 1, 4)


def test_apply_matches_fedavg_and_identity():
    cfg = FederationConfig(policy="synchronous", every=1)
    policy = get_policy(cfg)
    state = make_state([100, 300], [0.0, 0.0], config=cfg)
    plan = policy.plan(state)
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    models = [jax.tree_util.tree_map(lambda x, i=i: x + 0.01 * (i + 1),
                                     params) for i in range(2)]
    merged = policy.apply(models, plan)
    ref = fedavg(models, list(plan.weights))
    for a, b in zip(jax.tree_util.tree_leaves(merged),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    solo = dataclasses.replace(plan, participants=(0,), weights=(1.0,),
                               staleness=(0.0,))
    assert policy.apply([params], solo) is params
    with pytest.raises(ValueError, match="participants"):
        policy.apply([params], plan)


# ---------------------------------------------------------------------------
# Engine integration --------------------------------------------------------
# ---------------------------------------------------------------------------
def test_engine_soft_async_merges_do_not_touch_peers():
    scn = scenario3(FederationConfig(policy="soft_async", every=1,
                                     half_life=600.0))
    eng = SAGINEngine(scn, fl=tiny_cfg())
    eng.run(2)
    assert eng.merges, "healthy ISLs must yield soft merges"
    for m in eng.merges:
        assert m.policy == "soft_async"
        assert len(m.recipients) == 1
        assert m.hub == m.recipients[0]
        # non-recipients carry no toll and no accuracy evaluation
        for j in range(3):
            if j not in m.recipients:
                assert m.isl_costs[j] == 0.0
                assert math.isnan(m.accuracies[j])
    assert eng.global_params is not None


def test_engine_partial_skips_and_shields_disconnected_regions():
    dyn = DynamicsConfig(isl_outage_prob=0.5)
    scn = scenario3(FederationConfig(policy="partial", every=1,
                                     quorum=0.5), dynamics=dyn)
    eng = SAGINEngine(scn, fl=tiny_cfg())
    eng.run(2)
    sync = scenario3(FederationConfig(policy="synchronous", every=1),
                     dynamics=dyn, name="_fed3s")
    eng_sync = SAGINEngine(sync, fl=tiny_cfg())
    eng_sync.run(2)
    # same dynamics streams: with outage_prob=0.5 some barrier saw a
    # degraded region, so partial merged fewer region-slots overall
    assert (sum(len(m.participants) for m in eng.merges)
            < sum(len(m.participants) for m in eng_sync.merges))
    for m in eng.merges:
        assert m.policy == "partial"
        assert set(m.recipients) == set(m.participants)
        assert sum(m.weights) == pytest.approx(1.0)
        for j in range(3):
            if j not in m.participants:
                assert m.weights[j] == 0.0 and m.isl_costs[j] == 0.0
    # a region that sat a merge out was never dragged to the barrier:
    # its clock can only be its own training time
    for i, trace in enumerate(eng.traces):
        if all(i not in m.participants for m in eng.merges):
            assert eng.trainers[i].wall_clock == pytest.approx(
                sum(r.realized_latency for r in trace.records))


def test_engine_federation_none_means_independent():
    scn = scenario3(None)
    eng = SAGINEngine(scn, fl=tiny_cfg())
    eng.run(2)
    assert eng.merges == [] and eng.global_params is None


def test_flconfig_federation_overrides_scenario_policy():
    scn = scenario3(FederationConfig(policy="synchronous", every=1,
                                     half_life=600.0))
    eng = SAGINEngine(scn, fl=tiny_cfg(federation="soft_async"))
    assert eng.federation.policy == "soft_async"
    assert eng.federation.every == 1       # cadence kept from scenario
    eng.run(1)
    assert all(m.policy == "soft_async" for m in eng.merges)


def test_engine_federation_runs_are_deterministic():
    scn = scenario3(FederationConfig(policy="soft_async", every=1,
                                     half_life=600.0),
                    dynamics=DynamicsConfig(isl_outage_prob=0.3))
    a = SAGINEngine(scn, fl=tiny_cfg())
    a.run(2)
    b = SAGINEngine(scn, fl=tiny_cfg())
    b.run(2)
    assert a.step_order == b.step_order
    assert [m.participants for m in a.merges] == [m.participants
                                                  for m in b.merges]
    assert [m.weights for m in a.merges] == [m.weights for m in b.merges]


# ---------------------------------------------------------------------------
# Deprecation shims ---------------------------------------------------------
# ---------------------------------------------------------------------------
def test_legacy_merge_kwargs_map_to_synchronous_federation():
    with pytest.warns(DeprecationWarning, match="deprecated") as rec:
        scn = Scenario(name="_legacy", description="x", regions=REGIONS3,
                       merge_every=3, merge_topology="star",
                       merge_half_life=120.0)
    assert len(rec) == 1
    fed = scn.resolved_federation()
    assert fed == FederationConfig(policy="synchronous", every=3,
                                   topology="star", half_life=120.0)


def test_federation_wins_over_legacy_fields_without_warning():
    """replace()ing federation onto a legacy scenario must work (the
    migration path itself): federation= wins outright, no warning."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = Scenario(name="_legacyR", description="x",
                          regions=REGIONS3, merge_every=2)
    fed = FederationConfig(policy="soft_async", every=5)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        migrated = dataclasses.replace(legacy, federation=fed)
    assert migrated.resolved_federation() is fed


def test_disabling_merges_on_a_legacy_scenario_nulls_both_spellings():
    """federation=None alone cannot disable a legacy scenario's merges —
    resolved_federation() re-synthesizes from merge_every — so callers
    (example/benchmark --merge-every 0) must null both fields."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = Scenario(name="_legacyD", description="x",
                          regions=REGIONS3, merge_every=2)
        # replace() re-runs __post_init__, hence re-warns while the
        # legacy field is still set — expected shim behavior
        still_legacy = dataclasses.replace(legacy, federation=None)
    assert still_legacy.resolved_federation() is not None
    assert dataclasses.replace(
        legacy, federation=None,
        merge_every=None).resolved_federation() is None


def test_policy_name_without_any_cadence_is_an_error():
    """A bare policy name that would silently never merge must raise."""
    scn = scenario3(None)  # no federation, no legacy cadence
    with pytest.raises(ValueError, match="cadence"):
        SAGINEngine(scn, fl=tiny_cfg(federation="soft_async"))
    # a FULL config with every=None stays a legal explicit disable
    eng = SAGINEngine(scn, fl=tiny_cfg(
        federation=FederationConfig(policy="soft_async")))
    eng.run(1)
    assert eng.merges == [] and eng.global_params is None


def test_legacy_kwargs_trajectory_identical_to_federation_config():
    kw = dict(description="x", regions=REGIONS3[:2], n_devices=4, n_air=1,
              horizon=6 * 3600.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = Scenario(name="_shimL", merge_every=1,
                          merge_topology="star", merge_half_life=600.0,
                          **kw)
    modern = Scenario(name="_shimM",
                      federation=FederationConfig(policy="synchronous",
                                                  every=1, topology="star",
                                                  half_life=600.0), **kw)
    a = SAGINEngine(legacy, fl=tiny_cfg())
    a.run(2)
    b = SAGINEngine(modern, fl=tiny_cfg())
    b.run(2)
    for ra, rb in zip(a.fl_results.values(), b.fl_results.values()):
        assert ra.accuracies == rb.accuracies
        assert ra.times == rb.times
    assert [m.weights for m in a.merges] == [m.weights for m in b.merges]
    for x, y in zip(jax.tree_util.tree_leaves(a.global_params),
                    jax.tree_util.tree_leaves(b.global_params)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# FLResult.participated -----------------------------------------------------
# ---------------------------------------------------------------------------
def test_participated_mask_tracks_training_rounds():
    scn = Scenario(name="_churn_all", description="x",
                   dynamics=DynamicsConfig(churn_prob=1.0))
    register(scn)
    try:
        res = run_fl(tiny_cfg(scenario="_churn_all", n_rounds=1))
    finally:
        SCENARIOS.pop("_churn_all", None)
    assert res.participated == [False]
    assert math.isnan(res.losses[0])       # NaN sentinel kept (documented)
    ok = run_fl(tiny_cfg(scenario="paper", n_rounds=2))
    assert ok.participated == [True, True]
    assert all(np.isfinite(ok.losses))


# ---------------------------------------------------------------------------
# staleness_merge_weights edge cases ----------------------------------------
# ---------------------------------------------------------------------------
def test_half_life_zero_is_a_hard_cutoff():
    w = staleness_merge_weights([100, 300, 100], [0.0, 0.0, 5.0],
                                half_life=0.0)
    np.testing.assert_allclose(w, [0.25, 0.75, 0.0])
    assert w.sum() == pytest.approx(1.0)


def test_all_stale_renormalizes_over_the_freshest():
    # deep underflow: every exp2 weight hits 0.0 — must renormalize to
    # the freshest model's data shares, never emit zeros/NaN
    w = staleness_merge_weights([100, 300], [1e9, 1e9 + 5.0],
                                half_life=1.0)
    np.testing.assert_allclose(w, [1.0, 0.0])
    w = staleness_merge_weights([100, 300], [1e9, 1e9], half_life=1.0)
    np.testing.assert_allclose(w, [0.25, 0.75])


def test_single_region_degenerate_merge_weight_is_one():
    w = staleness_merge_weights([42], [1e9], half_life=1.0)
    np.testing.assert_allclose(w, [1.0])
    from repro.fl import staleness_weighted_merge
    params, _ = build_model("mnist", jax.random.PRNGKey(0))
    merged, wts = staleness_weighted_merge([params], [42], [1e9],
                                           half_life=1.0,
                                           return_weights=True)
    assert merged is params
    np.testing.assert_allclose(wts, [1.0])


def test_freshest_with_zero_mass_falls_back_to_data_shares():
    w = staleness_merge_weights([0, 300], [0.0, 1e9], half_life=1.0)
    np.testing.assert_allclose(w, [0.0, 1.0])


def test_get_scenario_registry_untouched_by_federation_tests():
    assert get_scenario("degraded_links").resolved_federation() is None
