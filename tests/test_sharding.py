"""Sharding-spec tests + a miniature-mesh integration dry-run.

The mini dry-run runs in a SUBPROCESS with 8 host devices so the main test
process keeps its single-device backend (the dry-run contract).
"""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.train import abstract_params
from repro.sharding.specs import cache_pspecs, param_pspecs


@pytest.mark.parametrize("arch", ["qwen3-32b", "qwen3-moe-235b-a22b",
                                  "rwkv6-1.6b", "jamba-1.5-large-398b",
                                  "deepseek-v2-lite-16b"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    specs = param_pspecs(cfg, shapes)
    s_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    p_leaves = jax.tree_util.tree_leaves(shapes)
    assert len(s_leaves) == len(p_leaves)
    # the vast majority of weight bytes must actually be sharded
    sharded_bytes = total_bytes = 0
    for spec, leaf in zip(s_leaves, p_leaves):
        b = np.prod(leaf.shape) * leaf.dtype.itemsize
        total_bytes += b
        if any(ax is not None for ax in spec):
            sharded_bytes += b
    assert sharded_bytes / total_bytes > 0.95


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b"])
def test_param_specs_divisible_on_production_mesh(arch):
    """Every sharded dim must divide the (16,16) production mesh axes."""
    axis_size = {"data": 16, "model": 16, "pod": 2}
    cfg = get_config(arch)
    shapes = abstract_params(cfg)
    specs = param_pspecs(cfg, shapes)
    for spec, leaf in zip(
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves(shapes)):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([axis_size[a] for a in axes]))
            assert dim % n == 0, (arch, leaf.shape, tuple(spec))


def test_cache_specs_divisible():
    axis_size = {"data": 16, "model": 16, "pod": 2}
    from repro.launch.serve import abstract_cache
    for arch, shape_name in [("qwen3-32b", "decode_32k"),
                             ("rwkv6-1.6b", "long_500k"),
                             ("deepseek-v2-lite-16b", "long_500k"),
                             ("jamba-1.5-large-398b", "decode_32k")]:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        cache = abstract_cache(cfg, shape)
        specs = cache_pspecs(cfg, cache, shape, multi_pod=False)
        for spec, leaf in zip(
                jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)),
                jax.tree_util.tree_leaves(cache)):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([axis_size[a] for a in axes]))
                assert dim % n == 0, (arch, shape_name, leaf.shape,
                                      tuple(spec))


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    from repro.configs import get_config
    from repro.configs.shapes import InputShape, input_specs
    from repro.launch.train import make_sharded_train_step, abstract_params
    from repro.sharding.activations import activation_sharding

    cfg = dataclasses.replace(
        get_config("llama3.2-3b").reduced(n_layers=2, d_model=128),
        param_dtype="float32")
    shape = InputShape("mini", 128, 8, "train")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh, activation_sharding(mesh, ("data",)):
        step, _, _ = make_sharded_train_step(cfg, mesh, shape)
        lowered = step.lower(abstract_params(cfg), input_specs(cfg, shape))
        compiled = lowered.compile()
    txt = compiled.as_text()
    assert ("all-reduce" in txt) or ("all-gather" in txt)
    print("MINI_DRYRUN_OK")
""")


@pytest.mark.slow
def test_mini_mesh_dryrun_compiles():
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MINI_DRYRUN_OK" in r.stdout


def test_serve_step_donate_false_keeps_cache_readable():
    """`make_serve_step(donate=False)` must leave the caller's cache
    buffers alive: the serving gateway's TransformerBackend re-reads a
    cache it keeps by reference, so a silently donated buffer would
    poison the next dispatch of the same batch width."""
    import jax.numpy as jnp
    from repro.configs.shapes import InputShape
    from repro.launch.serve import make_serve_step
    from repro.models import transformer as T

    cfg = get_config("llama3.2-3b").reduced(n_layers=2, d_model=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = InputShape("donate_smoke", 8, 2, "decode")
    step, _ = make_serve_step(cfg, mesh, shape, donate=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, 2, 8)
    tokens = jnp.zeros((2, 1), jnp.int32)

    logits, new_cache = step(params, cache, tokens, 0)
    # every original cache leaf is still materializable (not donated)
    for leaf in jax.tree_util.tree_leaves(cache):
        np.asarray(leaf)
    # and replaying from the ORIGINAL cache reproduces the step exactly
    logits2, _ = step(params, cache, tokens, 0)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))
