"""Unit + property tests for the adaptive offloading optimizer (Alg. 1-2)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # degrades to skip when hypothesis is absent

from repro.core import build_default_sagin, optimize_offloading
from repro.core.latency import round_latency_no_offload
from repro.core.network import Satellite
from repro.core.offloading import algorithm1_literal, cluster_case1


def make_sagin(seed=0, **kw):
    return build_default_sagin(n_devices=kw.pop("n_devices", 10),
                               n_air=kw.pop("n_air", 2), seed=seed, **kw)


class TestOptimizer:
    def test_improves_on_baseline(self):
        sagin = make_sagin(seed=1)
        plan = optimize_offloading(sagin)
        assert plan.round_latency <= plan.baseline_latency + 1e-6

    def test_case2_when_ground_slow(self):
        # default setup: ground devices are 10x slower than air, satellites
        # idle -> data must flow upward (Case II)
        sagin = make_sagin(seed=2)
        plan = optimize_offloading(sagin)
        assert plan.case == 2
        assert plan.new_sat_samples > 0

    def test_case1_when_satellite_overloaded(self):
        sagin = make_sagin(seed=3)
        # dump everything on a slow satellite with tiny coverage
        total = sum(d.n_samples for d in sagin.devices)
        for d in sagin.devices:
            d.n_samples = d.n_sensitive = 100
        sagin.n_sat_samples = total
        sagin.satellites = [Satellite(0, f=1e9, coverage_end=50.0),
                            Satellite(1, f=1e9, coverage_end=100.0),
                            Satellite(2, f=1e9, coverage_end=np.inf)]
        plan = optimize_offloading(sagin)
        assert plan.case == 1
        assert plan.new_sat_samples < total
        assert plan.round_latency <= plan.baseline_latency + 1e-6

    def test_conservation(self):
        sagin = make_sagin(seed=4)
        total = sagin.total_samples
        plan = optimize_offloading(sagin)
        g, a, s = plan.new_sizes(sagin)
        assert abs(sum(g) + sum(a) + s - total) < 1.0

    def test_privacy_constraint(self):
        """Sensitive samples never leave their device (eq. 35 cap)."""
        sagin = make_sagin(seed=5, alpha=0.5)
        plan = optimize_offloading(sagin)
        g, _, _ = plan.new_sizes(sagin)
        for k, dev in enumerate(sagin.devices):
            assert g[k] >= dev.n_sensitive - 1e-6

    def test_alpha_zero_means_no_ground_offload(self):
        sagin = make_sagin(seed=6, alpha=0.0)
        plan = optimize_offloading(sagin)
        g, _, _ = plan.new_sizes(sagin)
        for k, dev in enumerate(sagin.devices):
            assert g[k] >= dev.n_samples - 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       alpha=st.floats(0.0, 1.0),
       sat_f=st.floats(1e9, 1e10))
def test_property_never_worse_and_conserving(seed, alpha, sat_f):
    sagin = build_default_sagin(n_devices=6, n_air=2, alpha=alpha,
                                sat_f_list=[sat_f, sat_f],
                                coverage_times=[200.0, 1e9], seed=seed)
    total = sagin.total_samples
    plan = optimize_offloading(sagin)
    # 1. adaptive is never worse than no offloading
    assert plan.round_latency <= plan.baseline_latency * (1 + 1e-6) + 1e-3
    # 2. conservation of samples
    g, a, s = plan.new_sizes(sagin)
    assert abs(sum(g) + sum(a) + s - total) < 1.0
    # 3. non-negativity
    assert s >= -1e-6 and all(x >= -1e-6 for x in a)
    # 4. privacy cap
    for k, dev in enumerate(sagin.devices):
        assert g[k] >= dev.n_sensitive - 1.0


def test_literal_algorithm1_matches_fast_path():
    """The pseudocode-faithful Algorithm 1 and the closed-form fast path
    must land on allocations with (near-)equal objective values."""
    sagin = make_sagin(seed=7)
    # put some data on the satellite/air so Case-I balancing is non-trivial
    sagin.air_nodes[0].n_samples = 2000
    d_s2a = 500.0
    from repro.core.offloading import evaluate_cluster, ClusterPlan
    fast = cluster_case1(sagin, 0, d_s2a)
    lit = algorithm1_literal(sagin, 0, d_s2a)
    lit_plan = ClusterPlan(n=0, d_space_air=d_s2a,
                           d_air_ground={k: v for k, v in lit.items()
                                         if v > 1e-3})
    t_fast = evaluate_cluster(sagin, fast)
    t_lit = evaluate_cluster(sagin, lit_plan)
    # same optimum within bisection tolerance (5%)
    assert t_fast <= t_lit * 1.05 + 1e-3


def test_literal_algorithm2_matches_fast_path():
    """The printed Algorithm 2 and the grid-based fast path must reach
    (near-)equal round latencies in Case I."""
    from repro.core.offloading import (ClusterPlan, OffloadPlan,
                                       algorithm2_literal, cluster_case1,
                                       evaluate_plan)
    from repro.core.handover import space_latency
    sagin = make_sagin(seed=11)
    # overload the satellite so Case I applies
    total = sum(d.n_samples for d in sagin.devices)
    for d in sagin.devices:
        d.n_samples = d.n_sensitive = 100
    sagin.n_sat_samples = total
    sagin.satellites = [Satellite(0, f=1e9, coverage_end=120.0),
                        Satellite(1, f=1e9, coverage_end=np.inf)]
    fast = optimize_offloading(sagin)
    assert fast.case == 1
    lit_alloc = algorithm2_literal(sagin)
    clusters = [cluster_case1(sagin, n, lit_alloc[n]) for n in sagin.clusters]
    lit = OffloadPlan(case=1, clusters=clusters,
                      new_sat_samples=sagin.n_sat_samples
                      - sum(lit_alloc.values()),
                      space_latency=0.0, round_latency=0.0,
                      baseline_latency=0.0)
    t_lit = evaluate_plan(sagin, lit)
    # fast path is no worse than the literal pseudocode (within 10%)
    assert fast.round_latency <= t_lit * 1.10 + 1e-3
