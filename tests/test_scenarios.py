"""Tests for the scenario registry and the multi-region engine: preset
integrity, seed-path equivalence, event-stepped determinism, and every
registry scenario end-to-end through run_fl."""
import numpy as np
import pytest

from repro.core import SAGINOrchestrator, WalkerStar, build_default_sagin
from repro.fl import FLConfig, run_fl
from repro.scenarios import (SCENARIOS, Scenario, get_scenario,
                             list_scenarios)
from repro.sim import Region, SAGINEngine, access_intervals_loop

TINY = dict(dataset="mnist", n_rounds=2, n_devices=4, n_air=1, h_local=1,
            train_fraction=0.005, eval_size=64, seed=0)


def test_registry_contents():
    names = list_scenarios()
    assert len(names) >= 5
    for required in ("paper", "mega_constellation", "multi_region",
                     "degraded_links", "device_churn"):
        assert required in names
        scn = get_scenario(required)
        assert scn.description
        scn.build_constellation()  # constructible
    assert get_scenario("mega_constellation").n_sats >= 1000
    assert len(get_scenario("multi_region").regions) >= 3
    assert get_scenario("degraded_links").dynamics.any_active()
    assert get_scenario("device_churn").dynamics.churn_prob > 0


def test_unknown_scenario_raises_with_listing():
    with pytest.raises(ValueError, match="mega_constellation"):
        get_scenario("does_not_exist")


def test_duplicate_registration_rejected():
    from repro.scenarios import register
    with pytest.raises(ValueError):
        register(Scenario(name="paper", description="dup"))


def test_indivisible_constellation_rejected():
    scn = Scenario(name="_bad", description="x", n_sats=81, n_planes=5)
    with pytest.raises(ValueError, match="divisible"):
        scn.build_constellation()


def test_paper_scenario_matches_seed_orchestrator():
    """Acceptance equivalence: the `paper` preset reproduces the seed
    orchestrator's (loop-propagated Walker-Star) round latencies."""
    scn = get_scenario("paper")
    region = scn.regions[0]
    intervals = scn.build_intervals()[region.name]
    seed_intervals = access_intervals_loop(
        WalkerStar(), region.lat_deg, region.lon_deg, t_end=scn.horizon,
        dt=scn.dt, min_elevation_deg=region.min_elevation_deg)

    def latencies(ivs):
        sagin = build_default_sagin(n_devices=6, n_air=2, seed=0)
        orch = SAGINOrchestrator(sagin, intervals=ivs,
                                 rng=np.random.default_rng(0))
        return [r.latency for r in orch.run(4)]

    np.testing.assert_allclose(latencies(intervals),
                               latencies(seed_intervals), rtol=1e-9)


def test_engine_event_stepped_order_and_determinism():
    eng = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    traces = eng.run(3)
    assert len(traces) == len(get_scenario("multi_region").regions)
    for trace in traces:
        assert len(trace.records) == 3
        assert trace.wall_clock == pytest.approx(
            sum(trace.realized_latencies))
    eng2 = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    for a, b in zip(traces, eng2.run(3)):
        assert a.realized_latencies == b.realized_latencies
    summary = eng.summary()
    assert set(summary) == {t.region.name for t in traces}


def test_engine_shares_one_constellation():
    eng = SAGINEngine("multi_region", seed=0, n_devices=4, n_air=1)
    assert eng.constellation.n_sats == 80
    assert set(eng.intervals) == {r.name
                                  for r in eng.scenario.regions}
    # per-region windows really differ (different geometry)
    starts = {name: tuple(iv.start for iv in ivs[:5])
              for name, ivs in eng.intervals.items()}
    assert len(set(starts.values())) > 1


def test_degraded_links_engine_realizes_overhead():
    eng = SAGINEngine("degraded_links", seed=2, n_devices=4, n_air=1)
    trace = eng.run(5)[0]
    assert any(r.realized_latency != r.latency for r in trace.records)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_every_scenario_runs_end_to_end_through_run_fl(name):
    res = run_fl(FLConfig(scenario=name, **TINY))
    assert len(res.accuracies) == TINY["n_rounds"]
    assert all(np.isfinite(res.latencies))
    assert all(lat > 0 for lat in res.latencies)
    # wall clock advances by realized latencies
    assert res.times[-1] == pytest.approx(sum(res.latencies))


def test_run_fl_paper_scenario_equals_constellation_path():
    a = run_fl(FLConfig(use_constellation=True, **TINY))
    b = run_fl(FLConfig(scenario="paper", **TINY))
    np.testing.assert_allclose(a.latencies, b.latencies, rtol=1e-9)
    np.testing.assert_allclose(a.accuracies, b.accuracies, rtol=1e-6)


def test_run_fl_region_index_out_of_range():
    with pytest.raises(ValueError, match="region_index"):
        run_fl(FLConfig(scenario="paper", region_index=3, **TINY))
