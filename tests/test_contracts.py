"""Runtime-contract layer: no_recompile, assert_donated, nan_tripwire,
assert_finite — positive (violation raises) and negative (clean passes)
for each."""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts


@pytest.fixture(scope="module")
def doubler():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(3))          # warm one shape
    return f


# ---------------------------------------------------------------------------
# no_recompile
# ---------------------------------------------------------------------------
def test_no_recompile_clean(doubler):
    with contracts.no_recompile() as rc:
        doubler(jnp.ones(3))
        doubler(jnp.ones(3))
    if not rc.enforced:
        pytest.skip("jax lowering counters unavailable")
    assert rc.count == 0


def test_no_recompile_violation_names_label(doubler):
    with contracts.no_recompile() as probe:
        pass
    if not probe.enforced:
        pytest.skip("jax lowering counters unavailable")
    with pytest.raises(contracts.ContractViolation, match="warm path"):
        with contracts.no_recompile(label="warm path"):
            doubler(jnp.ones(17))          # fresh shape -> lowering


def test_no_recompile_allow_budget(doubler):
    with contracts.no_recompile() as probe:
        pass
    if not probe.enforced:
        pytest.skip("jax lowering counters unavailable")
    # one fresh compile emits a small bounded number of lowering events
    with contracts.no_recompile(allow=8) as rc:
        doubler(jnp.ones(23))
    assert 0 < rc.count <= 8


def test_contract_violation_is_assertion_error():
    assert issubclass(contracts.ContractViolation, AssertionError)


# ---------------------------------------------------------------------------
# assert_donated
# ---------------------------------------------------------------------------
def test_assert_donated_pass():
    g = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.ones(4)
    with contracts.assert_donated(x, strict=True):
        g(x)
    assert x.is_deleted()


def test_assert_donated_strict_raises_when_not_donated():
    x = jnp.ones(4)
    with pytest.raises(contracts.ContractViolation, match="still live"):
        with contracts.assert_donated(x, strict=True):
            y = x + 1          # plain op: no donation  # noqa: F841


def test_assert_donated_cpu_default_downgrades_to_warning():
    if jax.default_backend() != "cpu":
        pytest.skip("CPU-specific downgrade behavior")
    x = jnp.ones(4)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        with contracts.assert_donated(x):
            pass
    assert len(w) == 1 and issubclass(w[0].category, RuntimeWarning)


def test_assert_donated_watches_pytrees():
    g = jax.jit(lambda t: t, donate_argnums=(0,))
    tree = {"w": jnp.ones(3), "b": jnp.zeros(2)}
    with contracts.assert_donated(tree, strict=True):
        g(tree)


# ---------------------------------------------------------------------------
# nan_tripwire / assert_finite
# ---------------------------------------------------------------------------
def test_nan_tripwire_raises_and_restores():
    before = (jax.config.jax_debug_nans, jax.config.jax_debug_infs)
    with pytest.raises(FloatingPointError):
        with contracts.nan_tripwire():
            jnp.log(jnp.zeros(2) - 1.0)
    after = (jax.config.jax_debug_nans, jax.config.jax_debug_infs)
    assert before == after


def test_nan_tripwire_clean_block():
    with contracts.nan_tripwire():
        out = jnp.log(jnp.ones(2))
    assert bool(jnp.isfinite(out).all())


def test_assert_finite():
    contracts.assert_finite({"w": jnp.ones(3)})
    with pytest.raises(contracts.ContractViolation, match="NaN/inf"):
        contracts.assert_finite({"w": jnp.array([1.0, float("nan")])},
                                label="merge input")
    # integer leaves are ignored (no float finiteness to check)
    contracts.assert_finite({"counts": jnp.arange(4)})
