"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step and one decode step on
CPU with finite outputs and correct shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

B, S = 2, 32


def _batch(cfg, rng):
    if cfg.input_mode == "tokens":
        inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                             jnp.int32)
    else:
        inputs = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                             jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return {"inputs": inputs, "labels": labels}


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    step = jax.jit(T.make_train_step(cfg, lr=1e-3))
    new_params, metrics = step(params, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params changed and stayed finite
    changed = False
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(new_params)):
        assert a.shape == b.shape
        assert bool(jnp.isfinite(b.astype(jnp.float32)).all())
        changed = changed or not np.array_equal(np.asarray(a, np.float32),
                                                np.asarray(b, np.float32))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, B, 64)
    if cfg.input_mode == "tokens":
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    else:
        tok = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    logits, new_cache = jax.jit(
        T.serve_step, static_argnums=1)(params, cfg, cache, tok,
                                        jnp.int32(0))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(new_cache))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-1.6b",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_prefill(arch, rng):
    """Decoding token-by-token must reproduce the teacher-forced logits."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              sliding_window=None)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    h, _ = T.forward(params, cfg, toks)
    full_logits = T.unembed(params, cfg, h)            # (1, n, V)
    cache = T.init_cache(cfg, 1, n)
    outs = []
    for i in range(n):
        logits, cache = T.serve_step(params, cfg, cache, toks[:, i:i + 1],
                                     jnp.int32(i))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)                      # (1, n, V)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_cache_is_bounded():
    cfg = get_config("llama3.2-3b").reduced()          # window=64 in reduced
    assert cfg.sliding_window == 64
    cache = T.init_cache(cfg, B, 4096)
    k = cache["sub0"]["k"]
    assert k.shape[3] == 64  # (L, B, Hkv, min(cache, window), hd)


def test_loss_decreases_over_steps():
    cfg = get_config("olmo-1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    step = jax.jit(T.make_train_step(cfg, lr=5e-3))
    losses = []
    for _ in range(5):
        params, m = step(params, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
