"""Smoke tests: every Section VI-A scheme is an executable data-placement
policy wired through the strategy registry."""
import numpy as np
import pytest

from repro.core import SAGINOrchestrator, build_default_sagin
from repro.core.offloading import OffloadPlan
from repro.core.strategies import STRATEGIES, null_plan, resolve_strategy
from repro.fl.baselines import (ALL_SCHEMES, BASELINES, SCHEME_HOOKS,
                                compare_schemes, run_scheme)


def test_every_scheme_maps_to_a_hook():
    assert set(ALL_SCHEMES) == set(BASELINES) | {"adaptive"}
    for name in ALL_SCHEMES:
        hook = SCHEME_HOOKS[name]
        assert callable(hook)
        assert resolve_strategy(name) is hook
        assert STRATEGIES[name] is hook


def test_all_six_schemes_run_end_to_end():
    lats = compare_schemes(n_rounds=2, n_devices=6, n_air=2, seed=0)
    assert set(lats) == set(ALL_SCHEMES)
    for name, values in lats.items():
        assert len(values) == 2
        assert all(np.isfinite(v) and v > 0 for v in values), name
    # the proposed scheme must not lose to any baseline in round 0
    for name in BASELINES:
        assert lats["adaptive"][0] <= lats[name][0] + 1e-6, name


def test_run_scheme_records_are_complete():
    recs = run_scheme("air_ground", n_rounds=3, n_devices=6, n_air=2)
    assert len(recs) == 3
    for rec in recs:
        assert isinstance(rec.plan, OffloadPlan)
        # air_ground never touches the space layer
        for cp in rec.plan.clusters:
            assert cp.d_air_space == 0.0
            assert cp.d_space_air == 0.0


def test_unknown_strategy_raises():
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    with pytest.raises(ValueError, match="unknown strategy"):
        SAGINOrchestrator(sagin, strategy="nope")


def test_custom_callable_strategy():
    """Any (orchestrator, round) -> OffloadPlan callable is a policy."""
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    calls = []

    def policy(orch, r):
        calls.append(r)
        return null_plan(orch.sagin)

    orch = SAGINOrchestrator(sagin, strategy=policy)
    recs = orch.run(2)
    assert calls == [0, 1]
    assert all(r.plan.case == 0 for r in recs)
