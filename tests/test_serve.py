"""Tests for repro.serve: workload determinism, routing decisions,
gateway end-to-end behavior, training bit-identity with a gateway
attached, and the staleness -> served-accuracy gap between synchronous
and soft_async federation (the acceptance locks of ISSUE 10)."""
import dataclasses

import numpy as np
import pytest

from repro.fl.federation import FederationConfig
from repro.fl.rounds import FLConfig
from repro.scenarios import get_scenario
from repro.serve import (CNNBackend, LinkState, RegionWorkload, ServeConfig,
                         ServeGateway, ServeTopology, TransformerBackend,
                         get_router, resolve_serve, serve_rng)
from repro.serve.router import GROUND_RTT, INFER_CYCLES
from repro.sim.engine import SAGINEngine

TINY = dict(dataset="mnist", n_devices=4, n_air=1, h_local=1,
            train_fraction=0.005, eval_size=64, seed=0,
            execution="sequential")


def two_region_scenario():
    base = get_scenario("multi_region")
    return dataclasses.replace(base, name="_serve_test",
                               regions=base.regions[:2])


@pytest.fixture(scope="module")
def trained_engine():
    """One 2-region engine trained a single round, shared by the
    gateway tests (training is the expensive part)."""
    fl = FLConfig(n_rounds=1, **TINY)
    eng = SAGINEngine(two_region_scenario(), fl=fl)
    eng.run(1)
    return eng


# -- config -----------------------------------------------------------------
def test_serve_config_validation():
    with pytest.raises(ValueError, match="base_rate"):
        ServeConfig(base_rate=-1.0)
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        ServeConfig(diurnal_amplitude=1.0)
    with pytest.raises(ValueError, match="burst_markov"):
        ServeConfig(burst_markov=(0.5, 0.0))
    with pytest.raises(ValueError, match="burst_multiplier"):
        ServeConfig(burst_multiplier=0.5)
    with pytest.raises(ValueError, match="dt"):
        ServeConfig(dt=0.0)
    with pytest.raises(ValueError, match="max_batch"):
        ServeConfig(max_batch=0)


def test_resolve_serve():
    assert resolve_serve(None) == ServeConfig()
    cfg = ServeConfig(base_rate=3.0)
    assert resolve_serve(cfg) is cfg
    with pytest.raises(TypeError):
        resolve_serve("min_rt")


# -- workload ---------------------------------------------------------------
def test_workload_replay_deterministic():
    cfg = ServeConfig(base_rate=5.0, burst_markov=(0.1, 0.3))
    a = RegionWorkload(cfg, 0, seed=7, n_eval=64)
    b = RegionWorkload(cfg, 0, seed=7, n_eval=64)
    other = RegionWorkload(cfg, 1, seed=7, n_eval=64)
    arr_a = list(a.arrivals(0.0, 60.0))
    arr_b = list(b.arrivals(0.0, 60.0))
    arr_other = list(other.arrivals(0.0, 60.0))
    assert arr_a == arr_b                       # replayable
    assert arr_a != arr_other                   # per-region streams
    assert len(arr_a) > 0
    ts = [t for t, _ in arr_a]
    assert ts == sorted(ts)
    assert all(0.0 <= t < 60.0 for t in ts)
    assert all(0 <= s < 64 for _, s in arr_a)


def test_workload_serve_stream_never_aliases_training():
    """The serve-plane generator differs from every training stream
    rooted at the same region seed (tuple-fold isolation)."""
    from repro.sim.engine import region_seed
    sv = serve_rng(0, 0).random(8)
    train = np.random.default_rng(region_seed(0, 0)).random(8)
    assert not np.allclose(sv, train)


def test_workload_bursts_raise_arrival_count():
    quiet = ServeConfig(base_rate=2.0, diurnal_amplitude=0.0)
    bursty = dataclasses.replace(quiet, burst_markov=(0.3, 0.1),
                                 burst_multiplier=8.0)
    n_quiet = len(list(RegionWorkload(quiet, 0, 3, 64).arrivals(0, 300)))
    n_burst = len(list(RegionWorkload(bursty, 0, 3, 64).arrivals(0, 300)))
    assert n_burst > 2 * n_quiet


def test_workload_diurnal_phase():
    cfg = ServeConfig(base_rate=1.0, diurnal_amplitude=0.5)
    wl = RegionWorkload(cfg, 0, 0, 64, phase=0.0)
    peak = wl.rate_at(cfg.diurnal_period / 4.0)       # sin == 1
    trough = wl.rate_at(3.0 * cfg.diurnal_period / 4.0)
    assert peak == pytest.approx(1.5)
    assert trough == pytest.approx(0.5)


def test_workload_churn_thins_arrivals():
    cfg = ServeConfig(base_rate=4.0, diurnal_amplitude=0.0)
    full = RegionWorkload(cfg, 0, 5, 64, n_devices=20, churn_prob=0.0)
    thin = RegionWorkload(cfg, 0, 5, 64, n_devices=20, churn_prob=0.8)
    n_full = len(list(full.arrivals(0, 200)))
    n_thin = len(list(thin.arrivals(0, 200)))
    assert n_thin < 0.6 * n_full


# -- router -----------------------------------------------------------------
def make_topo(n=3, fast_sat=5e9):
    return ServeTopology(sat_f=[fast_sat] * n, ground_f=1e8,
                         req_bits=6272.0, z_isl=3.125e6, topology="ring")


def test_router_prefers_own_sat_when_clean():
    topo = make_topo()
    dec = get_router("min_rt", topo).route(0, {}, {})
    assert dec.target == ("sat", 0)
    assert dec.est_response > 0


def test_router_avoids_uplink_dead_air():
    """A 30 s uplink outage on the origin's satellite prices every
    space route out; the ground fallback wins despite slow compute."""
    topo = make_topo()
    links = {0: LinkState(uplink_delay=30.0)}
    dec = get_router("min_rt", topo).route(0, {}, links)
    assert dec.target == ("ground", 0)
    assert dec.network == pytest.approx(GROUND_RTT)


def test_router_spills_to_isl_neighbour_under_queue_pressure():
    topo = make_topo()
    depth = {("sat", 0): 500}
    dec = get_router("min_rt", topo).route(0, depth, {})
    assert dec.target in (("sat", 1), ("sat", 2))


def test_router_isl_fade_stretches_neighbour_route():
    topo = make_topo()
    clean = topo.network_time(0, ("sat", 1), {})
    faded = topo.network_time(0, ("sat", 1),
                              {1: LinkState(isl_scale=0.1)})
    assert faded > clean


def test_static_nearest_is_blind():
    topo = make_topo()
    links = {0: LinkState(uplink_delay=30.0)}
    dec = get_router("static_nearest", topo).route(
        0, {("sat", 0): 500}, links)
    assert dec.target == ("sat", 0)
    assert dec.est_response > 30.0      # still priced honestly

def test_service_time_hetero():
    topo = make_topo(fast_sat=3e9)
    assert topo.service_time(("sat", 0)) == pytest.approx(INFER_CYCLES / 3e9)
    assert topo.service_time(("ground", 0)) == pytest.approx(
        INFER_CYCLES / 1e8)


def test_get_router_unknown_raises():
    with pytest.raises(ValueError, match="static_nearest"):
        get_router("does_not_exist", make_topo())


# -- gateway ----------------------------------------------------------------
def test_gateway_requires_fl_engine():
    eng = SAGINEngine(two_region_scenario())      # no fl= -> no trainers
    with pytest.raises(ValueError, match="FL-mode"):
        ServeGateway(eng)


def test_gateway_end_to_end(trained_engine):
    gw = ServeGateway(trained_engine,
                      serve=ServeConfig(base_rate=1.0))
    rep = gw.run(90.0, t0=0.0)
    assert rep.requests > 0
    assert rep.served == rep.requests             # queues fully drained
    assert rep.batches > 0
    assert all(len(q) == 0 for q in gw.queues.values())
    assert rep.latency_p50 > 0
    assert rep.latency_p99 >= rep.latency_p50
    assert 0.0 <= rep.served_accuracy <= 1.0
    assert set(rep.count_by_target) <= {"sat", "isl", "ground"}
    assert sum(rep.count_by_target.values()) == rep.served
    assert set(rep.acc_by_region) <= {r.name for r in
                                      trained_engine.scenario.regions}
    assert "router=min_rt" in rep.summary()
    lat = [r.latency for r in gw.completed]
    assert all(l > 0 for l in lat)
    assert all(r.wait >= 0 for r in gw.completed)


def test_gateway_replay_identical(trained_engine):
    """Same engine state + same serve config -> identical sessions."""
    cfg = ServeConfig(base_rate=1.0)
    r1 = ServeGateway(trained_engine, serve=cfg).run(60.0, t0=0.0)
    r2 = ServeGateway(trained_engine, serve=cfg).run(60.0, t0=0.0)
    # qps_wall is host wall-clock throughput — everything else must match
    assert (dataclasses.replace(r1, qps_wall=0.0)
            == dataclasses.replace(r2, qps_wall=0.0))


def test_gateway_config_precedence(trained_engine):
    """Argument > FLConfig.serve > Scenario.serve > defaults."""
    eng = trained_engine
    gw = ServeGateway(eng)      # multi_region sets no serve -> defaults
    assert gw.cfg == ServeConfig()
    arg_cfg = ServeConfig(base_rate=9.0)
    gw = ServeGateway(eng, serve=arg_cfg)
    assert gw.cfg is arg_cfg


def test_gateway_per_request_dispatch_degenerate(trained_engine):
    gw = ServeGateway(trained_engine,
                      serve=ServeConfig(base_rate=1.0, max_batch=1,
                                        batch_align=1))
    rep = gw.run(30.0, t0=0.0)
    assert rep.batches == rep.served              # one dispatch per request


def test_transformer_backend_smoke():
    be = TransformerBackend(seq_len=8)
    assert be.has_labels is False
    x = np.zeros((4, 28, 28, 1), np.float32)
    out = be.predict(0, x, np.arange(4))
    assert out is None
    # same width reuses the compiled step and threads the cache
    be.predict(0, x, np.arange(4))
    assert be._pos[4] == 2


def test_gateway_transformer_backend(trained_engine):
    gw = ServeGateway(trained_engine, serve=ServeConfig(base_rate=0.3),
                      backend=TransformerBackend(seq_len=8))
    rep = gw.run(30.0, t0=0.0)
    assert rep.served == rep.requests
    assert rep.served_accuracy is None
    assert rep.acc_by_region == {}


# -- acceptance locks -------------------------------------------------------
def test_training_bit_identical_with_gateway_attached():
    """Serving between rounds must not perturb training: params and
    accuracy trajectories stay bit-identical (read-only contract)."""
    import jax

    scn = two_region_scenario()
    fl = FLConfig(n_rounds=2, **TINY)

    plain = SAGINEngine(scn, fl=fl)
    plain.run(2)

    attached = SAGINEngine(scn, fl=fl)
    # final_merge=False: split-run == one run (the PR-9 resume contract),
    # so any residual difference here is the gateway's doing
    attached.run(1, final_merge=False)
    gw = ServeGateway(attached, serve=ServeConfig(base_rate=2.0))
    rep = gw.run(60.0)                            # serve mid-training
    assert rep.served > 0
    attached.run(1)                               # resume training

    for a, b in zip(plain.trainers, attached.trainers):
        assert a.result.accuracies == b.result.accuracies
        assert a.wall_clock == b.wall_clock
        for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                          jax.tree_util.tree_leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_staleness_served_accuracy_gap():
    """FedMeld-style staleness must be visible at the serving plane:
    with an aggressive staleness discount (short half_life), soft_async
    merges keep regions on diverged, effectively older models, while the
    synchronous barrier installs one fresh merged model everywhere —
    and the gateway serves measurably better for it (config/seed locked
    to a regime with a wide margin)."""
    import jax

    def served(policy):
        scn = dataclasses.replace(
            two_region_scenario(),
            federation=FederationConfig(policy=policy, every=1,
                                        topology="ring", half_life=30.0))
        fl = FLConfig(dataset="mnist", n_devices=4, n_air=1, h_local=2,
                      train_fraction=0.05, eval_size=256, seed=1,
                      execution="sequential", n_rounds=3)
        eng = SAGINEngine(scn, fl=fl)
        eng.run(3)
        gw = ServeGateway(eng, serve=ServeConfig(base_rate=2.0))
        return eng, gw.run(120.0, t0=0.0)

    eng_sync, rep_sync = served("synchronous")
    eng_async, rep_async = served("soft_async")

    # identical arrival/routing trajectories: only the models differ
    assert rep_sync.requests == rep_async.requests
    assert rep_sync.count_by_target == rep_async.count_by_target

    # structural staleness chain: the barrier leaves every region on the
    # SAME merged params; soft_async leaves them diverged, and its
    # merges recorded genuinely stale peer snapshots
    t0, t1 = eng_sync.trainers
    for la, lb in zip(jax.tree_util.tree_leaves(t0.params),
                      jax.tree_util.tree_leaves(t1.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    a0, a1 = eng_async.trainers
    assert any(
        not np.array_equal(np.asarray(la), np.asarray(lb))
        for la, lb in zip(jax.tree_util.tree_leaves(a0.params),
                          jax.tree_util.tree_leaves(a1.params)))
    assert any(s > 0.0 for m in eng_async.merges for s in m.staleness)

    # ...and the gap shows up in what users actually receive
    assert rep_sync.served_accuracy > rep_async.served_accuracy + 0.02


# -- flash_crowd scenario ---------------------------------------------------
def test_flash_crowd_registered():
    scn = get_scenario("flash_crowd")
    assert scn.serve is not None
    assert scn.serve.burst_markov is not None
    assert scn.serve.burst_multiplier >= 10.0
    assert scn.serve.router == "min_rt"
    assert scn.dynamics is not None and scn.dynamics.any_active()
    assert scn.dynamics.uplink_outage_delay > 0   # degraded_links profile
    assert len(scn.regions) >= 3
    scn.build_constellation()


def test_flash_crowd_burstier_than_defaults():
    scn = get_scenario("flash_crowd")
    quiet = dataclasses.replace(scn.serve, burst_markov=None)
    n_flash = len(list(
        RegionWorkload(scn.serve, 0, 0, 64).arrivals(0, 600)))
    n_quiet = len(list(
        RegionWorkload(quiet, 0, 0, 64).arrivals(0, 600)))
    assert n_flash > n_quiet
