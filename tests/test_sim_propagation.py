"""Tests for the vectorized SAGIN propagation engine: geometry equivalence
with the seed implementation, multi-region batching, interval extraction."""
import numpy as np
import pytest

from repro.core.constellation import (WalkerStar, access_intervals,
                                      elevation_angles, target_eci)
from repro.sim.propagation import (Region, access_intervals_loop,
                                   access_intervals_multi,
                                   access_intervals_vec,
                                   coverage_dot_threshold,
                                   intervals_from_visibility,
                                   positions_eci_batch, resolve_backend,
                                   sin_elevations, targets_eci_batch,
                                   visibility)

REGIONS = [Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8),
           Region("sydney", -33.9, 151.2)]


def assert_same_intervals(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.sat == y.sat
        assert x.start == y.start
        assert x.end == y.end


def test_positions_match_seed_walker_star():
    ws = WalkerStar()
    t = np.linspace(0.0, 2 * 3600.0, 93)
    np.testing.assert_allclose(positions_eci_batch(ws, t),
                               ws.positions_eci(t), rtol=1e-12, atol=1e-5)


def test_targets_match_seed_target_eci():
    t = np.linspace(0.0, 6 * 3600.0, 201)
    batch = targets_eci_batch(REGIONS, t)
    for i, r in enumerate(REGIONS):
        np.testing.assert_allclose(batch[i],
                                   target_eci(r.lat_deg, r.lon_deg, t),
                                   rtol=1e-12, atol=1e-6)


def test_sin_elevations_match_seed_elevation_angles():
    ws = WalkerStar(n_sats=20, n_planes=4)
    t = np.linspace(0.0, 3600.0, 121)
    got = sin_elevations(ws, REGIONS, t)
    for i, r in enumerate(REGIONS):
        ref = np.sin(elevation_angles(ws, r.lat_deg, r.lon_deg, t))
        np.testing.assert_allclose(got[i], ref, rtol=1e-9, atol=1e-12)


def test_dot_threshold_equals_elevation_mask():
    """The central-angle threshold must reproduce sine-space thresholding."""
    ws = WalkerStar()
    t = np.arange(0.0, 2 * 3600.0, 10.0)
    sin_el = sin_elevations(ws, REGIONS, t)
    ref = sin_el >= np.sin(np.deg2rad(15.0))
    got = visibility(ws, REGIONS, t, backend="numpy")
    np.testing.assert_array_equal(got, ref)


def test_vectorized_intervals_equal_seed_loop():
    ws = WalkerStar()
    ref = access_intervals_loop(ws, 40.0, -86.0, t_end=4 * 3600.0)
    got = access_intervals_vec(ws, 40.0, -86.0, t_end=4 * 3600.0)
    assert len(ref) > 0
    assert_same_intervals(ref, got)


def test_core_access_intervals_delegates_to_vectorized():
    ws = WalkerStar()
    a = access_intervals(ws, t_end=2 * 3600.0)
    b = access_intervals_vec(ws, t_end=2 * 3600.0)
    assert_same_intervals(a, b)


def test_multi_region_shares_one_propagation():
    """Batched multi-region output equals independent per-region passes."""
    ws = WalkerStar(n_sats=40, n_planes=5)
    multi = access_intervals_multi(ws, REGIONS, t_end=2 * 3600.0)
    assert set(multi) == {r.name for r in REGIONS}
    for r in REGIONS:
        ref = access_intervals_loop(ws, r.lat_deg, r.lon_deg,
                                    t_end=2 * 3600.0)
        assert_same_intervals(ref, multi[r.name])


def test_mega_constellation_shape():
    ws = WalkerStar(n_sats=1080, n_planes=27, altitude=550e3,
                    inclination_deg=53.0)
    t = np.arange(0.0, 1800.0, 30.0)
    vis = visibility(ws, REGIONS, t)
    assert vis.shape == (len(REGIONS), len(t), 1080)
    # a 1080-sat shell must cover mid-latitude regions essentially always
    assert vis[0].any(axis=1).mean() > 0.95


def test_per_region_min_elevation():
    ws = WalkerStar()
    strict = Region("strict", 40.0, -86.0, min_elevation_deg=40.0)
    loose = Region("loose", 40.0, -86.0, min_elevation_deg=5.0)
    t = np.arange(0.0, 6 * 3600.0, 10.0)
    vis = visibility(ws, [strict, loose], t)
    assert vis[0].sum() < vis[1].sum()
    assert coverage_dot_threshold(ws, 40.0) > coverage_dot_threshold(ws, 5.0)


def test_intervals_from_visibility_edge_windows():
    """Windows open at t=0 and still open at the horizon match seed
    conventions (end clamped to the last sample)."""
    t = np.arange(0.0, 50.0, 10.0)
    v = np.zeros((5, 2), dtype=bool)
    v[:2, 0] = True      # open at t=0, closes at sample 2
    v[3:, 1] = True      # opens at sample 3, still open at horizon
    ivs = intervals_from_visibility(v, t)
    assert [(iv.sat, iv.start, iv.end) for iv in ivs] == [
        (0, 0.0, 20.0), (1, 30.0, 40.0)]


def test_backend_resolution():
    assert resolve_backend("numpy") is np
    import jax.numpy as jnp
    assert resolve_backend("jax") is jnp
    with pytest.raises(ValueError):
        resolve_backend("tensorflow")


def test_jax_backend_without_x64_raises():
    """Interval boundaries are precision-critical: the jax backend must
    refuse to run in float32 instead of silently shifting windows."""
    import jax
    if jax.config.jax_enable_x64:
        pytest.skip("x64 globally enabled; the guard cannot trip")
    ws = WalkerStar(n_sats=20, n_planes=4)
    with pytest.raises(ValueError, match="x64"):
        access_intervals_multi(ws, REGIONS, t_end=3600.0, backend="jax")


def test_jax_backend_with_x64_matches_numpy_exactly():
    from jax.experimental import enable_x64
    ws = WalkerStar(n_sats=20, n_planes=4)
    a = access_intervals_multi(ws, REGIONS, t_end=3600.0, backend="numpy")
    with enable_x64():
        b = access_intervals_multi(ws, REGIONS, t_end=3600.0, backend="jax")
    for r in REGIONS:
        assert_same_intervals(a[r.name], b[r.name])


def test_intervals_from_visibility_empty_mask_short_circuits():
    t = np.arange(0.0, 100.0, 10.0)
    assert intervals_from_visibility(np.zeros((len(t), 7), bool), t) == []


def test_basis_caches_are_shared_and_read_only():
    """constellation/region bases (and the contracted gram) are memoized
    per frozen constellation/region tuple and marked immutable."""
    from repro.sim.propagation import constellation_basis, region_basis
    ws = WalkerStar(n_sats=20, n_planes=4)
    b1 = constellation_basis(ws)
    b2 = constellation_basis(WalkerStar(n_sats=20, n_planes=4))
    assert b1 is b2                       # equal frozen configs, one entry
    assert not b1.flags.writeable
    with pytest.raises(ValueError):
        b1[0, 0, 0] = 1.0
    r1 = region_basis(REGIONS)
    assert r1 is region_basis(tuple(REGIONS))
    assert not r1.flags.writeable
    # cached basis still reproduces the seed geometry
    t = np.linspace(0.0, 3600.0, 37)
    np.testing.assert_allclose(positions_eci_batch(ws, t),
                               ws.positions_eci(t), rtol=1e-12, atol=1e-5)
