"""The linter linted: every rule gets >= 1 positive and >= 1 negative
fixture, plus golden file:line findings, a clean realistic file, the
baseline round trip, and the CLI's exit-code semantics."""
import json
import textwrap

import pytest

from repro.analysis import (DEFAULT_BASELINE, apply_baseline, classify,
                            load_baseline, scan, write_baseline)
from repro.analysis.__main__ import main as cli


def lint(tmp_path, src, name="lib/mod.py", rules=None):
    """Write ``src`` under tmp_path and scan it; returns findings."""
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(src))
    return scan([f], root=tmp_path, rule_ids=rules)


def rules_hit(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# RNG001 — legacy global numpy RNG
# ---------------------------------------------------------------------------
def test_rng001_positive(tmp_path):
    out = lint(tmp_path, """
        import numpy as np
        def draw(n):
            return np.random.rand(n)
    """)
    assert rules_hit(out) == ["RNG001"]
    assert out[0].line == 4


def test_rng001_negative_generator_and_aliases(tmp_path):
    out = lint(tmp_path, """
        import numpy as np
        import numpy.random as npr
        def draw(n, seed):
            rng = np.random.default_rng(seed)   # construction is fine
            gen = npr.Generator(npr.PCG64(seed))
            return rng.normal(size=n) + gen.normal(size=n)
    """)
    assert out == []


def test_rng001_skipped_in_tests(tmp_path):
    out = lint(tmp_path, """
        import numpy as np
        def fixture(n):
            return np.random.rand(n)
    """, name="tests/test_x.py")
    assert out == []


# ---------------------------------------------------------------------------
# RNG002 — jax key reuse
# ---------------------------------------------------------------------------
def test_rng002_positive_two_consumers(tmp_path):
    out = lint(tmp_path, """
        import jax
        def init(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
    """)
    assert rules_hit(out) == ["RNG002"]
    assert out[0].line == 5          # flagged at the SECOND consumer
    assert "'key'" in out[0].message


def test_rng002_positive_loop_reuse(tmp_path):
    out = lint(tmp_path, """
        import jax
        def draws(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (2,)))
            return out
    """)
    assert rules_hit(out) == ["RNG002"]
    assert "loop" in out[0].message


def test_rng002_negative_split_and_fold_in(tmp_path):
    # the repo's layers.py idiom: one split + fold_in derivations
    out = lint(tmp_path, """
        import jax
        def init(key):
            ks = jax.random.split(key, 3)
            a = jax.random.normal(ks[0], (3,))
            b = jax.random.uniform(ks[1], (3,))
            c = jax.random.normal(jax.random.fold_in(key, 99), (3,))
            d = jax.random.normal(jax.random.fold_in(key, 98), (3,))
            return a + b + c + d
    """)
    assert out == []


def test_rng002_positive_split_index_reused(tmp_path):
    out = lint(tmp_path, """
        import jax
        def init(key):
            ks = jax.random.split(key, 2)
            a = jax.random.normal(ks[0], (3,))
            b = jax.random.uniform(ks[0], (3,))
            return a + b
    """)
    assert rules_hit(out) == ["RNG002"]


def test_rng002_negative_rebind_in_loop(tmp_path):
    out = lint(tmp_path, """
        import jax
        def draws(key, n):
            out = []
            for i in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (2,)))
            return out
    """)
    assert out == []


def test_rng002_negative_branches_are_exclusive(tmp_path):
    out = lint(tmp_path, """
        import jax
        def pick(key, flag):
            if flag:
                return jax.random.normal(key, (3,))
            else:
                return jax.random.uniform(key, (3,))
    """)
    assert out == []


def test_rng002_skipped_in_tests(tmp_path):
    out = lint(tmp_path, """
        import jax
        def helper(key):
            return (jax.random.normal(key, (2,)),
                    jax.random.normal(key, (2,)))
    """, name="tests/test_y.py")
    assert out == []


# ---------------------------------------------------------------------------
# RNG003 — hard-coded PRNGKey literal
# ---------------------------------------------------------------------------
def test_rng003_positive(tmp_path):
    out = lint(tmp_path, """
        import jax
        def build():
            return jax.random.PRNGKey(42)
    """)
    assert rules_hit(out) == ["RNG003"]
    assert out[0].severity == "warning"


def test_rng003_negative_threaded_seed_and_test_kind(tmp_path):
    assert lint(tmp_path, """
        import jax
        def build(seed):
            return jax.random.PRNGKey(seed)
    """) == []
    assert lint(tmp_path, """
        import jax
        KEY = jax.random.PRNGKey(0)
    """, name="tests/test_z.py") == []


# ---------------------------------------------------------------------------
# JIT001 — jit constructed in a loop
# ---------------------------------------------------------------------------
def test_jit001_positive(tmp_path):
    out = lint(tmp_path, """
        import jax
        def run(fs, x):
            for f in fs:
                x = jax.jit(f)(x)
            return x
    """)
    assert "JIT001" in rules_hit(out)
    assert any(f.line == 5 for f in out)


def test_jit001_negative_module_level_and_nested_def(tmp_path):
    out = lint(tmp_path, """
        import jax

        step = jax.jit(lambda x: x + 1)

        def run(xs):
            for x in xs:
                def inner(y):
                    return jax.jit(lambda z: z)(y)   # not per-iteration
            return step(xs[0])
    """, rules=["JIT001"])
    assert out == []


# ---------------------------------------------------------------------------
# JIT002 — immediately-invoked jit
# ---------------------------------------------------------------------------
def test_jit002_positive(tmp_path):
    out = lint(tmp_path, """
        import jax
        def f(x):
            return jax.jit(lambda y: y * 2)(x)
    """)
    assert rules_hit(out) == ["JIT002"]


def test_jit002_negative_bound_once(tmp_path):
    out = lint(tmp_path, """
        import jax
        double = jax.jit(lambda y: y * 2)
        def f(x):
            return double(x)
    """)
    assert out == []


# ---------------------------------------------------------------------------
# JIT003 — unhashable static args
# ---------------------------------------------------------------------------
def test_jit003_positive_mutable_default(tmp_path):
    out = lint(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnums=(1,))
        def reshape(x, dims=[1, 2]):
            return x.reshape(dims)
    """)
    assert rules_hit(out) == ["JIT003"]


def test_jit003_positive_literal_at_static_position(tmp_path):
    out = lint(tmp_path, """
        import jax

        def _impl(x, dims):
            return x.reshape(dims)

        shaped = jax.jit(_impl, static_argnums=(1,))

        def call(x):
            return shaped(x, [4, 2])
    """)
    assert rules_hit(out) == ["JIT003"]


def test_jit003_negative_hashable(tmp_path):
    out = lint(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnums=(1,))
        def reshape(x, dims=(1, 2)):
            return x.reshape(dims)

        def call(x):
            return reshape(x, (4, 2))
    """)
    assert out == []


# ---------------------------------------------------------------------------
# DON001 — use-after-donate
# ---------------------------------------------------------------------------
def test_don001_positive_same_module(tmp_path):
    out = lint(tmp_path, """
        import jax

        step = jax.jit(lambda p, x: p, donate_argnums=(0,))

        def train(params, x):
            new = step(params, x)
            return params, new
    """)
    assert rules_hit(out) == ["DON001"]
    assert out[0].line == 8


def test_don001_positive_cross_module_donor(tmp_path):
    # the repo's real layout: the donating jit lives in one module,
    # the caller in another — the donor table is project-wide
    (tmp_path / "lib").mkdir(parents=True, exist_ok=True)
    (tmp_path / "lib" / "kernels.py").write_text(textwrap.dedent("""
        import jax
        fused_step = jax.jit(lambda p, x: p, donate_argnums=(0,))
    """))
    (tmp_path / "lib" / "driver.py").write_text(textwrap.dedent("""
        from lib.kernels import fused_step

        def train(params, x):
            new = fused_step(params, x)
            return params["w"], new
    """))
    out = scan([tmp_path / "lib"], root=tmp_path)
    assert [(f.rule, f.path) for f in out] == [("DON001", "lib/driver.py")]


def test_don001_negative_rebind(tmp_path):
    out = lint(tmp_path, """
        import jax

        step = jax.jit(lambda p, x: p, donate_argnums=(0,))

        def train(params, x):
            params = step(params, x)
            return params
    """)
    assert out == []


def test_don001_negative_branch_not_taken_pattern(tmp_path):
    # CohortEngine.round's shape: donate only in one branch, the result
    # rebinds; reading the ORIGINAL afterwards is still an error only
    # if any branch donated without rebinding
    out = lint(tmp_path, """
        import jax

        step = jax.jit(lambda p, x: p, donate_argnums=(0,))

        def train(params, x, fused):
            if fused:
                out = step(params, x)
            else:
                out = (params, x)
            return out
    """)
    assert out == []


# ---------------------------------------------------------------------------
# HOST001 — host sync in round/step loops
# ---------------------------------------------------------------------------
def test_host001_positive(tmp_path):
    out = lint(tmp_path, """
        def run(cfg, arr):
            losses = []
            for r in range(cfg.n_rounds):
                arr = arr * 2
                losses.append(float(arr))
        """)
    assert rules_hit(out) == ["HOST001"]
    assert out[0].severity == "warning"


def test_host001_positive_item(tmp_path):
    out = lint(tmp_path, """
        def run(n_steps, arr):
            tot = 0.0
            for step in range(n_steps):
                tot += arr.sum().item()
            return tot
    """)
    assert rules_hit(out) == ["HOST001"]


def test_host001_negative_outside_round_loop(tmp_path):
    out = lint(tmp_path, """
        def run(xs, arr):
            for x in xs:            # not a round/step loop
                arr = arr + float(x)
            return float(arr)       # after the loop: fine
    """)
    assert out == []


# ---------------------------------------------------------------------------
# OBS001 — tracer/metrics call inside a jitted function
# ---------------------------------------------------------------------------
def test_obs001_positive_decorated(tmp_path):
    out = lint(tmp_path, """
        import jax

        @jax.jit
        def step(tracer, x):
            tracer.span("round", "r")     # runs at trace time only
            return x * 2
    """)
    assert rules_hit(out) == ["OBS001"]
    assert out[0].line == 6


def test_obs001_positive_partial_and_attribute_receiver(tmp_path):
    out = lint(tmp_path, """
        import functools
        import jax

        class Engine:
            @functools.partial(jax.jit, static_argnums=(0,))
            def step(self, x):
                self.tracer.event("outage", "isl")
                self.metrics.counter("n").inc()
                return x
    """)
    assert rules_hit(out) == ["OBS001"]
    assert len(out) == 2


def test_obs001_positive_module_level_jit(tmp_path):
    out = lint(tmp_path, """
        import jax
        from repro.obs import NULL_TRACER

        def _inner(x):
            NULL_TRACER.span("round", "r")
            return x + 1

        step = jax.jit(_inner)
    """)
    assert rules_hit(out) == ["OBS001"]


def test_obs001_negative_outside_jit(tmp_path):
    out = lint(tmp_path, """
        import jax

        @jax.jit
        def step(x):
            return x * 2

        def round_driver(tracer, x):
            y = step(x)
            tracer.span("round", "r")     # host side: fine
            tracer.metrics.histogram("h").observe(1.0)
            return y
    """)
    assert out == []


def test_obs001_negative_unrelated_receiver_methods(tmp_path):
    out = lint(tmp_path, """
        import jax

        @jax.jit
        def step(layout, cfg, x):
            w = layout.span("a", "b")     # not a tracer/metrics object
            cfg.set(3)
            return x * w
    """)
    assert out == []


# ---------------------------------------------------------------------------
# SHARD001 — collective with literal axis outside shard_map context
# ---------------------------------------------------------------------------
def test_shard001_positive_unwired_function(tmp_path):
    out = lint(tmp_path, """
        import jax

        def agg(x):
            return jax.lax.psum(x, "data")
    """)
    assert rules_hit(out) == ["SHARD001"]
    assert out[0].line == 5


def test_shard001_positive_pmean_tuple_axes_and_kwarg(tmp_path):
    out = lint(tmp_path, """
        import jax
        from jax import lax

        def a(x):
            return lax.pmean(x, ("data", "pod"))

        def b(x):
            return jax.lax.all_gather(x, axis_name="data")
    """)
    assert rules_hit(out) == ["SHARD001"]
    assert len(out) == 2


def test_shard001_negative_wired_by_name(tmp_path):
    out = lint(tmp_path, """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map

        def agg(x):
            return jax.lax.psum(x, "data")

        def build(mesh):
            return jax.jit(shard_map(agg, mesh=mesh, in_specs=P("data"),
                                     out_specs=P()))
    """)
    assert out == []


def test_shard001_negative_closure_factory(tmp_path):
    # the CohortEngine._make_sharded_step idiom: the traced body is a
    # nested def inside the function that calls shard_map
    out = lint(tmp_path, """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map

        def make_step(mesh):
            def body(x):
                return jax.lax.psum(x, "data")
            return jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                                     out_specs=P()))
    """)
    assert out == []


def test_shard001_negative_axis_from_parameter(tmp_path):
    # hierarchical_weighted_psum takes the axes as a parameter — the
    # binding mesh lives in the caller's module, out of static reach
    out = lint(tmp_path, """
        import jax

        def weighted_psum(tree, lam, axis_names):
            def agg(leaf):
                contrib = lam * leaf
                for ax in axis_names:
                    contrib = jax.lax.psum(contrib, ax)
                return contrib
            return jax.tree_util.tree_map(agg, tree)
    """)
    assert out == []


def test_shard001_skipped_in_tests(tmp_path):
    out = lint(tmp_path, """
        import jax
        def agg(x):
            return jax.lax.psum(x, "data")
    """, name="tests/test_x.py")
    assert out == []


# ---------------------------------------------------------------------------
# RES001 — bare assert in library code
# ---------------------------------------------------------------------------
def test_res001_positive(tmp_path):
    out = lint(tmp_path, """
        def restore(state, n_regions):
            assert len(state) == n_regions, "region count mismatch"
            return list(state)
    """)
    assert rules_hit(out) == ["RES001"]
    assert out[0].line == 3
    assert "python -O" in out[0].message


def test_res001_negative_raise(tmp_path):
    out = lint(tmp_path, """
        def restore(state, n_regions):
            if len(state) != n_regions:
                raise ValueError("region count mismatch")
            return list(state)
    """)
    assert out == []


def test_res001_skipped_in_tests_and_benchmarks(tmp_path):
    src = """
        def check(xs):
            assert xs, "empty"
    """
    assert lint(tmp_path, src, name="tests/test_x.py") == []
    assert lint(tmp_path, src, name="benchmarks/bench_x.py") == []


# ---------------------------------------------------------------------------
# TIME001 — time.time() where a measurement is implied
# ---------------------------------------------------------------------------
def test_time001_positive_duration(tmp_path):
    out = lint(tmp_path, """
        import time
        def measure(fn):
            t0 = time.time()
            fn()
            return time.time() - t0
    """)
    assert rules_hit(out) == ["TIME001"]
    assert [f.line for f in out] == [4, 6]
    assert "perf_counter" in out[0].message


def test_time001_positive_from_import_alias(tmp_path):
    out = lint(tmp_path, """
        from time import time as now
        def stamp():
            return now()
    """)
    assert rules_hit(out) == ["TIME001"]


def test_time001_negative_perf_counter(tmp_path):
    out = lint(tmp_path, """
        import time
        def measure(fn):
            t0 = time.perf_counter()
            fn()
            return time.perf_counter() - t0
    """)
    assert out == []


def test_time001_negative_unrelated_time_name(tmp_path):
    # a local callable named `time` from another module is not the
    # stdlib wall clock
    out = lint(tmp_path, """
        from simclock import time
        def stamp():
            return time()
    """)
    assert out == []


def test_time001_skipped_in_tests(tmp_path):
    src = """
        import time
        def test_fresh():
            assert time.time() > 0
    """
    assert lint(tmp_path, src, name="tests/test_x.py") == []
    # ...but benchmarks ARE covered: measurement code is the point
    out = lint(tmp_path, """
        import time
        def bench():
            t0 = time.time()
            return time.time() - t0
    """, name="benchmarks/bench_x.py")
    assert rules_hit(out) == ["TIME001"]


# ---------------------------------------------------------------------------
# golden findings, clean file, parse errors
# ---------------------------------------------------------------------------
def test_golden_file_line_rule_triples(tmp_path):
    out = lint(tmp_path, """
        import numpy as np
        import jax

        def draw(n):
            return np.random.rand(n)

        def init(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b

        def hot(fs, x):
            for f in fs:
                x = jax.jit(f)(x)
            return x
    """)
    triples = [(f.rule, f.line) for f in out]
    assert triples == [("RNG001", 6), ("RNG002", 10),
                       ("JIT001", 15), ("JIT002", 15)]
    assert all(f.path == "lib/mod.py" for f in out)


def test_clean_realistic_file(tmp_path):
    out = lint(tmp_path, """
        import numpy as np
        import jax
        import jax.numpy as jnp
        from functools import partial

        @partial(jax.jit, static_argnums=(0,))
        def local_update(apply_fn, params, xs, ys, lr):
            grads = jax.grad(lambda p: apply_fn(p, xs).sum())(params)
            return jax.tree_util.tree_map(
                lambda p, g: p - lr * g, params, grads)

        def run(cfg, apply_fn, params, data, seed):
            rng = np.random.default_rng(seed)
            key = jax.random.PRNGKey(seed)
            for r in range(cfg.n_rounds):
                key, sub = jax.random.split(key)
                noise = jax.random.normal(sub, (4,))
                xs = jnp.asarray(rng.normal(size=(8, 4)))
                params = local_update(apply_fn, params, xs + noise,
                                      None, cfg.lr)
            return params
    """)
    assert out == []


def test_unparseable_file_reports_parse_finding(tmp_path):
    out = lint(tmp_path, "def broken(:\n")
    assert [f.rule for f in out] == ["PARSE"]
    assert out[0].severity == "error"


def test_classify():
    from pathlib import Path
    assert classify(Path("tests/test_x.py")) == "test"
    assert classify(Path("benchmarks/run.py")) == "bench"
    assert classify(Path("examples/demo.py")) == "example"
    assert classify(Path("src/repro/fl/rounds.py")) == "library"


# ---------------------------------------------------------------------------
# baseline round trip + CLI exit codes
# ---------------------------------------------------------------------------
BAD_SRC = """
import numpy as np
def draw(n):
    return np.random.rand(n)
"""


def test_baseline_round_trip(tmp_path):
    f = tmp_path / "lib.py"
    f.write_text(BAD_SRC)
    found = scan([f], root=tmp_path)
    assert len(found) == 1

    bl = tmp_path / "baseline.json"
    write_baseline(bl, found)
    suppressed = load_baseline(bl)
    assert suppressed == {found[0].key}
    assert apply_baseline(found, suppressed) == []

    # a NEW violation is not suppressed by the old baseline
    f.write_text(BAD_SRC + "\ndef more(n):\n    return np.random.rand(n)\n")
    again = scan([f], root=tmp_path)
    fresh = apply_baseline(again, suppressed)
    assert [g.rule for g in fresh] == ["RNG001"]
    assert fresh[0].line > found[0].line


def test_baseline_version_check(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"version": 99, "suppressed": []}))
    with pytest.raises(ValueError):
        load_baseline(bl)


def test_cli_exit_codes(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(tmp_path)
    bad = tmp_path / "lib.py"
    bad.write_text(BAD_SRC)
    clean = tmp_path / "ok.py"
    clean.write_text("import numpy as np\n\n\ndef f(rng):\n"
                     "    return rng.normal()\n")

    assert cli([str(clean)]) == 0
    assert cli([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "RNG001" in out and "1 error(s)" in out

    # json format round-trips through json.loads
    assert cli([str(bad), "--format", "json"]) == 1
    data = json.loads(capsys.readouterr().out)
    assert data["findings"][0]["rule"] == "RNG001"

    # write-baseline accepts everything; next run is clean via default
    # baseline discovery in cwd
    assert cli([str(bad), "--write-baseline"]) == 0
    assert (tmp_path / DEFAULT_BASELINE).exists()
    capsys.readouterr()
    assert cli([str(bad)]) == 0
    assert cli([str(bad), "--no-baseline"]) == 1

    # warnings don't fail unless --strict
    warn = tmp_path / "warn.py"
    warn.write_text("import jax\n\n\ndef build():\n"
                    "    return jax.random.PRNGKey(7)\n")
    capsys.readouterr()
    assert cli([str(warn), "--no-baseline"]) == 0
    assert cli([str(warn), "--no-baseline", "--strict"]) == 1

    # usage errors
    assert cli(["missing_dir_xyz"]) == 2
    assert cli([str(bad), "--select", "NOPE01"]) == 2


def test_cli_select_rules(tmp_path, capsys):
    f = tmp_path / "lib.py"
    f.write_text(BAD_SRC)
    assert cli([str(f), "--select", "JIT001", "--no-baseline"]) == 0
    assert cli([str(f), "--select", "RNG001", "--no-baseline"]) == 1
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert cli(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("RNG001", "RNG002", "RNG003", "JIT001", "JIT002",
                "JIT003", "DON001", "HOST001"):
        assert rid in out
