"""Mesh-sharded cohort engine (client axis over the mesh's data axis).

Three layers of coverage:

* Host-only planner checks plus the 1-device-mesh golden lock (the
  sharded engine must be BIT-identical to ``sharding="off"`` there) —
  these run on any device count, including the plain tier-1 lane.
* In-process multi-device tests, marked ``mesh`` and skipped below 2
  devices: the CI mesh lane runs the whole file (plus
  ``tests/test_cohort.py``) under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to activate
  them, asserting sharded == single-device trajectories at equal seeds
  and ZERO recompiles on warm shard-stable signatures.
* One subprocess test (marked ``slow``) that forces 8 host devices
  itself, so the ordinary slow lane exercises the sharded path even
  without the forced-device environment.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import ContractViolation
from repro.data.pipeline import plan_buckets
from repro.fl.cohort_engine import CohortEngine
from repro.launch.mesh import make_cohort_mesh
from repro.obs import ObsConfig, Tracer

N_DEVICES = len(jax.devices())

multi_device = pytest.mark.skipif(
    N_DEVICES < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mlp_init(key, din=32, dh=16, nc=10):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (din, dh)) * 0.1,
            "b1": jnp.zeros((dh,)),
            "w2": jax.random.normal(k2, (dh, nc)) * 0.1,
            "b2": jnp.zeros((nc,))}


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _toy_data(n=600, din=32, nc=10, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, nc, size=n)
    return x, y


def _skewed_pools(n, k_small=10, small=30, seed=0):
    pools = [np.arange(k * small, (k + 1) * small) for k in range(k_small)]
    pools.append(np.arange(k_small * small, n))
    return pools


# ---------------------------------------------------------------------------
# planner + degrade contract: any device count
# ---------------------------------------------------------------------------
def test_engine_modes_and_validation():
    with pytest.raises(ValueError):
        CohortEngine(_mlp_apply, sharding="bogus")
    off = CohortEngine(_mlp_apply, sharding="off")
    assert off.shards == 1 and off.mesh is None
    one = CohortEngine(_mlp_apply, sharding="mesh",
                       mesh=make_cohort_mesh(1))
    assert one.shards == 1


def test_sharded_plan_divides_across_shards():
    for shards in (2, 4, 8):
        plans = plan_buckets([8] * 12 + [512], batch_align=8,
                             client_align=4, client_multiple=shards)
        assert all(p.c_bucket % shards == 0 for p in plans)


def test_one_device_mesh_bit_identical_to_off():
    """The golden degrade lock: sharding="mesh" over a 1-device mesh IS
    the single-device engine — identical plans, bit-identical params and
    losses over a multi-round drifting trajectory."""
    x, y = _toy_data(n=900, seed=5)
    pools = _skewed_pools(900, k_small=6, small=40)
    total = sum(len(p) for p in pools)
    e_off = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                         sharding="off")
    e_one = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                         sharding="mesh", mesh=make_cohort_mesh(1))
    p_off = _mlp_init(jax.random.PRNGKey(1))
    p_one = _mlp_init(jax.random.PRNGKey(1))
    for r in range(3):
        c_off = e_off.build(x, y, pools, 3, np.random.default_rng(50 + r),
                            max_batch=16)
        c_one = e_one.build(x, y, pools, 3, np.random.default_rng(50 + r),
                            max_batch=16)
        assert [cb.xs.shape for cb in c_off.buckets] == \
               [cb.xs.shape for cb in c_one.buckets]
        p_off, l_off = e_off.round(p_off, c_off, 0.1, total)
        p_one, l_one = e_one.round(p_one, c_one, 0.1, total)
        assert l_off == l_one
        for a, b in zip(jax.tree_util.tree_leaves(p_off),
                        jax.tree_util.tree_leaves(p_one)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the 1-shard engine reports no sharded activity
    assert e_one.stats.sharded_dispatches == 0
    assert e_one.stats.last_shard_imbalance == 1.0


# ---------------------------------------------------------------------------
# multi-device: equivalence, recompiles, stats/obs (the CI mesh lane)
# ---------------------------------------------------------------------------
@multi_device
@pytest.mark.mesh
def test_sharded_matches_single_device_trajectory():
    """Sharded == unsharded trajectories at equal seeds (same RNG stream,
    same batches; only float reduction order differs across shards)."""
    x, y = _toy_data(n=900, seed=2)
    pools = _skewed_pools(900, k_small=8, small=30)
    total = sum(len(p) for p in pools)
    e_off = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                         sharding="off")
    e_mesh = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                          sharding="mesh")
    assert e_mesh.shards == N_DEVICES
    p_off = _mlp_init(jax.random.PRNGKey(0))
    p_mesh = _mlp_init(jax.random.PRNGKey(0))
    for r in range(4):
        c_off = e_off.build(x, y, pools, 3, np.random.default_rng(10 + r),
                            max_batch=16)
        c_mesh = e_mesh.build(x, y, pools, 3, np.random.default_rng(10 + r),
                              max_batch=16)
        assert all(cb.xs.shape[0] % e_mesh.shards == 0
                   for cb in c_mesh.buckets)
        p_off, l_off = e_off.round(p_off, c_off, 0.1, total)
        p_mesh, l_mesh = e_mesh.round(p_mesh, c_mesh, 0.1, total)
        np.testing.assert_allclose(l_mesh, l_off, rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(p_off),
                        jax.tree_util.tree_leaves(p_mesh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
    assert e_mesh.stats.sharded_dispatches == e_mesh.stats.bucket_dispatches
    assert e_mesh.stats.max_shard_imbalance >= 1.0


@multi_device
@pytest.mark.mesh
def test_sharded_zero_recompiles_after_warmup():
    """Pool drift re-lands on warm shard-stable signatures: after the
    warm-up rounds, guarded rounds must not lower a single program."""
    x, y = _toy_data(n=1200, seed=4)
    pools = _skewed_pools(1200, k_small=10, small=40)
    total = sum(len(p) for p in pools)
    eng = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                       sharding="mesh", guard=True)
    params = _mlp_init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)

    def drift(pools):
        # move ~10% of two random pools' samples to two others
        out = [p.copy() for p in pools]
        for _ in range(2):
            src, dst = rng.choice(len(out), 2, replace=False)
            k = max(1, len(out[src]) // 10)
            out[dst] = np.concatenate([out[dst], out[src][:k]])
            out[src] = out[src][k:]
        return out

    # warm-up: see every drifted layout once
    warm_pools = pools
    for r in range(3):
        c = eng.build(x, y, warm_pools, 3, np.random.default_rng(100 + r),
                      max_batch=16)
        params, _ = eng.round(params, c, 0.1, total)
        warm_pools = drift(warm_pools)
    # warm rounds under guard: signatures already seen -> no lowering;
    # a recompile would raise ContractViolation inside round()
    n_sigs = len(eng.signatures)
    warm_pools = pools
    for r in range(3):
        c = eng.build(x, y, warm_pools, 3, np.random.default_rng(100 + r),
                      max_batch=16)
        params, _ = eng.round(params, c, 0.1, total)
        warm_pools = drift(warm_pools)
    assert len(eng.signatures) == n_sigs


@multi_device
@pytest.mark.mesh
def test_sharded_guard_self_arms_and_trips_on_cleared_cache():
    x, y = _toy_data(n=600, seed=6)
    pools = _skewed_pools(600, k_small=4, small=40)
    total = sum(len(p) for p in pools)
    eng = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                       sharding="mesh", guard=True)
    params = _mlp_init(jax.random.PRNGKey(5))
    c = eng.build(x, y, pools, 3, np.random.default_rng(9), max_batch=16)
    params, _ = eng.round(params, c, 0.1, total)
    jax.clear_caches()
    with pytest.raises(ContractViolation):
        eng.round(params, c, 0.1, total)


@multi_device
@pytest.mark.mesh
def test_sharded_stats_spans_and_imbalance(tmp_path):
    x, y = _toy_data(n=600, seed=7)
    pools = _skewed_pools(600, k_small=6, small=30)
    total = sum(len(p) for p in pools)
    tr = Tracer(ObsConfig(path=str(tmp_path / "mesh.jsonl")))
    eng = CohortEngine(_mlp_apply, batch_align=8, client_align=4,
                       sharding="mesh", tracer=tr)
    params = _mlp_init(jax.random.PRNGKey(6))
    c = eng.build(x, y, pools, 3, np.random.default_rng(11), max_batch=16)
    params, _ = eng.round(params, c, 0.1, total)

    spans = [s for s in tr.spans if s.kind == "bucket_dispatch"]
    assert spans
    for s in spans:
        assert s.attrs["mesh_shape"] == [eng.shards]
        shard_real = s.attrs["shard_real"]
        assert len(shard_real) == eng.shards
        assert sum(shard_real) == s.attrs["real"]
    # the padded tail shards run less real work -> imbalance > 1
    assert eng.stats.last_shard_imbalance > 1.0
    snap = tr.metrics.snapshot()
    assert snap["cohort.shard_imbalance"]["count"] >= 1
    assert eng.stats.shard_pad_clients > 0


# ---------------------------------------------------------------------------
# subprocess fallback: force 8 devices without the special environment
# ---------------------------------------------------------------------------
SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp, numpy as np
    from repro.fl.cohort_engine import CohortEngine

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (32, 16)) * 0.1,
                "b1": jnp.zeros((16,)),
                "w2": jax.random.normal(k2, (16, 10)) * 0.1,
                "b2": jnp.zeros((10,))}

    def apply_fn(p, x):
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    rng = np.random.default_rng(0)
    x = rng.normal(size=(900, 32)).astype(np.float32)
    y = rng.integers(0, 10, size=900)
    pools = [np.arange(k * 30, (k + 1) * 30) for k in range(8)]
    pools.append(np.arange(240, 900))
    total = sum(len(p) for p in pools)

    e_off = CohortEngine(apply_fn, batch_align=8, client_align=4,
                         sharding="off")
    e_mesh = CohortEngine(apply_fn, batch_align=8, client_align=4,
                          sharding="mesh", guard=True)
    assert e_mesh.shards == 8, e_mesh.shards
    p_off, p_mesh = init(jax.random.PRNGKey(0)), init(jax.random.PRNGKey(0))
    for r in range(4):
        c_off = e_off.build(x, y, pools, 3, np.random.default_rng(10 + r),
                            max_batch=16)
        c_mesh = e_mesh.build(x, y, pools, 3, np.random.default_rng(10 + r),
                              max_batch=16)
        p_off, l_off = e_off.round(p_off, c_off, 0.1, total)
        p_mesh, l_mesh = e_mesh.round(p_mesh, c_mesh, 0.1, total)
        np.testing.assert_allclose(l_mesh, l_off, rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(p_off),
                        jax.tree_util.tree_leaves(p_mesh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
    # rounds 2..4 reused round-1 signatures (guard armed: a recompile
    # would have raised); the signature set must have stopped growing
    assert e_mesh.stats.rounds == 4
    assert len(e_mesh.round_signatures) == 1
    print("MESH_COHORT_OK")
""")


@pytest.mark.slow
def test_sharded_cohort_subprocess_8_devices():
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_COHORT_OK" in r.stdout
