"""Tests for stochastic network dynamics and their orchestrator coupling:
reproducibility, realized-vs-analytic latency, churn conservation."""
import numpy as np
import pytest

from repro.core import SAGINOrchestrator, build_default_sagin
from repro.core.network import Satellite
from repro.sim.dynamics import DynamicsConfig, NetworkDynamics, RoundEvents

FULL = DynamicsConfig(isl_outage_prob=0.5, uplink_outage_prob=0.5,
                      uplink_outage_delay=25.0, weather_std=0.4,
                      sat_freq_jitter_std=0.3, churn_prob=0.3)


def sample_trajectory(seed, n_rounds=6):
    dyn = NetworkDynamics(FULL, rng=np.random.default_rng(seed))
    return [dyn.sample_round(r, n_sats=3, n_clusters=2, n_devices=8)
            for r in range(n_rounds)]


def test_identical_seeds_identical_events():
    a, b = sample_trajectory(7), sample_trajectory(7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.sat_freq_scale, y.sat_freq_scale)
        assert x.isl_scale == y.isl_scale
        assert x.rate_scale == y.rate_scale
        assert x.uplink_delays == y.uplink_delays
        assert x.offline_devices == y.offline_devices


def test_different_seeds_differ():
    a, b = sample_trajectory(1), sample_trajectory(2)
    assert any(x.rate_scale != y.rate_scale for x, y in zip(a, b))


def test_spawned_streams_are_independent():
    root = NetworkDynamics(FULL, seed=0)
    c1, c2 = root.spawn(), root.spawn()
    e1 = c1.sample_round(0, 3, 2, 8)
    e2 = c2.sample_round(0, 3, 2, 8)
    assert not np.array_equal(e1.sat_freq_scale, e2.sat_freq_scale)


def test_zero_config_is_quiet():
    dyn = NetworkDynamics(DynamicsConfig(), seed=0)
    ev = dyn.sample_round(0, n_sats=2, n_clusters=2, n_devices=4)
    assert ev.quiet
    assert not DynamicsConfig().any_active()
    assert FULL.any_active()


def test_orchestrator_reproducible_under_dynamics():
    def traj(seed):
        sagin = build_default_sagin(n_devices=6, n_air=2, seed=0)
        orch = SAGINOrchestrator(
            sagin, rng=np.random.default_rng(seed),
            dynamics=NetworkDynamics(FULL, rng=np.random.default_rng(seed)))
        return [r.realized_latency for r in orch.run(5)]

    assert traj(3) == traj(3)
    assert traj(3) != traj(4)


def test_uplink_outage_adds_realized_delay():
    sagin = build_default_sagin(n_devices=6, n_air=2, seed=0)
    cfg = DynamicsConfig(uplink_outage_prob=1.0, uplink_outage_delay=40.0)
    orch = SAGINOrchestrator(sagin, dynamics=NetworkDynamics(cfg, seed=0))
    rec = orch.step(0)
    # every cluster hit by a 40 s dead-air window: realized > analytic
    # unless the space layer dominates the round
    assert rec.realized_latency >= rec.latency
    assert rec.events is not None and rec.events.uplink_delays


def test_isl_outage_stretches_space_bound_round():
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    sagin.n_sat_samples = 50000  # space layer dominates
    sagin.satellites = [Satellite(0, f=1e9, coverage_end=100.0),
                        Satellite(1, f=1e9, coverage_end=np.inf)]
    cfg = DynamicsConfig(isl_outage_prob=1.0, isl_outage_scale=0.1)
    orch = SAGINOrchestrator(sagin, strategy="none",
                             dynamics=NetworkDynamics(cfg, seed=0))
    rec = orch.step(0)
    assert rec.realized_latency > rec.latency


def test_churn_preserves_sample_conservation():
    sagin = build_default_sagin(n_devices=8, n_air=2, seed=0)
    total = sagin.total_samples
    cfg = DynamicsConfig(churn_prob=0.5)
    orch = SAGINOrchestrator(sagin, dynamics=NetworkDynamics(cfg, seed=1))
    offline_seen = False
    for rec in orch.run(5):
        assert (sum(rec.ground_sizes) + sum(rec.air_sizes) + rec.sat_size
                == total)
        offline_seen = offline_seen or bool(rec.offline_devices)
        # stripped plans never move data for offline devices
        for cp in rec.plan.clusters:
            for k in rec.offline_devices:
                assert k not in cp.d_ground_air
                assert k not in cp.d_air_ground
    assert offline_seen


def test_static_satellite_jitter_does_not_compound():
    """With a user-supplied satellite list, per-round compute jitter must
    apply to the nominal frequency, not accumulate round over round."""
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    sagin.satellites = [Satellite(0, f=5e9, coverage_end=np.inf)]
    cfg = DynamicsConfig(sat_freq_jitter_std=0.5)
    orch = SAGINOrchestrator(sagin, dynamics=NetworkDynamics(cfg, seed=0))
    scales = []
    for r in range(30):
        orch.step(r)
        scales.append(sagin.satellites[0].f / 5e9)
    # lognormal(-sigma^2/2, sigma) has mean 1: compounding would drift the
    # product toward 0; independent per-round draws keep it near 1
    assert 0.2 < np.median(scales) < 3.0


def test_markov_probability_edges_are_deterministic():
    # p_fail=0: the chain never leaves the good state
    never = NetworkDynamics(DynamicsConfig(isl_markov=(0.0, 0.5),
                                           isl_outage_scale=0.25), seed=0)
    assert all(never.sample_round(r, 2, 2, 4).isl_scale == 1.0
               for r in range(20))
    # p_fail=1, p_recover=1: strict good/bad alternation from round 0
    flip = NetworkDynamics(DynamicsConfig(isl_markov=(1.0, 1.0),
                                          isl_outage_scale=0.25), seed=0)
    scales = [flip.sample_round(r, 2, 2, 4).isl_scale for r in range(6)]
    assert scales == [0.25, 1.0, 0.25, 1.0, 0.25, 1.0]


def test_markov_validation_rejects_bad_pairs():
    with pytest.raises(ValueError, match="p_recover"):
        DynamicsConfig(isl_markov=(0.5, 0.0))   # absorbing bad state
    with pytest.raises(ValueError, match="pair"):
        DynamicsConfig(uplink_markov=(0.5,))
    with pytest.raises(ValueError, match="p_fail"):
        DynamicsConfig(uplink_markov=(1.5, 0.5))


def test_markov_stationary_outage_fraction():
    # Gilbert-Elliott stationary bad fraction is p_fail/(p_fail+p_recover)
    p_fail, p_recover = 0.2, 0.4
    dyn = NetworkDynamics(DynamicsConfig(isl_markov=(p_fail, p_recover),
                                         isl_outage_scale=0.25), seed=7)
    n = 4000
    bad = sum(dyn.sample_round(r, 2, 2, 4).isl_scale != 1.0
              for r in range(n)) / n
    assert bad == pytest.approx(p_fail / (p_fail + p_recover), abs=0.05)


def test_markov_draw_count_is_state_independent():
    """One uniform per link per round regardless of chain state: two
    chains with different (p_fail, p_recover) consume their RNG streams
    identically, so downstream draws never depend on realized states."""
    cfg_a = DynamicsConfig(isl_markov=(0.9, 0.1), uplink_markov=(0.9, 0.1),
                           churn_prob=0.3)
    cfg_b = DynamicsConfig(isl_markov=(0.1, 0.9), uplink_markov=(0.1, 0.9),
                           churn_prob=0.3)
    a = NetworkDynamics(cfg_a, rng=np.random.default_rng(5))
    b = NetworkDynamics(cfg_b, rng=np.random.default_rng(5))
    for r in range(10):
        ea = a.sample_round(r, 3, 2, 8)
        eb = b.sample_round(r, 3, 2, 8)
        # churn draws come AFTER the chain draws; identical consumption
        # means identical churn trajectories despite different chains
        assert ea.offline_devices == eb.offline_devices


def test_dynamics_state_dict_roundtrip_resumes_mid_burst():
    cfg = DynamicsConfig(isl_markov=(0.3, 0.3), uplink_markov=(0.3, 0.3),
                         weather_std=0.2, churn_prob=0.2)
    a = NetworkDynamics(cfg, seed=9)
    for r in range(7):
        a.sample_round(r, 3, 2, 8)
    snap = a.state_dict()
    b = NetworkDynamics(cfg, seed=123)      # wrong seed: state must win
    b.load_state_dict(snap)
    for r in range(7, 14):
        ea, eb = a.sample_round(r, 3, 2, 8), b.sample_round(r, 3, 2, 8)
        assert ea.isl_scale == eb.isl_scale
        assert ea.rate_scale == eb.rate_scale
        assert ea.uplink_delays == eb.uplink_delays
        assert ea.offline_devices == eb.offline_devices


def test_all_churn_round_keeps_nan_loss_sentinel():
    """churn_prob=1.0 knocks every ground device offline; the dynamics
    report all of them and the orchestrator still conserves samples."""
    sagin = build_default_sagin(n_devices=6, n_air=2, seed=0)
    total = sagin.total_samples
    orch = SAGINOrchestrator(
        sagin, dynamics=NetworkDynamics(DynamicsConfig(churn_prob=1.0),
                                        seed=0))
    rec = orch.step(0)
    assert len(rec.offline_devices) == 6
    assert sum(rec.ground_sizes) + sum(rec.air_sizes) + rec.sat_size == total


def test_quiet_events_leave_latency_untouched():
    sagin = build_default_sagin(n_devices=4, n_air=1, seed=0)
    orch = SAGINOrchestrator(
        sagin, dynamics=NetworkDynamics(DynamicsConfig(), seed=0))
    rec = orch.step(0)
    assert rec.realized_latency == rec.latency
    assert isinstance(rec.events, RoundEvents) and rec.events.quiet
