"""Resilience benchmark: chaos completion, recovery economics, and the
degradation curve (``repro.resilience``).

Three claims, two of them GATED (a failing gate fails the module, so a
regression can never silently become a committed perf baseline):

1. **Chaos completion** (gate): the engine runs the ``chaos`` scenario
   preset — every fault kind injected against bursty Gilbert-Elliott
   outages — to completion with a FINITE global model, and every
   injected in-round fault recovers.
2. **Recovered handover beats restart** (gate): for a mid-coverage
   satellite loss, the re-planned unplanned handover
   (``core.handover.replan_after_loss`` — truncate the active leg,
   hand the *unprocessed remainder* to the successor) must cost less
   simulated time than the naive alternative of restarting the whole
   space computation from scratch on the successor.
3. **Degradation curve** (measurement): engine wall-clock and final
   accuracy across increasing ``FaultPlan.generate`` fault rates —
   how gracefully training degrades as failures multiply.

Rows land in ``BENCH_resilience.json`` via ``benchmarks.run --json``.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from .common import FULL, row

def _smoke() -> bool:
    # read lazily: benchmarks.run sets the env var AFTER importing us
    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


def _chaos_config():
    from repro.fl.rounds import FLConfig
    return FLConfig(
        n_devices=12, n_air=2,
        train_fraction=0.05 if FULL else 0.01,
        eval_size=512 if FULL else 64,
        h_local=3, execution="sequential", seed=0)


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(tree))


def bench_chaos_completion() -> bool:
    """Gate 1: the chaos preset completes with a finite global model."""
    from repro.sim.engine import SAGINEngine

    n_rounds = 4 if _smoke() else 6
    engine = SAGINEngine("chaos", fl=_chaos_config())
    t0 = time.perf_counter()
    engine.run(n_rounds)
    wall = time.perf_counter() - t0
    inj = engine.fault_injector
    finite = engine.global_params is not None and _finite(
        engine.global_params)
    # in-round faults must all be absorbed; isl_partition recovery
    # legitimately fails when the quorum collapses, so it is not gated
    in_round = ("sat_loss", "straggler", "nan_update", "trainer_crash")
    absorbed = all(inj.recovered[k] >= inj.injected[k] for k in in_round)
    ok = finite and absorbed
    row("resilience.chaos_complete", wall * 1e6,
        f"finite={finite} rounds={n_rounds} "
        f"injected={sum(inj.injected.values())} "
        f"recovered={sum(inj.recovered.values())}",
        metrics={"injected": dict(inj.injected),
                 "recovered": dict(inj.recovered),
                 "merges": len(engine.merges),
                 "gate": "finite global model + all in-round faults "
                         "recovered", "ok": ok})
    return ok


def bench_recovery_vs_restart() -> bool:
    """Gate 2: unplanned-handover recovery beats restart-from-scratch."""
    from repro.core.handover import replan_after_loss, space_schedule
    from repro.core.network import build_default_sagin
    from repro.core.scheduler import SAGINOrchestrator
    from repro.core.constellation import WalkerStar

    sagin = build_default_sagin(n_devices=10, n_air=2, seed=0)
    orch = SAGINOrchestrator(sagin, constellation=WalkerStar(),
                             sat_f_seed=0)
    orch._refresh_satellites()
    n = max(2000.0, float(sagin.n_sat_samples) or 2000.0)
    schedule = space_schedule(n, sagin)
    loss_t = 0.5 * schedule.total_latency
    t0 = time.perf_counter()
    recovered, restart = replan_after_loss(schedule, loss_t, sagin)
    us = (time.perf_counter() - t0) * 1e6
    gain = restart - recovered.total_latency
    ok = recovered.total_latency < restart
    row("resilience.replan_vs_restart", us,
        f"recovered_s={recovered.total_latency:.1f} "
        f"restart_s={restart:.1f} gain_s={gain:.1f}",
        metrics={"recovered_s": recovered.total_latency,
                 "restart_s": restart, "gain_s": gain,
                 "gate": "recovered < restart", "ok": ok})
    return ok


def bench_degradation_curve() -> None:
    """Measurement: wall-clock + accuracy vs fault rate (not gated)."""
    import dataclasses

    from repro.resilience import FaultPlan
    from repro.scenarios.registry import SCENARIOS, get_scenario, register
    from repro.sim.engine import SAGINEngine

    n_rounds = 3 if _smoke() else 6
    rates = (0.0, 0.1) if _smoke() else (0.0, 0.1, 0.3)
    base = get_scenario("chaos")
    cfg = _chaos_config()
    for rate in rates:
        plan = (None if rate == 0.0 else FaultPlan.generate(
            seed=7, n_rounds=n_rounds, n_regions=len(base.regions),
            rates={k: rate for k in ("sat_loss", "straggler",
                                     "nan_update")}))
        name = f"chaos@{rate:g}"
        SCENARIOS.pop(name, None)
        register(dataclasses.replace(base, name=name, faults=plan))
        try:
            engine = SAGINEngine(name, fl=cfg)
            t0 = time.perf_counter()
            engine.run(n_rounds)
            wall = time.perf_counter() - t0
        finally:
            SCENARIOS.pop(name, None)
        accs = [res.accuracies[-1]
                for res in engine.fl_results.values() if res.accuracies]
        sim_end = max(t.wall_clock for t in engine.trainers)
        inj = engine.fault_injector
        row(f"resilience.degradation.rate{rate:g}", wall * 1e6,
            f"sim_end_s={sim_end:.1f} "
            f"mean_final_acc={sum(accs) / len(accs):.3f} "
            f"faults={sum(inj.injected.values()) if inj else 0}",
            metrics={"fault_rate": rate, "sim_end_s": sim_end,
                     "final_accs": [round(a, 4) for a in accs],
                     "injected": (dict(inj.injected) if inj else {})})


def main() -> int:
    ok = bench_chaos_completion()
    ok = bench_recovery_vs_restart() and ok
    bench_degradation_curve()
    if not ok:
        print("# resilience gate FAILED", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
