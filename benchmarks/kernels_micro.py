"""Micro-benchmarks of the jitted kernel wrappers (CPU oracle path; the
Pallas TPU path is compile-validated in interpret mode by the test suite).
Derived column reports achieved GB/s or GFLOP/s on this host."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedavg_agg import ops as agg_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.wkv6 import ops as wkv_ops

from .common import row, timeit


def main():
    rng = np.random.default_rng(0)
    # fedavg_agg: 8 clients x 4M params
    x = jnp.asarray(rng.normal(size=(8, 4_000_000)).astype(np.float32))
    w = jnp.ones((8,), jnp.float32) / 8

    agg = jax.jit(agg_ops.weighted_aggregate)
    agg(x, w).block_until_ready()
    us = timeit(lambda: agg(x, w).block_until_ready(), n=5)
    gbs = x.nbytes / (us * 1e-6) / 1e9
    row("kernel_fedavg_agg_8x4M", us, f"GB/s={gbs:.1f}")

    # flash attention (blocked path), B1 H4 S4096 D64
    q = jnp.asarray(rng.normal(size=(1, 4, 4096, 64)).astype(np.float32))
    fa = jax.jit(lambda q: fa_ops.attention(q, q, q))
    fa(q).block_until_ready()
    us = timeit(lambda: fa(q).block_until_ready(), n=3)
    flops = 4 * 4 * 4096 * 4096 * 64 / 2  # causal
    row("kernel_flash_attn_s4096", us, f"GFLOP/s={flops/(us*1e-6)/1e9:.1f}")

    # wkv6: B1 H8 T1024 D64
    r, k, v = (jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(
        np.float32)) for _ in range(3))
    wdec = jnp.asarray(rng.uniform(0.9, 0.999, size=(1, 8, 1024, 64)).astype(
        np.float32))
    u = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    wkv = jax.jit(wkv_ops.wkv)
    wkv(r, k, v, wdec, u).block_until_ready()
    us = timeit(lambda: wkv(r, k, v, wdec, u).block_until_ready(), n=3)
    row("kernel_wkv6_t1024", us, f"tokens/s={1024/(us*1e-6):.0f}")


if __name__ == "__main__":
    main()
