"""Beyond-paper synthesis: the assigned architectures as FL payloads.

The paper's latency model is parameterized by the payload's model size
Q(w) (handover + model-upload delays, eqs. 7/14) and per-sample compute m.
This benchmark plugs every assigned architecture's analytic Q(w) and a
compute cost scaled by its *active* parameter count into the SAGIN round
optimizer, and reports (i) the optimized round latency, (ii) how the data
placement responds, (iii) when the model gets too big to handover within a
coverage window — the regime where the paper's seamless-handover design
breaks down and pure ground/air FL wins.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import build_default_sagin, optimize_offloading
from repro.core.latency import handover_delay

from .common import row

# cycles/sample for the paper's CNN (3e9) scaled by active params relative
# to the paper's ~1M-param payloads (kept within a sane envelope)
PAPER_M = 3e9
PAPER_PARAMS = 1e6


def main():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        q_w = cfg.param_count() * 16.0          # bf16 bits
        m = PAPER_M * min(cfg.active_param_count() / PAPER_PARAMS, 1e4)
        sagin = build_default_sagin(n_devices=10, n_air=2, seed=0,
                                    model_bits=q_w)
        for d in sagin.devices:
            d.m = m
        for a in sagin.air_nodes:
            a.m = m
        for s in sagin.satellites:
            s.m = m
        plan = optimize_offloading(sagin)
        # model handover feasibility: can Q(w) cross the ISL within a
        # typical coverage window (~450 s from the Walker-Star geometry)?
        hand = handover_delay(q_w, sagin.q_bits, 0, sagin.z_isl)
        g, a, s = plan.new_sizes(sagin)
        total = max(1.0, sum(g) + sum(a) + s)
        row(f"flpayload_{arch}", 0.0,
            f"Qw_GB={q_w/8e9:.1f};model_handover_s={hand:.0f};"
            f"handover_fits_450s_window={hand < 450};"
            f"space_share={s/total:.2f};"
            f"speedup_vs_no_offload="
            f"{plan.baseline_latency/max(plan.round_latency,1e-9):.2f}x")


if __name__ == "__main__":
    main()
