"""Theorem 1: the analytic bound vs an empirical FL run.

Evaluates the RHS of eq. (38) for the settings of a short run and checks
it (a) decays with R, (b) upper-bounds the observed squared-gradient trend
qualitatively (loss decreases while the bound is nontrivial)."""
from __future__ import annotations

import numpy as np

from repro.core.convergence import (ConvergenceConfig, constant_lr,
                                    theorem1_bound)
from repro.fl import FLConfig, run_fl

from .common import fl_common, row


def main():
    # analytic bound curve
    for r_tot in (10, 100, 1000):
        c = ConvergenceConfig(smoothness=10.0, sigma_g=1.0,
                              c_r=[1.0] * r_tot, delta_r=[1.0] * r_tot,
                              h_local=5, f0_minus_fstar=2.3)
        eta = constant_lr(5, r_tot)
        b = theorem1_bound(c, [eta] * r_tot, [0.1] * r_tot)
        row(f"thm1_bound_R{r_tot}", 0.0, f"bound={b:.4f}")
    # empirical: loss decreases under the adaptive scheme
    res = run_fl(FLConfig(dataset="mnist", strategy="adaptive",
                          **fl_common(n_rounds=5)))
    dec = res.losses[-1] < res.losses[0]
    row("thm1_empirical_loss_decreases", 0.0,
        f"loss0={res.losses[0]:.3f};lossR={res.losses[-1]:.3f};holds={dec}")


if __name__ == "__main__":
    main()
