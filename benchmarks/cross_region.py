"""Cross-region hierarchical FL benchmark: global merge vs independence.

Event-steps the full ``multi_region`` training engine twice — once with
the scenario's staleness-aware global merge over the ISLs, once with
merging disabled (independent per-region models) — and reports:

* wall time per engine round in both modes (the merge's compute cost),
* the simulated ISL overhead the merges add to the regions' clocks,
* final shared-eval accuracy of the global model vs the best and mean
  independent region model (the accuracy return on the ISL traffic).

    PYTHONPATH=src python -m benchmarks.cross_region [--smoke]
        [--rounds N] [--regions R] [--merge-every K]

``--smoke`` (or REPRO_BENCH_SMOKE=1) shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import row, timeit  # noqa: E402


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.data import make_dataset
    from repro.fl import FLConfig
    from repro.fl.client import evaluate, stacked_evaluate
    from repro.scenarios import get_scenario
    from repro.sim import SAGINEngine

    ap = argparse.ArgumentParser()
    smoke_env = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    ap.add_argument("--smoke", action="store_true", default=smoke_env,
                    help="tiny sizes for CI")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--regions", type=int, default=None)
    ap.add_argument("--merge-every", type=int, default=None,
                    help="override the merge cadence (0 disables merging)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        rounds, n_regions, fraction, devices = 2, 2, 0.005, 3
    else:
        rounds, n_regions, fraction, devices = 6, 4, 0.01, 4
    rounds = args.rounds if args.rounds is not None else rounds
    n_regions = args.regions if args.regions is not None else n_regions

    scn = get_scenario("multi_region")
    scn = dataclasses.replace(scn, regions=scn.regions[:n_regions])
    if args.merge_every is not None:
        scn = dataclasses.replace(scn, merge_every=args.merge_every or None)
    cfg = FLConfig(dataset="mnist", n_devices=devices, n_air=1, h_local=2,
                   train_fraction=fraction, eval_size=128, seed=0)
    tag = f"{n_regions}rx{rounds}"

    engines = {}

    def run_mode(merge_every):
        eng = SAGINEngine(dataclasses.replace(scn, merge_every=merge_every),
                          fl=cfg)
        eng.run(rounds)
        return eng

    us_global = timeit(lambda: engines.setdefault(
        "global", run_mode(scn.merge_every)), n=1, warmup=0)
    us_indep = timeit(lambda: engines.setdefault(
        "indep", run_mode(None)), n=1, warmup=0)
    total_rounds = rounds * n_regions
    isl_overhead = sum(sum(m.isl_costs) for m in engines["global"].merges)
    row(f"cross_region.global_{tag}", us_global,
        f"us_per_round={us_global / total_rounds:.0f};"
        f"merges={len(engines['global'].merges)};"
        f"isl_overhead_s={isl_overhead:.1f}")
    row(f"cross_region.independent_{tag}", us_indep,
        f"us_per_round={us_indep / total_rounds:.0f}")

    # shared eval: a fresh sample draw of the same task, unseen by any
    # region, scoring the one global model against every independent one
    g_params = engines["global"].global_params
    if g_params is None:  # --merge-every 0: nothing global to score
        row(f"cross_region.shared_eval_{tag}", 0.0, "merging_disabled")
        return 0
    ds = make_dataset("mnist", seed=cfg.seed, train_fraction=0.02,
                      sample_seed=10 ** 6)
    n_eval = 512 if args.smoke else 1024
    x = jnp.asarray(ds.x_test[:n_eval])
    y = jnp.asarray(ds.y_test[:n_eval])
    apply_fn = engines["global"].trainers[0].apply_fn
    _, g_acc = evaluate(apply_fn, g_params, x, y)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[t.params for t in engines["indep"].trainers])
    _, ind = stacked_evaluate(apply_fn, stacked, x, y)
    best, mean = float(jnp.max(ind)), float(jnp.mean(ind))
    row(f"cross_region.shared_eval_{tag}", 0.0,
        f"global_acc={float(g_acc):.3f};best_indep={best:.3f};"
        f"mean_indep={mean:.3f}")
    if not args.smoke and float(g_acc) < best:
        print(f"cross_region: global model acc {float(g_acc):.3f} below "
              f"best independent {best:.3f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
