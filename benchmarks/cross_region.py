"""Cross-region hierarchical FL benchmark: federation-policy sweep.

Part 1 (since PR 3): event-steps the full ``multi_region`` training
engine twice — once with the scenario's synchronous staleness-aware
global merge over the ISLs, once with merging disabled (independent
per-region models) — and reports wall time per engine round, the
simulated ISL overhead, and the shared-eval accuracy return on the ISL
traffic.

Part 2 (PR 5): the federation-policy sweep.  Runs ``synchronous`` vs
``soft_async`` vs ``partial`` (``repro.fl.federation``) on the
``degraded_links`` dynamics stretched across the ``multi_region``
continents and reports each policy's TIME-TO-TARGET-LOSS: the earliest
simulated wall-clock at which EVERY region's train loss has reached the
loosest loss any policy achieves (so the target is reachable by all).
Under hostile ISLs the barrier policy drags every region to the slowest
clock, while soft/partial merges keep regions off the barrier — the
sweep quantifies that gap and gates on it (non-smoke).  Rows feed the
``BENCH_federation.json`` artifact via ``benchmarks.run --json``.

    PYTHONPATH=src python -m benchmarks.cross_region [--smoke]
        [--rounds N] [--regions R] [--merge-every K]

``--smoke`` (or REPRO_BENCH_SMOKE=1) shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import row, timeit  # noqa: E402

SWEEP_POLICIES = ("synchronous", "soft_async", "partial")


def _best_reachable_loss(results) -> float:
    """Loosest train loss this run pins down: the worst, over regions,
    of each region's best (minimum) participated-round loss."""
    worst = 0.0
    for res in results.values():
        finite = [l for l, p in zip(res.losses, res.participated) if p]
        if not finite:
            return float("inf")
        worst = max(worst, min(finite))
    return worst


def _time_to_loss(results, target: float) -> float:
    """Earliest wall-clock at which EVERY region's train loss has
    reached ``target`` (inf when any region never does)."""
    worst = 0.0
    for res in results.values():
        hit = None
        for t, loss, part in zip(res.times, res.losses, res.participated):
            if part and loss <= target:
                hit = t
                break
        if hit is None:
            return float("inf")
        worst = max(worst, hit)
    return worst


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.data import make_dataset
    from repro.fl import FLConfig
    from repro.fl.client import evaluate, stacked_evaluate
    from repro.fl.federation import FederationConfig
    from repro.scenarios import get_scenario
    from repro.sim import SAGINEngine

    ap = argparse.ArgumentParser()
    smoke_env = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    ap.add_argument("--smoke", action="store_true", default=smoke_env,
                    help="tiny sizes for CI")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--regions", type=int, default=None)
    ap.add_argument("--merge-every", type=int, default=None,
                    help="override the merge cadence (0 disables merging)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        rounds, n_regions, fraction, devices = 2, 2, 0.005, 3
    else:
        rounds, n_regions, fraction, devices = 6, 4, 0.01, 4
    rounds = args.rounds if args.rounds is not None else rounds
    n_regions = args.regions if args.regions is not None else n_regions

    scn = get_scenario("multi_region")
    scn = dataclasses.replace(scn, regions=scn.regions[:n_regions])
    if args.merge_every is not None:
        fed = (None if args.merge_every == 0 else dataclasses.replace(
            scn.resolved_federation() or FederationConfig(),
            every=args.merge_every))
        # merge_every=None too: a legacy base scenario must not resurrect
        # its deprecated cadence through resolved_federation()
        scn = dataclasses.replace(scn, federation=fed, merge_every=None)
    cfg = FLConfig(dataset="mnist", n_devices=devices, n_air=1, h_local=2,
                   train_fraction=fraction, eval_size=128, seed=0)
    tag = f"{n_regions}rx{rounds}"

    engines = {}

    def run_mode(federation):
        eng = SAGINEngine(dataclasses.replace(scn, federation=federation),
                          fl=cfg)
        eng.run(rounds)
        return eng

    us_global = timeit(lambda: engines.setdefault(
        "global", run_mode(scn.federation)), n=1, warmup=0)
    us_indep = timeit(lambda: engines.setdefault(
        "indep", run_mode(None)), n=1, warmup=0)
    total_rounds = rounds * n_regions
    isl_overhead = sum(sum(m.isl_costs) for m in engines["global"].merges)
    row(f"cross_region.global_{tag}", us_global,
        f"us_per_round={us_global / total_rounds:.0f};"
        f"merges={len(engines['global'].merges)};"
        f"isl_overhead_s={isl_overhead:.1f}")
    row(f"cross_region.independent_{tag}", us_indep,
        f"us_per_round={us_indep / total_rounds:.0f}")

    # ---- federation-policy sweep under degraded links ---------------------
    # multi_region geography x degraded_links dynamics: frequent ISL
    # fades are exactly the regime where barrier merges stall and the
    # async/partial policies should win on time-to-target-loss.
    sweep_scn = dataclasses.replace(
        get_scenario("degraded_links"), name="degraded_links_multi",
        regions=scn.regions, horizon=scn.horizon)
    # Shorter simulated rounds (smaller per-region datasets) and more
    # boundaries: the policies differ in per-boundary overhead (barrier
    # waits + round-trip tolls vs one-way fetches vs quorum skips), so
    # the sweep runs the regime where that overhead is a visible
    # fraction of the round clock.  Cadence 2 keeps the policies
    # statistically comparable (same merge information flow per round
    # pair); going to every=1 instead rewards the barrier's stronger
    # per-round mixing and measures learning dynamics, not overhead.
    sweep_rounds = 4 if args.smoke else max(rounds, 8)
    sweep_cfg = dataclasses.replace(cfg, train_fraction=fraction / 2)
    half_life = 1200.0
    sweep = {}
    # After the first policy run every compiled step should be cached:
    # trajectories diverge across policies (merge barriers shift the
    # wall clock, which shifts satellite chains, plans, and pool
    # widths), so a BOUNDED number of fresh cohort shapes is
    # legitimate — but a recompile-per-round regression scales as
    # rounds x regions x nodes and blows through this ceiling, failing
    # the lane with a ContractViolation.
    from repro.analysis import contracts
    warm_budget = 2 * (sweep_cfg.batch_cap + 24)
    for i, pol in enumerate(SWEEP_POLICIES):
        fed = FederationConfig(policy=pol, every=2, topology="ring",
                               half_life=half_life, quorum=0.5)

        def _run(f=fed, p=pol):
            return sweep.setdefault(
                p, run_mode_scn(sweep_scn, f, sweep_cfg, sweep_rounds))

        if i == 0:          # cold run: compiles freely
            us = timeit(_run, n=1, warmup=0)
        else:
            with contracts.no_recompile(allow=warm_budget,
                                        label=f"federation sweep: {pol}"):
                us = timeit(_run, n=1, warmup=0)
        sweep[pol + "_us"] = us
    target = max(_best_reachable_loss(sweep[p].fl_results)
                 for p in SWEEP_POLICIES)
    sweep_tag = f"{n_regions}rx{sweep_rounds}"  # the sweep's OWN config
    times_to_loss = {}
    for pol in SWEEP_POLICIES:
        eng = sweep[pol]
        tt = _time_to_loss(eng.fl_results, target)
        times_to_loss[pol] = tt
        isl = sum(sum(m.isl_costs) for m in eng.merges)
        row(f"federation.{pol}_{sweep_tag}", sweep[pol + "_us"],
            f"time_to_loss_s={tt:.0f};target_loss={target:.4f};"
            f"merges={len(eng.merges)};isl_overhead_s={isl:.1f}")

    # shared eval: a fresh sample draw of the same task, unseen by any
    # region, scoring the one global model against every independent one
    g_params = engines["global"].global_params
    if g_params is None:  # --merge-every 0: nothing global to score
        row(f"cross_region.shared_eval_{tag}", 0.0, "merging_disabled")
        return 0
    ds = make_dataset("mnist", seed=cfg.seed, train_fraction=0.02,
                      sample_seed=10 ** 6)
    n_eval = 512 if args.smoke else 1024
    x = jnp.asarray(ds.x_test[:n_eval])
    y = jnp.asarray(ds.y_test[:n_eval])
    apply_fn = engines["global"].trainers[0].apply_fn
    _, g_acc = evaluate(apply_fn, g_params, x, y)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs),
        *[t.params for t in engines["indep"].trainers])
    _, ind = stacked_evaluate(apply_fn, stacked, x, y)
    best, mean = float(jnp.max(ind)), float(jnp.mean(ind))
    row(f"cross_region.shared_eval_{tag}", 0.0,
        f"global_acc={float(g_acc):.3f};best_indep={best:.3f};"
        f"mean_indep={mean:.3f}")
    if not args.smoke:
        if float(g_acc) < best:
            print(f"cross_region: global model acc {float(g_acc):.3f} "
                  f"below best independent {best:.3f}", file=sys.stderr)
            return 1
        tt_sync = times_to_loss["synchronous"]
        lagging = [p for p in ("soft_async", "partial")
                   if not times_to_loss[p] < tt_sync
                   or math.isinf(times_to_loss[p])]
        if lagging:
            print(f"cross_region: {lagging} did not beat synchronous "
                  f"time-to-target-loss {tt_sync:.0f}s "
                  f"({ {p: round(times_to_loss[p]) for p in SWEEP_POLICIES} })",
                  file=sys.stderr)
            return 1
    return 0


def run_mode_scn(scenario, federation, cfg, rounds):
    """Run one policy variant of the sweep scenario to completion."""
    import dataclasses as _dc

    from repro.sim import SAGINEngine
    eng = SAGINEngine(_dc.replace(scenario, federation=federation), fl=cfg)
    eng.run(rounds)
    return eng


if __name__ == "__main__":
    sys.exit(main())
