"""Section IV-D: optimizer complexity scaling.

Measures wall time of the offloading optimizer vs |G_n| and |A| and checks
the (log-factor-dominated) near-linear scaling in the node counts."""
from __future__ import annotations

import time

import numpy as np

from repro.core import build_default_sagin, optimize_offloading

from .common import row, timeit


def main():
    times = {}
    for n_dev, n_air in [(5, 1), (10, 2), (20, 4), (40, 8)]:
        sagin = build_default_sagin(n_devices=n_dev, n_air=n_air, seed=0)
        us = timeit(lambda: optimize_offloading(sagin), n=3)
        times[(n_dev, n_air)] = us
        row(f"complexity_K{n_dev}_N{n_air}", us,
            f"per_device_us={us / n_dev:.0f}")
    # near-linear: 8x nodes should cost < 32x time (log factors allowed)
    ratio = times[(40, 8)] / times[(5, 1)]
    row("complexity_scaling", 0.0, f"t(40)/t(5)={ratio:.1f};subquadratic="
        f"{ratio < 32}")


if __name__ == "__main__":
    main()
