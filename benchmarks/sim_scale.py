"""Scenario-engine scale benchmark: vectorized propagation vs seed loop.

Default configuration is the acceptance scale — a 1080-satellite shell
covering 4 regions — where the batched ``(n_regions, n_times, n_sats)``
propagation/coverage path must beat the seed's per-satellite,
per-region Python loop by >= 10x.  Also times an event-stepped
multi-region engine run over the ``multi_region`` scenario preset.

    PYTHONPATH=src python -m benchmarks.sim_scale [--sats N] [--regions R]
        [--t-end SECONDS] [--smoke]

``--smoke`` (or REPRO_BENCH_SMOKE=1) shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import row, timeit, timeit_min  # noqa: E402
from repro.core.constellation import WalkerStar  # noqa: E402
from repro.scenarios import get_scenario  # noqa: E402
from repro.sim import SAGINEngine  # noqa: E402
from repro.sim.propagation import (Region, access_intervals_loop,  # noqa: E402
                                   access_intervals_multi)

REGIONS = (Region("indiana", 40.0, -86.0), Region("nairobi", -1.3, 36.8),
           Region("reykjavik", 64.1, -21.9), Region("sydney", -33.9, 151.2))


def propagation_speedup(n_sats: int, n_regions: int, t_end: float,
                        dt: float = 10.0, reps: int = 5) -> float:
    # closest divisor of n_sats to ~40 satellites per plane
    planes = min((p for p in range(1, n_sats + 1) if n_sats % p == 0),
                 key=lambda p: abs(n_sats // p - 40))
    ws = WalkerStar(n_sats=n_sats, n_planes=planes, altitude=550e3,
                    inclination_deg=53.0)
    regions = REGIONS[:n_regions]
    tag = f"{n_sats}x{n_regions}"

    def loop():
        return [access_intervals_loop(ws, r.lat_deg, r.lon_deg, t_end=t_end,
                                      dt=dt,
                                      min_elevation_deg=r.min_elevation_deg)
                for r in regions]

    def vec():
        return access_intervals_multi(ws, regions, t_end=t_end, dt=dt)

    # equivalence guard: identical windows before timing anything
    ref, got = loop(), vec()
    for r, ivs in zip(regions, ref):
        vs = got[r.name]
        assert len(ivs) == len(vs), (r.name, len(ivs), len(vs))
        assert all(a.sat == b.sat and a.start == b.start and a.end == b.end
                   for a, b in zip(ivs, vs)), r.name

    us_loop = timeit_min(loop, n=reps, warmup=1)
    us_vec = timeit_min(vec, n=reps, warmup=1)
    speedup = us_loop / us_vec
    row(f"sim_scale.loop_{tag}", us_loop)
    row(f"sim_scale.vectorized_{tag}", us_vec, f"speedup={speedup:.1f}x")
    return speedup


def engine_throughput(n_rounds: int, n_devices: int) -> None:
    scn = get_scenario("multi_region")

    def run():
        eng = SAGINEngine(scn, seed=0, n_devices=n_devices, n_air=2)
        eng.run(n_rounds)
        return eng

    us = timeit(run, n=1, warmup=0)
    total_rounds = n_rounds * len(scn.regions)
    row("sim_scale.engine_multi_region", us,
        f"rounds={total_rounds};us_per_round={us / total_rounds:.0f}")


def main() -> int:
    ap = argparse.ArgumentParser()
    smoke_env = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    ap.add_argument("--sats", type=int, default=None)
    ap.add_argument("--regions", type=int, default=None)
    ap.add_argument("--t-end", type=float, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--smoke", action="store_true", default=smoke_env,
                    help="tiny sizes for CI")
    args, _ = ap.parse_known_args()
    if args.smoke:
        n_sats, n_regions, t_end, rounds, devices = 60, 2, 1800.0, 2, 4
    else:
        n_sats, n_regions, t_end, rounds, devices = 1080, 4, 3600.0, 5, 10
    n_sats = args.sats if args.sats is not None else n_sats
    n_regions = args.regions if args.regions is not None else n_regions
    t_end = args.t_end if args.t_end is not None else t_end
    rounds = args.rounds if args.rounds is not None else rounds

    speedup = propagation_speedup(n_sats, n_regions, t_end)
    engine_throughput(rounds, devices)
    if not args.smoke and speedup < 10.0:
        # return instead of sys.exit: benchmarks.run must survive one
        # module's failure and keep printing the remaining rows
        print(f"sim_scale: speedup {speedup:.1f}x below the 10x target",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
