"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_FULL=1 for the
paper-scale settings (50 devices, full datasets, 30 rounds).

Artifact mode (``--json``) additionally writes machine-readable perf
baselines so every PR's numbers are comparable against the previous
ones:

* ``BENCH_cohort.json`` — rows from ``cohort_scaling``,
  ``obs_overhead`` (the <2% disabled-tracing gate; rows carry
  ``repro.obs`` metrics snapshots) and ``fl_payload_scaling`` when it
  ran: the FL round-engine trajectory.
* ``BENCH_sim.json``    — rows from ``sim_scale`` (and
  ``handover_dynamics`` when it ran): the propagation/engine trajectory.
* ``BENCH_federation.json`` — rows from ``cross_region``: the
  federation-policy sweep (synchronous vs soft_async vs partial
  time-to-target-loss under degraded ISLs) plus the global-vs-
  independent merge comparison.
* ``BENCH_resilience.json`` — rows from ``resilience``: chaos-preset
  completion (gated: finite global model, in-round faults recovered),
  unplanned-handover recovery vs restart-from-scratch (gated:
  recovery wins), and the fault-rate degradation curve.

``--smoke`` shrinks every module to CI sizes (exports
``REPRO_BENCH_SMOKE=1``) and restricts the run to the artifact-feeding
modules, which is what the CI bench-smoke lane executes:

    PYTHONPATH=src python -m benchmarks.run --json --smoke

``--only NAME [NAME ...]`` selects modules explicitly in either mode.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

from .common import drain_rows, write_bench_json

# module name -> BENCH artifact it feeds (None: CSV only)
ARTIFACT_OF = {
    "cohort_scaling": "BENCH_cohort.json",
    "fl_payload_scaling": "BENCH_cohort.json",
    "obs_overhead": "BENCH_cohort.json",
    "sim_scale": "BENCH_sim.json",
    "handover_dynamics": "BENCH_sim.json",
    "cross_region": "BENCH_federation.json",
    "resilience": "BENCH_resilience.json",
    "serve": "BENCH_serve.json",
}
SMOKE_MODULES = ("sim_scale", "cohort_scaling", "cross_region",
                 "obs_overhead", "resilience", "serve")


def _modules():
    from . import (cohort_scaling, complexity, convergence_bound,
                   cross_region, fig4_time_to_accuracy,
                   fig5_compute_ablation, fig6_alpha_sweep, fig7_pathloss,
                   fl_payload_scaling, handover_dynamics, kernels_micro,
                   obs_overhead, resilience, roofline_report, serve,
                   sim_scale)
    return [
        ("sim_scale", sim_scale),
        ("cross_region", cross_region),
        ("cohort_scaling", cohort_scaling),
        ("obs_overhead", obs_overhead),
        ("resilience", resilience),
        ("serve", serve),
        ("fig5_compute_ablation", fig5_compute_ablation),
        ("handover_dynamics", handover_dynamics),
        ("fl_payload_scaling", fl_payload_scaling),
        ("complexity", complexity),
        ("convergence_bound", convergence_bound),
        ("kernels_micro", kernels_micro),
        ("fig4_time_to_accuracy", fig4_time_to_accuracy),
        ("fig6_alpha_sweep", fig6_alpha_sweep),
        ("fig7_pathloss", fig7_pathloss),
        ("roofline_report", roofline_report),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_*.json perf artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes; runs only the artifact modules")
    ap.add_argument("--only", nargs="+", default=None,
                    help="run only these modules")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_*.json artifacts")
    args = ap.parse_args()

    modules = _modules()
    known = [name for name, _ in modules]
    selected = args.only or (list(SMOKE_MODULES) if args.smoke else known)
    unknown = sorted(set(selected) - set(known))
    if unknown:
        ap.error(f"unknown modules {unknown}; available: {known}")
    modules = [(n, m) for n, m in modules if n in selected]

    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    # module mains parse sys.argv themselves; hide the driver's flags
    sys.argv = [sys.argv[0]]

    print("name,us_per_call,derived")
    failures = []
    rows_by_module = {}
    drain_rows()
    for name, mod in modules:
        ok = True
        try:
            rc = mod.main()
            if rc:
                ok = False
        except Exception:
            ok = False
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
        rows = drain_rows()
        if ok:
            rows_by_module[name] = rows
        else:
            # a failed module's partial rows (or below-gate numbers) must
            # not become a committed perf baseline
            failures.append(name)
            print(f"# dropping {len(rows)} row(s) of failed module {name} "
                  f"from artifacts", flush=True)

    if args.json:
        os.makedirs(args.out_dir, exist_ok=True)
        for target in ("BENCH_cohort.json", "BENCH_sim.json",
                       "BENCH_federation.json", "BENCH_resilience.json",
                       "BENCH_serve.json"):
            feeders = [n for n, _ in _modules()
                       if ARTIFACT_OF.get(n) == target]
            ran = [n for n in feeders if n in rows_by_module]
            if not ran:
                # never clobber a committed baseline with an empty doc
                # when the selection excluded every feeding module
                print(f"# skipping {target}: none of {feeders} ran",
                      flush=True)
                continue
            rows = [r for n in ran for r in rows_by_module[n]]
            write_bench_json(os.path.join(args.out_dir, target), rows,
                             smoke=args.smoke)

    if failures:
        print(f"# failed modules: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
