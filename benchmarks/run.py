"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_FULL=1 for the
paper-scale settings (50 devices, full datasets, 30 rounds).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from . import (cohort_scaling, complexity, convergence_bound,
                   cross_region, fig4_time_to_accuracy,
                   fig5_compute_ablation, fig6_alpha_sweep, fig7_pathloss,
                   fl_payload_scaling, handover_dynamics, kernels_micro,
                   roofline_report, sim_scale)
    modules = [
        ("sim_scale", sim_scale),
        ("cross_region", cross_region),
        ("cohort_scaling", cohort_scaling),
        ("fig5_compute_ablation", fig5_compute_ablation),
        ("handover_dynamics", handover_dynamics),
        ("fl_payload_scaling", fl_payload_scaling),
        ("complexity", complexity),
        ("convergence_bound", convergence_bound),
        ("kernels_micro", kernels_micro),
        ("fig4_time_to_accuracy", fig4_time_to_accuracy),
        ("fig6_alpha_sweep", fig6_alpha_sweep),
        ("fig7_pathloss", fig7_pathloss),
        ("roofline_report", roofline_report),
    ]
    failures = []
    for name, mod in modules:
        try:
            mod.main()
        except Exception:
            failures.append(name)
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
