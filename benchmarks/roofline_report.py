"""Aggregates the dry-run JSON records into the EXPERIMENTS.md roofline
table. Reads experiments/dryrun/*.json (produced by repro.launch.dryrun);
prints CSV rows and, with --markdown, the §Roofline table."""
from __future__ import annotations

import argparse
import glob
import json
import os

from .common import row


def load(out_dir="experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main(markdown: bool = False):
    recs = load()
    if not recs:
        row("roofline_report", 0.0, "no dry-run records yet")
        return
    lines = []
    for r in recs:
        if r.get("status") == "skipped":
            lines.append((r["arch"], r["shape"], r["mesh"], "skipped",
                          r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            lines.append((r["arch"], r["shape"], r["mesh"], "ERROR", ""))
            continue
        rf = r["roofline"]
        tag = "flstep" if r.get("fl_step") else ""
        lines.append((
            r["arch"], r["shape"], r["mesh"] + tag,
            f"c={rf['t_compute_s']:.3g}s m={rf['t_memory_s']:.3g}s "
            f"n={rf['t_collective_s']:.3g}s dom={rf['dominant']} "
            f"useful={rf['useful_flops_ratio']:.2f}",
            f"temp={r['memory'].get('temp_bytes', 0) / 1e9:.1f}GB"))
    if markdown:
        print("| arch | shape | mesh | compute s | memory s | collective s"
              " | dominant | useful | temp GB |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in recs:
            if r.get("status") != "ok":
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                      + (f"skipped: {r.get('reason','')} |" if r.get("status")
                         == "skipped" else "ERROR |") * 1)
                continue
            rf = r["roofline"]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{rf['t_compute_s']:.3g} | {rf['t_memory_s']:.3g} | "
                  f"{rf['t_collective_s']:.3g} | {rf['dominant']} | "
                  f"{rf['useful_flops_ratio']:.2f} | "
                  f"{r['memory'].get('temp_bytes', 0) / 1e9:.1f} |")
        return
    for arch, shape, mesh, status, extra in lines:
        row(f"roofline_{arch}_{shape}_{mesh}", 0.0, f"{status};{extra}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    main(**vars(ap.parse_args()))
