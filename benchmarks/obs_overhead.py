"""Observability overhead gate: the disabled tracer must be free.

``repro.obs`` instrumentation sites in the cohort round path guard on
``tracer.enabled`` (one attribute load + branch each).  This benchmark
prices that guard and GATES it: the disabled-tracer round must stay
within :data:`GATE_PCT` (2%) of a bare reference round, measured on the
same cohort-engine workload ``benchmarks.cohort_scaling`` times.

Three arms, identical synthetic workload (logreg payload, drifting
ragged pools), best-of-n steady-state timing (``timeit_min`` — noise
only ever adds time):

* ``bare`` — the round body with the obs blocks bypassed
  (``_record`` + ``_execute`` called directly): the pre-instrumentation
  reference.  The residual per-dispatch ``if trace:`` guards inside
  ``_execute`` ride along in BOTH arms, so the gated delta isolates
  exactly the code the instrumentation added to ``round()``.
* ``off``  — ``CohortEngine.round()`` with the shared ``NULL_TRACER``
  (the default for every untraced run).  **Gated: off/bare − 1 < 2%.**
* ``on``   — ``round()`` with an enabled in-memory tracer (spans +
  metrics, no file I/O).  Informational: the price of turning tracing
  on, reported but not gated.

Exit status 1 when the gate fails (``benchmarks.run`` then drops the
rows from the perf artifacts and fails the lane).

Usage:
  PYTHONPATH=src python -m benchmarks.obs_overhead
  PYTHONPATH=src python -m benchmarks.obs_overhead --smoke
"""
from __future__ import annotations

import argparse
import os
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.cohort_engine import CohortEngine
from repro.obs import ObsConfig, Tracer

from .common import row, timeit_min

GATE_PCT = 2.0


def _logreg(key, din=64, nc=10):
    params = {"w": jax.random.normal(key, (din, nc)) * 0.05,
              "b": jnp.zeros(nc)}

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"] + p["b"]

    return params, apply_fn


def _pools(n_samples, c, h, rng):
    sizes = np.maximum(h, rng.lognormal(3.0, 0.8, c).astype(int))
    sizes = np.minimum(sizes, max(h, n_samples // max(1, c)))
    perm = rng.permutation(n_samples)
    pools, pos = [], 0
    for s in sizes:
        pools.append(perm[pos:pos + s].copy())
        pos += s
    return pools


def _cohorts(engine, c, h, rounds, seed):
    """Pre-built bucketed cohorts for ``rounds`` drifting pools, so the
    timed region is the round execution only (no host-side planning)."""
    rng = np.random.default_rng(seed)
    din = 64
    n = max(4096, c * 48)
    x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    ds = SimpleNamespace(x_train=x, y_train=y)
    out = []
    for _ in range(rounds):
        pools = _pools(n, c, h, rng)
        cohort = engine.build(ds.x_train, ds.y_train, pools, h, rng,
                              max_batch=8)
        out.append((cohort, sum(len(p) for p in pools)))
    return out


def bench_overhead(c=32, h=5, rounds=4, reps=20, seed=0):
    """Best-of-``reps`` seconds for one pass over ``rounds`` cohorts,
    per arm.  Returns ``(t_bare, t_off, t_on, tracer)``."""
    params, apply_fn = _logreg(jax.random.PRNGKey(seed))

    def make_engine(tracer=None):
        # donate=False: the timed loop reuses the same params buffer
        return CohortEngine(apply_fn, batch_align=8, client_align=4,
                            donate=False, tracer=tracer)

    eng_bare = make_engine()
    eng_off = make_engine()
    tracer = Tracer(ObsConfig(path=None))     # in-memory spans + metrics
    eng_on = make_engine(tracer=tracer)
    work = _cohorts(eng_bare, c, h, rounds, seed)

    def run_bare():
        for cohort, total in work:
            eng_bare._record(cohort)
            p, _ = eng_bare._execute(params, cohort, 0.05, total)
        jax.block_until_ready(p)

    def run_off():
        for cohort, total in work:
            p, _ = eng_off.round(params, cohort, 0.05, total)
        jax.block_until_ready(p)

    def run_on():
        for cohort, total in work:
            p, _ = eng_on.round(params, cohort, 0.05, total)
        jax.block_until_ready(p)

    # warmup=2: first pass compiles every bucket signature
    t_bare = timeit_min(run_bare, n=reps, warmup=2) / 1e6
    t_off = timeit_min(run_off, n=reps, warmup=2) / 1e6
    t_on = timeit_min(run_on, n=reps, warmup=2) / 1e6
    return t_bare, t_off, t_on, tracer


def main() -> int:
    ap = argparse.ArgumentParser()
    smoke_env = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    ap.add_argument("--cohorts", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--smoke", action="store_true", default=smoke_env,
                    help="tiny sizes for CI")
    args, _ = ap.parse_known_args()

    c = args.cohorts or (16 if args.smoke else 32)
    rounds = args.rounds or (3 if args.smoke else 4)
    reps = args.reps or (8 if args.smoke else 20)

    print(f"# obs_overhead C={c} rounds={rounds} reps={reps} "
          f"gate=<{GATE_PCT:.0f}% smoke={args.smoke}")
    t_bare, t_off, t_on, tracer = bench_overhead(c=c, rounds=rounds,
                                                 reps=reps)
    off_pct = 100.0 * (t_off / t_bare - 1.0)
    on_pct = 100.0 * (t_on / t_bare - 1.0)
    print(f"bare {t_bare * 1e3:8.3f}ms  off {t_off * 1e3:8.3f}ms "
          f"({off_pct:+.2f}%)  on {t_on * 1e3:8.3f}ms ({on_pct:+.2f}%)",
          flush=True)

    snap = tracer.metrics.snapshot(prefix="cohort.")
    row("obs.overhead.bare_pass", t_bare * 1e6)
    row("obs.overhead.disabled_pass", t_off * 1e6,
        f"overhead_vs_bare={off_pct:+.2f}%;gate=<{GATE_PCT:.0f}%")
    row("obs.overhead.enabled_pass", t_on * 1e6,
        f"overhead_vs_bare={on_pct:+.2f}%;spans={len(tracer.spans)}",
        metrics={"cohort.bucket_dispatches":
                 snap.get("cohort.bucket_dispatches", 0),
                 "cohort.recompiled_signatures":
                 snap.get("cohort.recompiled_signatures", 0)})

    if off_pct >= GATE_PCT:
        # return instead of sys.exit: benchmarks.run must survive one
        # module's failure and keep printing the remaining rows
        print(f"obs_overhead: disabled-path overhead {off_pct:+.2f}% "
              f"breaches the {GATE_PCT:.0f}% gate", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
