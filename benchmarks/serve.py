"""Serving benchmark: gateway throughput and router tail latency
(``repro.serve``).

Three claims, two of them GATED (a failing gate fails the module, so a
regression can never silently become a committed perf baseline):

1. **Bucketed batching pays** (gate): geometric size-bucketed batch
   dispatch (``max_batch=64, batch_align=8``) must sustain >= 3x the
   wall-clock inference QPS of per-request dispatch (``max_batch=1``)
   on the SAME arrival trajectory — the serving analogue of the cohort
   engine's compile-once bucketing win.
2. **Adaptive routing beats static at the tail** (gate): under the
   ``degraded_links`` preset (uplink dead-air outages, ISL fades), the
   ``min_rt`` router's p99 end-to-end latency must beat the
   ``static_nearest`` baseline, which keeps piling requests onto the
   origin satellite while its uplink is out.
3. **Latency matrix** (measurement): p50/p99 simulated latency and
   sustained QPS per router per scenario (``degraded_links`` and the
   burst-dominated ``flash_crowd``).

Rows land in ``BENCH_serve.json`` via ``benchmarks.run --json``.
"""
from __future__ import annotations

import dataclasses
import os
import time

from .common import FULL, row


def _smoke() -> bool:
    # read lazily: benchmarks.run sets the env var AFTER importing us
    return os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"


_ENGINES = {}


def _engine(scenario: str):
    """One trained engine per scenario, shared across benchmarks (the
    serving plane is read-only on it, so reuse is safe)."""
    if scenario not in _ENGINES:
        from repro.fl.rounds import FLConfig
        from repro.sim.engine import SAGINEngine
        fl = FLConfig(
            n_devices=4, n_air=1, h_local=1,
            train_fraction=0.01 if FULL else 0.005,
            eval_size=256 if FULL else 64,
            execution="sequential", seed=0)
        eng = SAGINEngine(scenario, fl=fl)
        t0 = time.perf_counter()
        eng.run(1)
        _ENGINES[scenario] = (eng, time.perf_counter() - t0)
    return _ENGINES[scenario]


def _session(engine, serve, duration: float, backend=None):
    from repro.serve import ServeGateway
    gw = ServeGateway(engine, serve=serve, backend=backend)
    t0 = time.perf_counter()
    rep = gw.run(duration, t0=0.0)
    return rep, time.perf_counter() - t0


def bench_batching_speedup() -> bool:
    """Gate 1: bucketed batch dispatch >= 3x per-request dispatch QPS.

    Measured on the production transformer decode path
    (``launch.serve.make_serve_step`` via ``TransformerBackend``), where
    a decode step's cost is dominated by per-dispatch overhead — the
    regime batch serving exists for.  Same arrival trajectory on both
    sides; only the gateway's batching policy differs."""
    from repro.serve import ServeConfig, TransformerBackend

    eng, _ = _engine("degraded_links")
    duration = 60.0 if _smoke() else 180.0
    base = ServeConfig(base_rate=16.0, diurnal_amplitude=0.0)
    bucketed = dataclasses.replace(base, max_batch=64, batch_align=8)
    per_req = dataclasses.replace(base, max_batch=1, batch_align=1)

    import numpy as np

    def warmed(widths):
        # pre-compile the geometric width grid: steady-state QPS is the
        # claim (compile-once is what the bucketing buys), so one-time
        # jit costs stay out of the timed window
        be = TransformerBackend(seq_len=128)
        for b in widths:
            be.predict(0, np.zeros((b, 28, 28, 1), np.float32),
                       np.arange(b))
        return be

    grid = [w for w in (1, 2, 4, 8, 16, 32, 64) if w <= bucketed.max_batch]
    rep_b, wall_b = _session(eng, bucketed, duration, backend=warmed(grid))
    rep_p, wall_p = _session(eng, per_req, duration, backend=warmed([1]))
    speedup = (rep_b.qps_wall / rep_p.qps_wall
               if rep_p.qps_wall > 0 else float("inf"))
    ok = rep_b.served == rep_p.served and speedup >= 3.0
    row("serve.batching_speedup", wall_b * 1e6,
        f"bucketed_qps={rep_b.qps_wall:.0f} "
        f"per_req_qps={rep_p.qps_wall:.0f} speedup={speedup:.1f}x "
        f"served={rep_b.served}",
        metrics={"bucketed_qps": round(rep_b.qps_wall, 1),
                 "per_request_qps": round(rep_p.qps_wall, 1),
                 "speedup": round(speedup, 2),
                 "served": rep_b.served,
                 "bucketed_batches": rep_b.batches,
                 "per_request_batches": rep_p.batches,
                 "gate": "bucketed qps >= 3x per-request qps", "ok": ok})
    return ok


def bench_router_tail_degraded() -> bool:
    """Gate 2: min_rt p99 < static_nearest p99 under degraded_links."""
    from repro.serve import ServeConfig

    eng, _ = _engine("degraded_links")
    duration = 300.0 if _smoke() else 900.0
    reps = {}
    wall = 0.0
    for router in ("min_rt", "static_nearest"):
        cfg = ServeConfig(base_rate=2.0, router=router)
        reps[router], w = _session(eng, cfg, duration)
        wall += w
    mrt, static = reps["min_rt"], reps["static_nearest"]
    ok = (mrt.requests == static.requests
          and mrt.latency_p99 < static.latency_p99)
    row("serve.router_tail_degraded", wall * 1e6,
        f"min_rt_p99={mrt.latency_p99:.3f}s "
        f"static_p99={static.latency_p99:.3f}s "
        f"min_rt_p50={mrt.latency_p50:.3f}s "
        f"static_p50={static.latency_p50:.3f}s n={mrt.served}",
        metrics={"min_rt_p99_s": round(mrt.latency_p99, 4),
                 "static_p99_s": round(static.latency_p99, 4),
                 "min_rt_p50_s": round(mrt.latency_p50, 4),
                 "static_p50_s": round(static.latency_p50, 4),
                 "min_rt_targets": mrt.count_by_target,
                 "static_targets": static.count_by_target,
                 "served": mrt.served,
                 "gate": "min_rt p99 < static_nearest p99", "ok": ok})
    return ok


def bench_latency_matrix() -> None:
    """Measurement: p50/p99 + sustained QPS per router per scenario."""
    from repro.serve import ServeConfig

    scenarios = (("degraded_links",)
                 if _smoke() else ("degraded_links", "flash_crowd"))
    duration = 120.0 if _smoke() else 600.0
    for scenario in scenarios:
        eng, train_wall = _engine(scenario)
        for router in ("min_rt", "static_nearest"):
            base = getattr(eng.scenario, "serve", None)
            cfg = (dataclasses.replace(base, router=router)
                   if base is not None
                   else ServeConfig(base_rate=2.0, router=router))
            rep, wall = _session(eng, cfg, duration)
            row(f"serve.latency.{scenario}.{router}", wall * 1e6,
                f"p50={rep.latency_p50:.3f}s p99={rep.latency_p99:.3f}s "
                f"qps_sim={rep.qps_sim:.2f} qps_wall={rep.qps_wall:.0f} "
                f"acc={rep.served_accuracy:.3f} n={rep.served}",
                metrics={"scenario": scenario, "router": router,
                         "p50_s": round(rep.latency_p50, 4),
                         "p99_s": round(rep.latency_p99, 4),
                         "qps_sim": round(rep.qps_sim, 3),
                         "qps_wall": round(rep.qps_wall, 1),
                         "served_accuracy": rep.served_accuracy,
                         "served": rep.served, "batches": rep.batches,
                         "by_target": rep.count_by_target,
                         "train_wall_s": round(train_wall, 1)})


def main() -> int:
    ok = bench_batching_speedup()
    ok = bench_router_tail_degraded() and ok
    bench_latency_matrix()
    if not ok:
        print("# serve gate FAILED", flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
