"""Cohort-engine scaling: bucketed vs global-Bmax vs sequential rounds.

Drives three round engines over synthetic federated pools:

* ``bucketed``   — the size-bucketed, device-resident cohort engine
  (``repro.fl.cohort_engine.CohortEngine``): one compiled dispatch per
  geometric width bucket, single device-side aggregation.
* ``global``     — the PR-1 batched path (every client padded to the
  round's global ``Bmax``), kept as ``cohort_bucketing="global"``.
* ``sequential`` — the reference loop: one jitted dispatch per node.

Two pool regimes:

* ``uniform`` — lognormal ragged pools, mild spread: the regime PR 1
  optimized, where global-``Bmax`` padding is already cheap.  Bucketing
  must not regress here.
* ``skewed``  — mega_constellation-style offloading skew: one pool holds
  ~10x the samples of each of the many small ones, so the global layout
  pads every small client to the big client's batch width.  This is the
  regime the paper's adaptive offloading deliberately creates, and where
  bucketing must deliver >= 2x per-round speedup over the global layout
  at engine scale (C >= 64; below that the round is dispatch-bound, not
  padding-bound, and both batched layouts cost microseconds — those rows
  stay informational).

Pools DRIFT between rounds (offloading churn).  Round 1 is the
warmup/compile round; headline numbers are means over the remaining
rounds.  Rows feed ``BENCH_cohort.json`` via ``benchmarks.run --json``.

Gates (non-smoke): skewed-regime bucketed-vs-global speedup must stay
>= 2x at engine scale, and small uniform cohorts (C <= 32) must never
regress below 1x — there the planner's collapse pass folds
near-uniform plans into a single global-shaped bucket, so bucketing
costs nothing where it cannot win (larger uniform cohorts sit at
parity within timing noise and are tracked, not gated).

A fourth row family, ``cohort.sharded.D{n}``, measures the mesh-sharded
engine (clients/sec at 1/2/4/8 forced host devices on the
mega_constellation skewed shape, C=256 mlp by default).  Each device
count runs in a ``--sharded-worker`` subprocess because
``--xla_force_host_platform_device_count`` binds at jax import; rows
carry per-shard padding/imbalance metrics from
``CohortEngineStats``.  The D8 gate requires >= 1.5x round throughput
over D1 wherever >= 2 usable cores exist; a 1-core host serializes the
shard programs (the residual ~1.2-1.4x is per-shard working-set and
fusion effects only), so there the gate records the number and skips.

The bucketed engine runs with ``guard=True``: every round whose bucket
layout is already warm executes under
``repro.analysis.contracts.no_recompile()``, so a recompile regression
on the steady-state path fails the bench lane with a
``ContractViolation`` naming the round instead of silently inflating
the timings.  (The guard is exact — zero lowerings allowed — and
self-gating: rounds that legitimately introduce a new bucket signature
under drift stay unguarded.)

Usage:
  PYTHONPATH=src python -m benchmarks.cohort_scaling
  PYTHONPATH=src python -m benchmarks.cohort_scaling --regime skewed \
      --cohorts 64 --rounds 5
  PYTHONPATH=src python -m benchmarks.cohort_scaling --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import sys
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import batch_width_for_pool, plan_buckets
from repro.fl.cohort_engine import CohortEngine
from repro.fl.rounds import FLConfig, _round_batched, _round_sequential

from .common import row


# --------------------------------------------------------------------------
# FL payloads (client models)
# --------------------------------------------------------------------------
def _logreg(key, din, nc=10):
    params = {"w": jax.random.normal(key, (din, nc)) * 0.05,
              "b": jnp.zeros(nc)}

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"] + p["b"]

    return params, apply_fn


def _mlp(key, din, dh=64, nc=10):
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (din, dh)) * 0.05,
              "b1": jnp.zeros(dh),
              "w2": jax.random.normal(k2, (dh, nc)) * 0.05,
              "b2": jnp.zeros(nc)}

    def apply_fn(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    return params, apply_fn


def _cnn(key, din):
    from repro.models.cnn import build_model
    return build_model("mnist", key, image_shape=(28, 28, 1))


PAYLOADS = {"logreg": _logreg, "mlp": _mlp, "cnn": _cnn}
PAYLOAD_DIN = {"logreg": 64, "mlp": 784, "cnn": None}


# --------------------------------------------------------------------------
# Pool regimes
# --------------------------------------------------------------------------
def _make_pools_uniform(n_samples, c, h, rng):
    """Ragged client pools: lognormal sizes, every client non-empty."""
    sizes = np.maximum(h, rng.lognormal(3.0, 0.8, c).astype(int))
    sizes = np.minimum(sizes, max(h, n_samples // max(1, c)))
    perm = rng.permutation(n_samples)
    pools, pos = [], 0
    for s in sizes:
        pools.append(perm[pos:pos + s].copy())
        pos += s
    return pools


def _make_pools_skewed(n_samples, c, h, rng):
    """Offloading skew: c-1 sensor-class pools plus ONE pool holding
    ~10x the combined mass of the rest (the satellite after adaptive
    offloading concentrates data on the best-placed node)."""
    small = np.maximum(h, rng.integers(24, 56, c - 1))
    big = 10 * int(small.sum())
    total = int(small.sum()) + big
    if total > n_samples:
        raise ValueError(f"need {total} samples, have {n_samples}")
    perm = rng.permutation(n_samples)
    pools, pos = [], 0
    for s in small:
        pools.append(perm[pos:pos + s].copy())
        pos += s
    pools.append(perm[pos:pos + big].copy())
    return pools


def _drift(pools, rng, frac=0.15):
    """Move ~frac of a few clients' samples to others (offloading churn)."""
    pools = [p.copy() for p in pools]
    c = len(pools)
    for _ in range(max(1, c // 4)):
        src, dst = rng.integers(0, c, 2)
        if src == dst or len(pools[src]) <= 2:
            continue
        k = max(1, int(frac * len(pools[src])))
        pools[dst] = np.concatenate([pools[dst], pools[src][:k]])
        pools[src] = pools[src][k:]
    return pools


REGIMES = {"uniform": _make_pools_uniform, "skewed": _make_pools_skewed}


# --------------------------------------------------------------------------
# Round drivers
# --------------------------------------------------------------------------
def _padding_ratios(schedule, h, batch_cap, align, pad_clients):
    """Mean layout/real element ratios of both batched layouts over the
    pool schedule — pure arithmetic over the per-pool batch widths
    (``batch_width_for_pool`` is the sizing rule both builders share),
    no tensors materialized."""
    buck, glob = [], []
    for pools in schedule:
        widths = [batch_width_for_pool(len(p), h, batch_cap)
                  for p in pools if len(p)]
        real = sum(widths)
        plans = plan_buckets(widths, batch_align=align)
        buck.append(sum(p.c_bucket * p.b_bucket for p in plans) / real)
        b_max = int(np.ceil(max(widths) / align) * align)
        glob.append(max(len(widths), pad_clients) * b_max / real)
    return float(np.mean(buck)), float(np.mean(glob))


def bench_cohort(c, payload="logreg", regime="skewed", h=5, batch_cap=8,
                 rounds=5, seed=0, seq=True):
    rng = np.random.default_rng(seed)
    din = PAYLOAD_DIN[payload]
    n = max(4096, c * 48)
    if regime == "skewed":
        n = max(n, 11 * 56 * c)          # room for the 10x pool
    if payload == "cnn":
        x = rng.normal(size=(n, 28, 28, 1)).astype(np.float32)
    else:
        x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    ds = SimpleNamespace(x_train=x, y_train=y)

    params, apply_fn = PAYLOADS[payload](jax.random.PRNGKey(seed), din)
    cfg = FLConfig(n_devices=c, n_air=0, h_local=h, lr=0.05,
                   batch_cap=batch_cap, seed=seed,
                   cohort_batch_align=max(8, batch_cap))

    # identical pool schedule for every engine
    pools0 = REGIMES[regime](n, c, h, rng)
    schedule = [pools0]
    for _ in range(rounds - 1):
        schedule.append(_drift(schedule[-1], rng))
    total = sum(len(p) for p in pools0)

    def run(engine, run_cfg):
        times = []
        eng_rng = np.random.default_rng(seed + 1)
        p = jax.tree_util.tree_map(lambda a: jnp.array(a, copy=True), params)
        for pools in schedule:
            t0 = time.perf_counter()
            p = engine(run_cfg, apply_fn, p, ds, pools, total, eng_rng)[0]
            jax.block_until_ready(p)
            times.append(time.perf_counter() - t0)
        return times

    cfg_buck = dataclasses.replace(cfg, cohort_bucketing="geometric")
    cfg_glob = dataclasses.replace(cfg, cohort_bucketing="global")
    # persistent engine with the recompile contract armed: warm-layout
    # rounds that lower anything fail the bench (module docstring)
    guarded = CohortEngine(apply_fn, batch_align=cfg.cohort_batch_align,
                           client_align=cfg.cohort_client_align,
                           guard=True)
    t_buck = run(functools.partial(_round_batched, engine=guarded),
                 cfg_buck)
    t_glob = run(_round_batched, cfg_glob)
    t_seq = run(_round_sequential, cfg) if seq else None
    # the timed global path pads clients to n_devices + n_air + 1 = c + 1
    ratios = _padding_ratios(schedule, h, batch_cap, max(8, batch_cap),
                             c + 1)
    return t_buck, t_glob, t_seq, ratios, guarded.stats


def _steady(times):
    """Best-of over the post-warmup rounds — the ``timeit_min``
    statistic (see ``benchmarks.common``): scheduler noise only ever
    ADDS time, so the minimum is the right basis for speedup ratios of
    deterministic code at millisecond round times."""
    return float(np.min(times[1:])) if len(times) > 1 else float(times[0])


# --------------------------------------------------------------------------
# Mesh-sharded rows (cohort.sharded.*): one subprocess per device count
# --------------------------------------------------------------------------
def bench_sharded_round(c, payload="mlp", rounds=6, h=5, batch_cap=8,
                        seed=0):
    """Engine-only sharded round timing over a drifting skewed schedule.

    Cohorts are prebuilt so the row isolates what the tentpole changed —
    the engine's ``round()`` dispatch (local updates + in-mesh
    aggregation) — from the host-side pipeline work that is identical
    at every device count.  Runs under whatever device count
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` forced before
    the jax import; the parent process launches one worker per count.
    """
    rng = np.random.default_rng(seed)
    din = PAYLOAD_DIN[payload]
    n = max(4096, c * 48, 11 * 56 * c)
    x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    params, apply_fn = PAYLOADS[payload](jax.random.PRNGKey(seed), din)

    schedule = [_make_pools_skewed(n, c, h, rng)]
    for _ in range(rounds - 1):
        schedule.append(_drift(schedule[-1], rng))
    total = sum(len(p) for p in schedule[0])

    eng = CohortEngine(apply_fn, batch_align=max(8, batch_cap),
                       client_align=4, guard=True, sharding="auto")
    build_rng = np.random.default_rng(seed + 1)
    cohorts = [eng.build(x, y, ps, h, build_rng, batch_cap)
               for ps in schedule]

    p, times = params, []
    for co in cohorts:
        t0 = time.perf_counter()
        p, _ = eng.round(p, co, 0.05, total)
        jax.block_until_ready(p)
        times.append(time.perf_counter() - t0)
    return _steady(times), eng


def _sharded_worker(args) -> int:
    """``--sharded-worker`` mode: run one device count, print one JSON
    line (the parent parses stdout's last line)."""
    import json
    c = (args.cohorts or [256])[0]
    rounds = args.rounds or 6
    steady, eng = bench_sharded_round(c, payload=args.payload,
                                      rounds=rounds, h=args.h_local,
                                      batch_cap=args.batch_cap)
    st = eng.stats
    print(json.dumps({
        "devices": len(jax.devices()), "shards": eng.shards,
        "clients": c, "steady_s": steady,
        "clients_per_s": c / steady,
        "padding_ratio": round(st.padding_ratio, 4),
        "shard_pad_clients": st.shard_pad_clients,
        "max_shard_imbalance": round(st.max_shard_imbalance, 4),
        "sharded_dispatches": st.sharded_dispatches,
        "compiled_signatures": st.compiled_signatures,
    }))
    return 0


def _sharded_rows(args) -> int:
    """Emit the ``cohort.sharded.D{n}`` row family and apply the D8
    scaling gate.  Each device count runs in its own subprocess because
    ``--xla_force_host_platform_device_count`` only takes effect before
    the first jax import."""
    import json
    import subprocess
    devices = args.sharded_devices or ([1, 2] if args.smoke
                                       else [1, 2, 4, 8])
    c = (args.cohorts or [None])[0] or (64 if args.smoke else 256)
    rounds = args.rounds or (3 if args.smoke else 6)
    payload = args.payload if args.payload != "logreg" else "mlp"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    results = {}
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src")]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        cmd = [sys.executable, "-m", "benchmarks.cohort_scaling",
               "--sharded-worker", "--cohorts", str(c),
               "--rounds", str(rounds), "--payload", payload,
               "--h-local", str(args.h_local),
               "--batch-cap", str(args.batch_cap)]
        proc = subprocess.run(cmd, env=env, cwd=root,
                              capture_output=True, text=True)
        if proc.returncode != 0:
            print(f"sharded D{n} worker failed:\n{proc.stderr}",
                  file=sys.stderr)
            continue
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        results[n] = res
        speed = (results[1]["steady_s"] / res["steady_s"]
                 if 1 in results else 1.0)
        print(f"sharded  D={n:2d} C={c:5d}  round {res['steady_s']:7.3f}s"
              f"  ({res['clients_per_s']:8.1f} clients/s, {speed:4.2f}x D1)",
              flush=True)
        row(f"cohort.sharded.D{n}.{payload}.round",
            res["steady_s"] * 1e6,
            f"clients_per_s={res['clients_per_s']:.1f};"
            f"speedup_vs_D1={speed:.2f}x;shards={res['shards']}",
            metrics={"cohort.shards": res["shards"],
                     "cohort.padding_ratio": res["padding_ratio"],
                     "cohort.shard_pad_clients": res["shard_pad_clients"],
                     "cohort.shard_imbalance": res["max_shard_imbalance"],
                     "cohort.sharded_dispatches":
                     res["sharded_dispatches"],
                     "cohort.recompiled_signatures":
                     res["compiled_signatures"]})
    top = max(results) if results else 0
    if args.smoke or top < 8 or 1 not in results:
        return 0
    speed = results[1]["steady_s"] / results[top]["steady_s"]
    cores = len(os.sched_getaffinity(0))
    if cores < 2:
        # a 1-core box serializes the 8 shard programs: the residual
        # speedup is per-shard working-set/fusion only, so the thread-
        # scaling gate is not meaningful here — record, don't fail
        print(f"sharded D{top} speedup {speed:.2f}x on {cores} usable "
              f"core(s): scaling gate skipped (needs >=2)",
              file=sys.stderr)
        return 0
    if speed < 1.5:
        print(f"cohort_scaling: sharded D{top} round speedup "
              f"{speed:.2f}x below the 1.5x target", file=sys.stderr)
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    smoke_env = os.environ.get("REPRO_BENCH_SMOKE", "0") == "1"
    ap.add_argument("--payload", default="logreg", choices=sorted(PAYLOADS))
    ap.add_argument("--regime", default="both",
                    choices=["uniform", "skewed", "both"])
    ap.add_argument("--cohorts", type=int, nargs="+", default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--h-local", type=int, default=5)
    ap.add_argument("--batch-cap", type=int, default=8)
    ap.add_argument("--skip-seq-above", type=int, default=1024,
                    help="skip the sequential engine beyond this C")
    ap.add_argument("--smoke", action="store_true", default=smoke_env,
                    help="tiny sizes for CI")
    ap.add_argument("--sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)   # internal: one device count
    ap.add_argument("--sharded-devices", type=int, nargs="+", default=None,
                    help="forced host device counts for cohort.sharded.*")
    args, _ = ap.parse_known_args()

    if args.sharded_worker:
        return _sharded_worker(args)

    cohorts = args.cohorts or ([16] if args.smoke else [16, 64, 256])
    rounds = args.rounds or (3 if args.smoke else 8)
    regimes = (["uniform", "skewed"] if args.regime == "both"
               else [args.regime])

    print(f"# cohort_scaling payload={args.payload} h={args.h_local} "
          f"batch_cap={args.batch_cap} rounds={rounds} smoke={args.smoke}")
    print("# regime, C: bucketed | global | sequential steady round "
          "seconds; speedups vs bucketed; padding ratios")
    worst_skewed_speedup = None
    worst_uniform_speedup = None
    for regime in regimes:
        for c in cohorts:
            seq = c <= args.skip_seq_above
            # small cohorts run millisecond rounds where scheduler noise
            # swamps an 8-round best-of; give the min more samples
            c_rounds = (max(rounds, 20) if c <= 32 and not args.smoke
                        else rounds)
            t_buck, t_glob, t_seq, (r_buck, r_glob), stats = bench_cohort(
                c, payload=args.payload, regime=regime, h=args.h_local,
                batch_cap=args.batch_cap, rounds=c_rounds, seq=seq)
            buck_s, glob_s = _steady(t_buck), _steady(t_glob)
            speed_glob = glob_s / buck_s
            line = (f"{regime:8s} C={c:5d}  bucketed {buck_s:7.3f}s"
                    f"  global {glob_s:7.3f}s ({speed_glob:4.1f}x)")
            derived = (f"speedup_vs_global={speed_glob:.2f}x;"
                       f"pad_bucketed={r_buck:.2f};pad_global={r_glob:.2f}")
            if t_seq is not None:
                seq_s = _steady(t_seq)
                line += f"  seq {seq_s:7.3f}s ({seq_s / buck_s:4.1f}x)"
                derived += f";speedup_vs_seq={seq_s / buck_s:.2f}x"
            print(line, flush=True)
            # the bucketed engine's cumulative stats ride along as row
            # metrics (same names as the repro.obs cohort.* counters)
            row(f"cohort.{regime}.C{c}.{args.payload}.bucketed_round",
                buck_s * 1e6, derived,
                metrics={"cohort.bucket_dispatches":
                         stats.bucket_dispatches,
                         "cohort.recompiled_signatures":
                         stats.compiled_signatures,
                         "cohort.padding_ratio":
                         round(stats.padding_ratio, 4)})
            row(f"cohort.{regime}.C{c}.{args.payload}.global_round",
                glob_s * 1e6, f"pad_global={r_glob:.2f}")
            if regime == "skewed" and c >= 64:   # engine scale (docstring)
                worst_skewed_speedup = (speed_glob
                                        if worst_skewed_speedup is None
                                        else min(worst_skewed_speedup,
                                                 speed_glob))
            if regime == "uniform" and c <= 32:
                # bucketing must never LOSE to the global layout in the
                # regime it did not target: at small C the planner's
                # collapse pass folds near-uniform plans into one
                # global-shaped bucket, so the bound is structural.
                # Larger uniform cohorts legitimately split buckets and
                # sit at parity — tracked in the rows, not gated (the
                # worst observed is ~0.98x, i.e. timing noise)
                worst_uniform_speedup = (speed_glob
                                         if worst_uniform_speedup is None
                                         else min(worst_uniform_speedup,
                                                  speed_glob))
    rc = _sharded_rows(args)
    if (not args.smoke and worst_skewed_speedup is not None
            and worst_skewed_speedup < 2.0):
        # return instead of sys.exit: benchmarks.run must survive one
        # module's failure and keep printing the remaining rows
        print(f"cohort_scaling: skewed-regime speedup "
              f"{worst_skewed_speedup:.2f}x below the 2x target",
              file=sys.stderr)
        return 1
    if (not args.smoke and worst_uniform_speedup is not None
            and worst_uniform_speedup < 1.0):
        print(f"cohort_scaling: uniform-regime speedup "
              f"{worst_uniform_speedup:.2f}x — bucketed rounds regressed "
              f"below the global layout", file=sys.stderr)
        return 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
