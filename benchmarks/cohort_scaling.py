"""Cohort-engine scaling: batched vs sequential round execution.

Drives the two ``run_fl`` round engines (``repro.fl.rounds._round_batched``
and ``_round_sequential``) over synthetic federated pools at cohort sizes
C in {16, 64, 256, 1024} and reports per-round wall time, rounds/sec and
the batched-over-sequential speedup.

The default workload is the many-small-clients regime the paper's SAGIN
targets (tens to thousands of sensor-class devices, each holding a few
dozen samples): a 64-feature logistic-regression payload with per-client
batches of <= 8. There the sequential engine's cost is C jitted dispatches
plus C host->device transfers per round, while the batched engine issues
ONE compiled ``cohort_local_update`` over the padded ``(C, H, B, ...)``
cohort — the dispatch overhead is amortized C-fold. ``--payload mlp|cnn``
switches to the heavier paper payloads (where CPU conv gradients are
compute-bound and the win shrinks; on TPU the vmapped cohort step is the
intended path regardless).

Pools are RAGGED (heterogeneous sizes) and DRIFT between rounds, as the
offloading optimizer does in real runs: the sequential engine also pays a
fresh XLA compile for every previously-unseen (H, B) batch shape, while
the batched engine's padded shapes stay stable and compile once. Round 1
is reported separately as the warmup/compile round; the headline numbers
and the speedup are means over the remaining rounds.

Usage:
  PYTHONPATH=src python -m benchmarks.cohort_scaling
  PYTHONPATH=src python -m benchmarks.cohort_scaling --payload mlp \
      --cohorts 16 64 --rounds 4
"""
from __future__ import annotations

import argparse
import time
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.rounds import FLConfig, _round_batched, _round_sequential

from .common import row


# --------------------------------------------------------------------------
# FL payloads (client models)
# --------------------------------------------------------------------------
def _logreg(key, din, nc=10):
    params = {"w": jax.random.normal(key, (din, nc)) * 0.05,
              "b": jnp.zeros(nc)}

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"] + p["b"]

    return params, apply_fn


def _mlp(key, din, dh=64, nc=10):
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (din, dh)) * 0.05,
              "b1": jnp.zeros(dh),
              "w2": jax.random.normal(k2, (dh, nc)) * 0.05,
              "b2": jnp.zeros(nc)}

    def apply_fn(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    return params, apply_fn


def _cnn(key, din):
    from repro.models.cnn import build_model
    return build_model("mnist", key, image_shape=(28, 28, 1))


PAYLOADS = {"logreg": _logreg, "mlp": _mlp, "cnn": _cnn}
PAYLOAD_DIN = {"logreg": 64, "mlp": 784, "cnn": None}


def _make_pools(n_samples, c, h, rng):
    """Ragged client pools: lognormal sizes, every client non-empty."""
    sizes = np.maximum(h, rng.lognormal(3.0, 0.8, c).astype(int))
    sizes = np.minimum(sizes, max(h, n_samples // max(1, c)))
    perm = rng.permutation(n_samples)
    pools, pos = [], 0
    for s in sizes:
        pools.append(perm[pos:pos + s].copy())
        pos += s
    return pools


def _drift(pools, rng, frac=0.15):
    """Move ~frac of a few clients' samples to others (offloading churn)."""
    pools = [p.copy() for p in pools]
    c = len(pools)
    for _ in range(max(1, c // 4)):
        src, dst = rng.integers(0, c, 2)
        if src == dst or len(pools[src]) <= 2:
            continue
        k = max(1, int(frac * len(pools[src])))
        pools[dst] = np.concatenate([pools[dst], pools[src][:k]])
        pools[src] = pools[src][k:]
    return pools


def bench_cohort(c, payload="logreg", h=5, batch_cap=8, rounds=5, seed=0,
                 seq=True):
    rng = np.random.default_rng(seed)
    din = PAYLOAD_DIN[payload]
    n = max(4096, c * 48)
    if payload == "cnn":
        x = rng.normal(size=(n, 28, 28, 1)).astype(np.float32)
    else:
        x = rng.normal(size=(n, din)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    ds = SimpleNamespace(x_train=x, y_train=y)

    params, apply_fn = PAYLOADS[payload](jax.random.PRNGKey(seed), din)
    cfg = FLConfig(n_devices=c, n_air=0, h_local=h, lr=0.05,
                   batch_cap=batch_cap, seed=seed,
                   cohort_batch_align=max(8, batch_cap))

    # identical pool schedule for both engines
    pools0 = _make_pools(n, c, h, rng)
    schedule = [pools0]
    for _ in range(rounds - 1):
        schedule.append(_drift(schedule[-1], rng))
    total = sum(len(p) for p in pools0)

    def run(engine):
        times = []
        eng_rng = np.random.default_rng(seed + 1)
        p = params
        for pools in schedule:
            t0 = time.perf_counter()
            p, _ = engine(cfg, apply_fn, p, ds, pools, total, eng_rng)
            jax.block_until_ready(p)
            times.append(time.perf_counter() - t0)
        return times

    t_bat = run(_round_batched)
    t_seq = run(_round_sequential) if seq else None
    return t_bat, t_seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--payload", default="logreg", choices=sorted(PAYLOADS))
    ap.add_argument("--cohorts", type=int, nargs="+",
                    default=[16, 64, 256, 1024])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--h-local", type=int, default=5)
    ap.add_argument("--batch-cap", type=int, default=8)
    ap.add_argument("--skip-seq-above", type=int, default=1024,
                    help="skip the sequential engine beyond this C")
    args = ap.parse_args()

    print(f"# cohort_scaling payload={args.payload} h={args.h_local} "
          f"batch_cap={args.batch_cap} rounds={args.rounds}")
    print("# C, batched_round_s (warmup | steady), seq_round_s "
          "(warmup | steady), batched rounds/s, speedup")
    for c in args.cohorts:
        seq = c <= args.skip_seq_above
        t_bat, t_seq = bench_cohort(c, payload=args.payload,
                                    h=args.h_local,
                                    batch_cap=args.batch_cap,
                                    rounds=args.rounds, seq=seq)
        bat_steady = float(np.mean(t_bat[1:])) if len(t_bat) > 1 else t_bat[0]
        rps = 1.0 / bat_steady
        if t_seq is not None:
            seq_steady = (float(np.mean(t_seq[1:])) if len(t_seq) > 1
                          else t_seq[0])
            speedup = seq_steady / bat_steady
            print(f"C={c:5d}  batched {t_bat[0]:7.2f}s | {bat_steady:7.3f}s"
                  f"   seq {t_seq[0]:7.2f}s | {seq_steady:7.3f}s"
                  f"   {rps:8.2f} rounds/s   speedup {speedup:5.1f}x",
                  flush=True)
            row(f"cohort_scaling_C{c}_{args.payload}", bat_steady * 1e6,
                f"speedup={speedup:.1f}x")
        else:
            print(f"C={c:5d}  batched {t_bat[0]:7.2f}s | {bat_steady:7.3f}s"
                  f"   seq   (skipped)   {rps:8.2f} rounds/s", flush=True)
            row(f"cohort_scaling_C{c}_{args.payload}", bat_steady * 1e6,
                "seq_skipped")


if __name__ == "__main__":
    main()
