"""Shared helpers for the paper-figure benchmarks.

Benchmarks run REDUCED configurations by default (CPU container); pass
--full via the environment variable REPRO_BENCH_FULL=1 for paper-scale
settings. Every benchmark prints ``name,us_per_call,derived`` CSV rows so
``python -m benchmarks.run`` yields one machine-readable artifact.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

# Every row() call is also recorded here so benchmarks.run --json can
# group rows per module and write the BENCH_*.json artifacts.
ROWS: List[Dict[str, object]] = []


def fl_common(**overrides):
    """Shared FLConfig kwargs, reduced for CPU."""
    base = dict(
        n_devices=50 if FULL else 10,
        n_air=5 if FULL else 2,
        n_rounds=30 if FULL else 6,
        h_local=5 if FULL else 3,
        train_fraction=1.0 if FULL else 0.02,
        eval_size=4096 if FULL else 512,
        seed=0,
    )
    base.update(overrides)
    return base


def timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def timeit_min(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Best-of-n microseconds per call — robust to scheduler noise, the
    right statistic for speedup ratios of deterministic code."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def row(name: str, us: float, derived: str = "",
        metrics: Dict[str, object] | None = None):
    """Record one benchmark row.  ``metrics`` (optional) is a flat
    JSON-serializable dict — typically a ``repro.obs`` metrics snapshot
    or engine-stats excerpt — attached to the BENCH_*.json artifact row
    (the CSV line stays the name,us,derived triple)."""
    r: Dict[str, object] = {"name": name, "us_per_call": round(float(us), 1),
                            "derived": derived}
    if metrics:
        r["metrics"] = metrics
    ROWS.append(r)
    print(f"{name},{us:.1f},{derived}", flush=True)


def drain_rows() -> List[Dict[str, object]]:
    """Pop and return every row recorded since the last drain."""
    out = list(ROWS)
    ROWS.clear()
    return out


def write_bench_json(path: str, rows: List[Dict[str, object]],
                     smoke: bool = False) -> None:
    """Write one BENCH_*.json perf artifact: rows + enough environment
    metadata that a future PR can tell whether a delta is real."""
    import jax
    doc = {
        "schema": "repro-bench/1",
        "generated_unix": int(time.time()),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "smoke": bool(smoke),
        "full": FULL,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", flush=True)
