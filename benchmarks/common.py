"""Shared helpers for the paper-figure benchmarks.

Benchmarks run REDUCED configurations by default (CPU container); pass
--full via the environment variable REPRO_BENCH_FULL=1 for paper-scale
settings. Every benchmark prints ``name,us_per_call,derived`` CSV rows so
``python -m benchmarks.run`` yields one machine-readable artifact.
"""
from __future__ import annotations

import os
import time
from typing import Callable

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def fl_common(**overrides):
    """Shared FLConfig kwargs, reduced for CPU."""
    base = dict(
        n_devices=50 if FULL else 10,
        n_air=5 if FULL else 2,
        n_rounds=30 if FULL else 6,
        h_local=5 if FULL else 3,
        train_fraction=1.0 if FULL else 0.02,
        eval_size=4096 if FULL else 512,
        seed=0,
    )
    base.update(overrides)
    return base


def timeit(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def timeit_min(fn: Callable, n: int = 5, warmup: int = 1) -> float:
    """Best-of-n microseconds per call — robust to scheduler noise, the
    right statistic for speedup ratios of deterministic code."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
