"""Fig. 7: free-space path-loss (LoS) channel variant.

All schemes get faster with the LoS channel (less communication delay);
the adaptive scheme keeps its lead."""
from __future__ import annotations

from repro.fl import FLConfig, run_fl

from .common import fl_common, row


def main(dataset: str = "cifar10"):
    out = {}
    for rayleigh in (True, False):
        tag = "rayleigh" if rayleigh else "freespace"
        for scheme in ("adaptive", "none"):
            cfg = FLConfig(dataset=dataset, iid=True, rayleigh=rayleigh,
                           strategy=scheme,
                           **fl_common(n_rounds=4, train_fraction=0.01))
            res = run_fl(cfg)
            out[(tag, scheme)] = res.times[-1]
            row(f"fig7_{tag}_{scheme}", 0.0,
                f"train_time_s={res.times[-1]:.0f};"
                f"final_acc={res.accuracies[-1]:.3f}")
    ok1 = out[("freespace", "adaptive")] <= out[("rayleigh", "adaptive")]
    ok2 = out[("freespace", "adaptive")] < out[("freespace", "none")]
    row("fig7_claims", 0.0,
        f"freespace_faster={ok1};adaptive_still_wins={ok2}")


if __name__ == "__main__":
    main()
