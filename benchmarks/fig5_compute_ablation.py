"""Fig. 5: effect of space/air compute power on the data allocation.

Sweeps (f_S, f_A) as in the paper and reports the per-layer data portions
chosen by the adaptive optimizer, confirming: more satellite compute =>
more data at the space layer; with both layers strong, ground keeps only
its sensitive share (1 - alpha)."""
from __future__ import annotations

import numpy as np

from repro.core import build_default_sagin, optimize_offloading

from .common import row


def portions(f_s: float, f_a: float, alpha: float = 0.8, seed: int = 0):
    sagin = build_default_sagin(
        n_devices=10, n_air=2, alpha=alpha, seed=seed,
        sat_f_list=[f_s] * 3,
        coverage_times=[300.0, 600.0, 1e9])
    for a in sagin.air_nodes:
        a.f = f_a
    plan = optimize_offloading(sagin)
    g, a, s = plan.new_sizes(sagin)
    total = sum(g) + sum(a) + s
    return (max(0.0, sum(g) / total), max(0.0, sum(a) / total),
            max(0.0, s / total), plan.round_latency)


def main():
    cases = [
        ("fS3e9_fA1e9", 3e9, 1e9),
        ("fS3e9_fA3e9", 3e9, 3e9),
        ("fS1e10_fA1e9", 1e10, 1e9),
        ("fS1e10_fA3e9", 1e10, 3e9),
    ]
    res = {}
    for name, fs, fa in cases:
        g, a, s, lat = portions(fs, fa)
        res[name] = (g, a, s)
        row(f"fig5_{name}", 0.0,
            f"ground={g:.2f};air={a:.2f};space={s:.2f};latency_s={lat:.0f}")
    # paper claims (Fig. 5a): the equilibrium here is pinned by the
    # sensitive-data floor at the ground layer, so air share responds to
    # f_A only weakly (non-decreasing); space share responds to f_S.
    ok1 = res["fS1e10_fA1e9"][2] > res["fS3e9_fA1e9"][2]   # more f_S -> more space
    ok2 = res["fS3e9_fA3e9"][1] >= res["fS3e9_fA1e9"][1] - 1e-3
    ok3 = res["fS1e10_fA3e9"][0] <= 0.25                   # ground keeps ~1-alpha
    row("fig5_claims", 0.0, f"fS_up_space_up={ok1};fA_up_air_up={ok2};"
        f"ground_floor_alpha={ok3}")


if __name__ == "__main__":
    main()
