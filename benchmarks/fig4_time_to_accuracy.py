"""Fig. 4: accuracy-vs-training-time for all six schemes.

Runs the full FL simulation (analytic SAGIN latency + real training on the
synthetic datasets) per scheme and reports the training time needed to hit
a target accuracy, plus the final accuracy. The paper's headline claim —
adaptive space+air+ground offloading reaches the target fastest — is
checked by the ordering of the derived column.
"""
from __future__ import annotations

import time

from repro.fl import ALL_SCHEMES, FLConfig, run_fl

from .common import FULL, fl_common, row


def main(dataset: str = "mnist", iid: bool = True):
    """Equal TRAINING-TIME protocol (the paper's Fig. 4 reads accuracy vs
    training time): the no-offloading baseline sets the time budget; every
    other scheme runs as many rounds as fit in that budget."""
    target = 0.60 if not FULL else 0.95
    common = fl_common()
    base_rounds = common.pop("n_rounds")
    results = {}
    none_res = run_fl(FLConfig(dataset=dataset, iid=iid, strategy="none",
                               n_rounds=base_rounds, **common))
    budget = none_res.times[-1]
    results["none"] = none_res
    row(f"fig4_{dataset}_{'iid' if iid else 'noniid'}_none", 0.0,
        f"rounds={base_rounds};train_time_s={budget:.0f};"
        f"final_acc={none_res.accuracies[-1]:.3f}")
    for scheme in ALL_SCHEMES:
        if scheme == "none":
            continue
        probe = run_fl(FLConfig(dataset=dataset, iid=iid, strategy=scheme,
                                n_rounds=1, **common))
        per_round = max(probe.latencies[-1], 1e-9)
        n_rounds = int(min(max(base_rounds, budget // per_round),
                           6 * base_rounds))
        res = run_fl(FLConfig(dataset=dataset, iid=iid, strategy=scheme,
                              n_rounds=n_rounds, **common))
        # truncate to the budget
        upto = [i for i, t in enumerate(res.times) if t <= budget * 1.001]
        last = upto[-1] if upto else 0
        results[scheme] = res
        tta = res.time_to_accuracy(target)
        row(f"fig4_{dataset}_{'iid' if iid else 'noniid'}_{scheme}", 0.0,
            f"rounds_in_budget={last + 1};"
            f"acc_at_budget={res.accuracies[last]:.3f};"
            f"tta{target:.0%}={'%.0f' % tta if tta else 'n/a'}")
    # headline: at the no-offloading baseline's time budget, adaptive has
    # run more rounds and reached at-least-as-good accuracy
    ad = results["adaptive"]
    upto = [i for i, t in enumerate(ad.times) if t <= budget * 1.001]
    acc_ad = ad.accuracies[upto[-1]] if upto else 0.0
    ok_time = ad.latencies[-1] < none_res.latencies[-1]
    ok_acc = acc_ad >= none_res.accuracies[-1] - 0.02
    row(f"fig4_{dataset}_claim_adaptive_faster", 0.0,
        f"holds={ok_time};acc_at_equal_time_ge={ok_acc}")


if __name__ == "__main__":
    main()
