"""Fig. 2 mechanism: space-layer latency vs coverage windows/handover.

Verifies the closed-form latency behaviour of eqs. (8)-(12): shorter
coverage windows force more handovers, and each handover pays the eq.-(7)
ISL delay; beyond a point, offloading to space stops being attractive and
the adaptive optimizer routes data elsewhere."""
from __future__ import annotations

import numpy as np

from repro.core import build_default_sagin, optimize_offloading, space_schedule
from repro.core.network import Satellite

from .common import row


def main():
    base = build_default_sagin(n_devices=10, n_air=2, seed=0)
    n = 9600
    prev = None
    for window in (2000.0, 500.0, 120.0, 30.0):
        sagin = build_default_sagin(n_devices=10, n_air=2, seed=0)
        sagin.satellites = [
            Satellite(i, f=3e9, coverage_end=window * (i + 1))
            for i in range(40)]
        sch = space_schedule(n, sagin)
        row(f"handover_window{window:.0f}s", 0.0,
            f"latency_s={sch.total_latency:.0f};"
            f"handovers={sch.n_handovers}")
        if prev is not None:
            assert sch.total_latency >= prev - 1e-6, "shorter windows slower"
        prev = sch.total_latency
    # with very short windows the optimizer should keep data off the space
    # layer (the handover tax dominates)
    sagin = build_default_sagin(n_devices=10, n_air=2, seed=0)
    sagin.satellites = [Satellite(i, f=3e9, coverage_end=30.0 * (i + 1))
                        for i in range(40)]
    plan = optimize_offloading(sagin)
    g, a, s = plan.new_sizes(sagin)
    total = sum(g) + sum(a) + s
    sagin2 = build_default_sagin(n_devices=10, n_air=2, seed=0)
    sagin2.satellites = [Satellite(0, f=3e9, coverage_end=np.inf)]
    plan2 = optimize_offloading(sagin2)
    g2, a2, s2 = plan2.new_sizes(sagin2)
    row("handover_adaptive_response", 0.0,
        f"space_share_short_cov={s/total:.2f};"
        f"space_share_long_cov={s2/(sum(g2)+sum(a2)+s2):.2f};"
        f"adapts={s/total <= s2/(sum(g2)+sum(a2)+s2) + 1e-6}")


if __name__ == "__main__":
    main()
