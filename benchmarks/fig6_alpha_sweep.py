"""Fig. 6: effect of the non-sensitive portion alpha.

alpha = 0 reduces to conventional FL with no offloading; larger alpha gives
the optimizer more freedom and must reach the target accuracy faster."""
from __future__ import annotations

from repro.fl import FLConfig, run_fl

from .common import fl_common, row


def main(dataset: str = "mnist"):
    times = {}
    for alpha in (0.0, 0.4, 0.8):
        cfg = FLConfig(dataset=dataset, iid=False, alpha=alpha,
                       strategy="adaptive", **fl_common())
        res = run_fl(cfg)
        times[alpha] = res.times[-1]
        row(f"fig6_alpha{alpha:.1f}", 0.0,
            f"train_time_s={res.times[-1]:.0f};"
            f"final_acc={res.accuracies[-1]:.3f}")
    ok = times[0.8] < times[0.4] < times[0.0] * 1.001
    row("fig6_claim_alpha_monotone", 0.0, f"holds={ok}")


if __name__ == "__main__":
    main()
