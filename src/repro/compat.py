"""JAX-version compatibility shims.

The reproduction targets a range of JAX releases, and two APIs it relies on
moved/changed shape across that range:

* ``shard_map`` graduated from ``jax.experimental.shard_map.shard_map``
  to top-level ``jax.shard_map`` (jax >= 0.4.35 exposes one or the other,
  newer releases only the top-level name).
* ``Compiled.cost_analysis()`` historically returned a list with one dict
  per program, and newer releases return the dict directly.

Everything that touches either API goes through this module so the rest of
the codebase can be written against a single stable surface.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

__all__ = ["shard_map", "normalize_cost_analysis", "cost_analysis"]


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm  # noqa: F811
    return sm


#: Version-stable ``shard_map`` (prefers ``jax.shard_map``, falls back to
#: ``jax.experimental.shard_map.shard_map`` on older releases).
shard_map = _resolve_shard_map()


def normalize_cost_analysis(cost: Any) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` output to a flat dict.

    Accepts the raw return value in any of its historical shapes
    (``None``, ``{...}``, or ``[{...}]``) and always returns a dict, so
    callers can do ``cost["flops"]`` regardless of the JAX version.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` with the version shim applied."""
    return normalize_cost_analysis(compiled.cost_analysis())
