"""Scenario-coupled inference request arrivals (the serving workload).

The serving half of the paper's story needs *traffic*: this module turns
a scenario's dynamics into per-region request arrival processes the
gateway can admit.  Each region runs a non-homogeneous Poisson process
whose instantaneous rate is the product of four factors:

* a **diurnal load curve** — ``1 + amplitude * sin(2*pi*(t/period +
  phase))`` with the phase derived from the region's longitude, so
  "local evening" peaks at different simulated instants per region;
* **burst episodes** — a 2-state Gilbert–Elliott chain per region
  (``burst_markov=(p_enter, p_exit)`` per slot, the exact idiom of
  :meth:`repro.sim.dynamics.NetworkDynamics._ge_step`) multiplies the
  rate by ``burst_multiplier`` while in the burst state.  One uniform
  is drawn per slot regardless of state, so the draw count — hence the
  whole arrival trajectory — never depends on the realized episodes;
* **device-churn scaling** — the online fraction of the region's client
  population (sampled from the scenario's ``churn_prob``) scales the
  rate: offline devices issue no requests;
* the configured ``base_rate`` (requests/s per region at nominal load).

Randomness is fully threaded: every region's workload draws from its own
:class:`numpy.random.Generator` rooted at ``region_seed(seed, i)`` but
folded with a serve-plane stream constant, so the serving traffic is
seeded and replayable WITHOUT consuming a single draw from the training
streams (trajectory bit-identity with a gateway attached is test-locked).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Tuple

import numpy as np

#: Stream fold distinguishing serve-plane RNGs from the training streams
#: rooted at the same ``region_seed`` ("SERV" in ASCII).
SERVE_STREAM = 0x53455256


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-gateway wiring for one run (``FLConfig.serve`` /
    ``Scenario.serve``; ``FLConfig`` wins when both are set).

    ``base_rate`` is requests/s per region at nominal population and
    mid-curve load.  ``burst_markov=(p_enter, p_exit)`` arms the
    Gilbert–Elliott burst chain (per ``dt`` slot); ``None`` keeps
    arrivals burst-free.  ``router`` names a registered policy from
    :mod:`repro.serve.router`.  ``batch_align``/``max_batch`` control
    the gateway's geometric request batching (compile-once shapes);
    ``max_batch=1`` degenerates to per-request dispatch (the benchmark
    baseline).  ``link_refresh`` is how often (simulated seconds) the
    gateway re-samples the serving-plane link state from the scenario's
    :class:`~repro.sim.dynamics.DynamicsConfig`.
    """
    base_rate: float = 2.0
    diurnal_amplitude: float = 0.5
    diurnal_period: float = 86400.0
    burst_markov: Optional[Tuple[float, float]] = None
    burst_multiplier: float = 6.0
    churn_coupling: bool = True
    dt: float = 1.0
    link_refresh: float = 30.0
    router: str = "min_rt"
    batch_align: int = 8
    max_batch: int = 64

    def __post_init__(self):
        if self.base_rate < 0:
            raise ValueError(f"base_rate must be >= 0, got {self.base_rate}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError(f"diurnal_amplitude must be in [0, 1), got "
                             f"{self.diurnal_amplitude}")
        if self.burst_markov is not None:
            p_enter, p_exit = self.burst_markov
            if not (0.0 <= p_enter <= 1.0 and 0.0 < p_exit <= 1.0):
                raise ValueError(
                    f"burst_markov=(p_enter={p_enter}, p_exit={p_exit}) "
                    f"needs p_enter in [0, 1] and p_exit in (0, 1]")
        if self.burst_multiplier < 1.0:
            raise ValueError(f"burst_multiplier must be >= 1, got "
                             f"{self.burst_multiplier}")
        if self.dt <= 0:
            raise ValueError(f"dt must be > 0, got {self.dt}")
        if self.max_batch < 1 or self.batch_align < 1:
            raise ValueError(f"max_batch/batch_align must be >= 1, got "
                             f"{self.max_batch}/{self.batch_align}")


def serve_rng(seed: int, region_index: int) -> np.random.Generator:
    """Serve-plane generator for one region: rooted at the region's
    canonical seed, folded with :data:`SERVE_STREAM` so it never aliases
    the training/dynamics streams of :func:`repro.sim.engine.region_streams`.
    """
    from repro.sim.engine import region_seed
    return np.random.default_rng((region_seed(seed, region_index),
                                  SERVE_STREAM))


@dataclasses.dataclass
class Request:
    """One inference request admitted by the gateway."""
    rid: int                    # unique per gateway, admission order
    region: int                 # originating region index
    t_arrival: float            # simulated arrival instant (s)
    sample: int                 # index into the origin region's eval batch
    # routing / completion (filled in by the gateway) -----------------------
    target: Tuple[str, int] = ("sat", -1)   # (kind, region) node key
    t_done: float = -1.0
    latency: float = -1.0       # end-to-end simulated seconds
    wait: float = 0.0           # queueing share of the latency (s)
    correct: Optional[bool] = None


class RegionWorkload:
    """Per-region arrival process over simulated time slots.

    ``step(t0)`` advances one ``cfg.dt`` slot starting at ``t0`` and
    returns the slot's arrivals as ``(offset, sample)`` pairs —
    offsets are uniform within the slot and sorted, sample indices
    address the region's eval set.  The burst chain advances EVERY slot
    with exactly one uniform (state-independent draw count), and the
    churn thinning draws one binomial per slot when armed.
    """

    def __init__(self, cfg: ServeConfig, region_index: int, seed: int,
                 n_eval: int, n_devices: int = 0, churn_prob: float = 0.0,
                 phase: float = 0.0):
        if n_eval < 1:
            raise ValueError(f"region {region_index}: empty eval set")
        self.cfg = cfg
        self.region_index = region_index
        self.rng = serve_rng(seed, region_index)
        self.n_eval = int(n_eval)
        self.n_devices = int(n_devices)
        self.churn_prob = float(churn_prob) if cfg.churn_coupling else 0.0
        self.phase = float(phase)
        self.bursting = False

    def rate_at(self, t: float) -> float:
        """Instantaneous request rate (requests/s) BEFORE churn thinning."""
        cfg = self.cfg
        diurnal = 1.0 + cfg.diurnal_amplitude * math.sin(
            2.0 * math.pi * (t / cfg.diurnal_period + self.phase))
        burst = cfg.burst_multiplier if self.bursting else 1.0
        return cfg.base_rate * diurnal * burst

    def step(self, t0: float) -> List[Tuple[float, int]]:
        cfg = self.cfg
        rng = self.rng
        if cfg.burst_markov is not None:
            p_enter, p_exit = cfg.burst_markov
            u = rng.random()
            # the Gilbert–Elliott transition of sim.dynamics._ge_step:
            # quiet slots enter a burst with p_enter, bursting slots
            # exit with p_exit — one uniform per slot either way
            self.bursting = (u >= p_exit) if self.bursting else (u < p_enter)
        online = 1.0
        if self.churn_prob > 0.0 and self.n_devices > 0:
            online = rng.binomial(self.n_devices,
                                  1.0 - self.churn_prob) / self.n_devices
        lam = self.rate_at(t0) * online * cfg.dt
        n = int(rng.poisson(lam)) if lam > 0 else 0
        if n == 0:
            return []
        offsets = np.sort(rng.random(n)) * cfg.dt
        samples = rng.integers(0, self.n_eval, size=n)
        return [(float(o), int(s)) for o, s in zip(offsets, samples)]

    def arrivals(self, t0: float, t1: float) -> Iterator[Tuple[float, int]]:
        """Every arrival in ``[t0, t1)`` as absolute ``(t, sample)`` pairs."""
        n_slots = int(math.ceil((t1 - t0) / self.cfg.dt))
        for k in range(n_slots):
            base = t0 + k * self.cfg.dt
            for off, sample in self.step(base):
                t = base + off
                if t < t1:
                    yield t, sample
