"""Event-stepped serving gateway over a running :class:`SAGINEngine`.

:class:`ServeGateway` closes the loop the ROADMAP's north star asks
for — "serving heavy traffic" — on top of the training stack that
already exists:

* **admission** — each simulated ``dt`` slot, every region's
  :class:`~repro.serve.workload.RegionWorkload` emits arrivals; each
  request is routed AT ADMISSION by the configured router
  (:mod:`repro.serve.router`) using the live queue depths and the
  serving-plane link state (re-sampled from the scenario's
  :class:`~repro.sim.dynamics.DynamicsConfig` every ``link_refresh``
  simulated seconds);
* **batched dispatch** — at each slot boundary, every target node
  drains its queue in chunks of ``max_batch``, padded up to the
  geometric grid ``batch_align * 2**k``
  (:func:`repro.data.pipeline.next_geometric` — the cohort engine's
  compile-once idiom), and one jitted batched inference runs against
  whatever model the target's region CURRENTLY holds;
* **accounting** — per-request end-to-end simulated latency (wait +
  batched service + network), served accuracy against the origin
  region's labels, wall-clock inference throughput, and ``request`` /
  ``serve_batch`` spans + ``serve.*`` metrics into the run's shared
  :class:`repro.obs.Tracer`.

The gateway is strictly READ-ONLY on training state: it never writes a
trainer's params, never consumes a training/dynamics RNG draw (all
serve-plane streams are rooted at
:func:`repro.serve.workload.serve_rng`), and never moves a region's
wall clock — attaching one to an engine leaves training trajectories
bit-identical (test-locked in ``tests/test_serve.py``).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.network import F_GROUND
from repro.data.pipeline import next_geometric
from repro.obs import resolve_obs
from repro.serve.router import (LinkState, NodeKey, RouteDecision,
                                ServeTopology, get_router)
from repro.serve.workload import (Request, RegionWorkload, ServeConfig,
                                  serve_rng)


def resolve_serve(value) -> ServeConfig:
    """Coerce an ``FLConfig.serve``/``Scenario.serve`` value: ``None``
    means the default :class:`ServeConfig`."""
    if value is None:
        return ServeConfig()
    if isinstance(value, ServeConfig):
        return value
    raise TypeError(f"serve must be None or a ServeConfig, got "
                    f"{type(value).__name__}")


@dataclasses.dataclass
class ServeReport:
    """Headline numbers of one gateway session."""
    router: str
    duration: float                 # simulated seconds served
    requests: int                   # admitted
    served: int                     # completed (== admitted: queues drain)
    batches: int                    # jitted dispatches issued
    qps_sim: float                  # served / simulated duration
    qps_wall: float                 # served / wall seconds spent in inference
    latency_p50: float              # end-to-end simulated seconds
    latency_p99: float
    latency_mean: float
    wait_mean: float                # queueing share of the latency
    served_accuracy: Optional[float]        # None: backend has no labels
    acc_by_region: Dict[str, float] = dataclasses.field(default_factory=dict)
    count_by_target: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        acc = ("-" if self.served_accuracy is None
               else f"{self.served_accuracy:.3f}")
        targets = " ".join(f"{k}={n}"
                           for k, n in sorted(self.count_by_target.items()))
        return (f"router={self.router} served={self.served}/{self.requests} "
                f"batches={self.batches} qps_sim={self.qps_sim:.2f} "
                f"qps_wall={self.qps_wall:.0f} "
                f"p50={self.latency_p50:.3f}s p99={self.latency_p99:.3f}s "
                f"acc={acc} [{targets}]")


class ServeGateway:
    """Request-driven serving over an FL-mode :class:`SAGINEngine`.

    ``serve`` overrides the resolved config (argument >
    ``FLConfig.serve`` > ``Scenario.serve`` > defaults); ``tracer``
    overrides the engine's shared tracer; ``backend`` swaps the model
    executor (default: :class:`~repro.serve.backends.CNNBackend` over
    the engine's live region models).
    """

    def __init__(self, engine, serve: Optional[ServeConfig] = None,
                 tracer=None, backend=None):
        if not getattr(engine, "trainers", None):
            raise ValueError("ServeGateway needs an FL-mode SAGINEngine "
                             "(construct it with fl=FLConfig(...))")
        self.engine = engine
        self.scenario = engine.scenario
        if serve is not None:
            cfg = serve
        elif engine.fl_config is not None and engine.fl_config.serve is not None:
            cfg = resolve_serve(engine.fl_config.serve)
        else:
            cfg = resolve_serve(getattr(self.scenario, "serve", None))
        self.cfg = cfg
        self.tracer = resolve_obs(tracer) if tracer is not None \
            else engine.tracer

        trainers = engine.trainers
        seed = engine.fl_config.seed
        fed = engine.federation
        topology = fed.topology if fed is not None else "ring"
        self.topo = ServeTopology(
            sat_f=[t.sagin.satellites[0].f for t in trainers],
            ground_f=F_GROUND,
            req_bits=trainers[0].ds.sample_bits,
            z_isl=trainers[0].sagin.z_isl,
            topology=topology)
        self.router = get_router(cfg.router, self.topo)
        self.workloads = [
            RegionWorkload(
                cfg, i, seed, n_eval=len(t.x_eval),
                n_devices=t.cfg.n_devices,
                churn_prob=(self.scenario.dynamics.churn_prob
                            if self.scenario.dynamics is not None else 0.0),
                phase=(t.region.lon_deg / 360.0
                       if t.region is not None else 0.0))
            for i, t in enumerate(trainers)]
        # serving-plane link dynamics: same DynamicsConfig as training,
        # independent serve-rooted streams (training draws untouched)
        self._link_dyn = None
        if self.scenario.dynamics is not None:
            from repro.sim.dynamics import NetworkDynamics
            self._link_dyn = [
                NetworkDynamics(self.scenario.dynamics,
                                rng=serve_rng(seed, i).spawn(1)[0])
                for i in range(len(trainers))]
        self.links: Dict[int, LinkState] = {
            i: LinkState() for i in range(len(trainers))}
        self._link_round = 0

        from repro.serve.backends import CNNBackend
        self.backend = backend if backend is not None \
            else CNNBackend(trainers)
        # origin-region eval data, host-side, gathered per batch
        # (read-only views of the trainers' eval tensors)
        self._x = [np.asarray(t.x_eval) for t in trainers]
        self._y = [np.asarray(t.y_eval) for t in trainers]

        self.queues: Dict[NodeKey, List[Tuple[Request, RouteDecision]]] = {}
        self.busy_until: Dict[NodeKey, float] = {}
        self.completed: List[Request] = []
        self.n_batches = 0
        self.wall_infer = 0.0       # wall seconds inside jitted inference
        self._rid = 0

    # -- link state ---------------------------------------------------------
    def _refresh_links(self) -> None:
        if self._link_dyn is None:
            return
        for i, dyn in enumerate(self._link_dyn):
            ev = dyn.sample_round(self._link_round, n_sats=1, n_clusters=1,
                                  n_devices=0)
            self.links[i] = LinkState(
                isl_scale=float(ev.isl_scale),
                uplink_delay=float(sum(ev.uplink_delays.values())),
                rate_scale=float(ev.rate_scale))
        self._link_round += 1

    # -- main loop ----------------------------------------------------------
    def run(self, duration: float, t0: Optional[float] = None) -> ServeReport:
        """Serve ``duration`` simulated seconds of traffic starting at
        ``t0`` (default: the latest region wall clock — "now").  Admits
        arrivals slot by slot, dispatches each node's queue at every
        slot boundary, drains all queues at the end, and returns the
        session's :class:`ServeReport`."""
        cfg = self.cfg
        if t0 is None:
            t0 = max(t.wall_clock for t in self.engine.trainers)
        n_slots = int(math.ceil(duration / cfg.dt))
        refresh_every = max(1, int(round(cfg.link_refresh / cfg.dt)))
        n_admitted_before = self._rid
        served_before = len(self.completed)
        wall_before = self.wall_infer
        tr = self.tracer
        for k in range(n_slots):
            t_slot = t0 + k * cfg.dt
            if k % refresh_every == 0:
                self._refresh_links()
            for i, wl in enumerate(self.workloads):
                for off, sample in wl.step(t_slot):
                    self._admit(i, t_slot + off, sample)
            t_edge = t_slot + cfg.dt
            self._dispatch_all(t_edge)
        report = self._report(duration,
                              requests=self._rid - n_admitted_before,
                              served_from=served_before,
                              wall_from=wall_before)
        if tr.enabled:
            tr.flush()
        return report

    def _admit(self, origin: int, t: float, sample: int) -> None:
        req = Request(rid=self._rid, region=origin, t_arrival=t,
                      sample=sample)
        self._rid += 1
        depth = {node: len(q) for node, q in self.queues.items()}
        dec = self.router.route(origin, depth, self.links)
        req.target = dec.target
        self.queues.setdefault(dec.target, []).append((req, dec))
        tr = self.tracer
        if tr.enabled:
            tr.metrics.counter("serve.requests").inc()
            tr.metrics.histogram("serve.est_response_s").observe(
                dec.est_response)

    def _dispatch_all(self, t_now: float) -> None:
        for node in sorted(self.queues):
            q = self.queues[node]
            while q:
                chunk = q[:self.cfg.max_batch]
                del q[:self.cfg.max_batch]
                self._dispatch(node, chunk, t_now)

    def _dispatch(self, node: NodeKey,
                  chunk: List[Tuple[Request, RouteDecision]],
                  t_now: float) -> None:
        """One batched inference at ``node``: pad the chunk to the
        geometric width, execute against the node's region model, and
        complete every request in the chunk."""
        cfg = self.cfg
        n = len(chunk)
        pad = next_geometric(n, cfg.batch_align)
        kind, j = node
        model_region = j
        samples = np.zeros(pad, dtype=np.int64)
        x = np.zeros((pad,) + self._x[0].shape[1:], dtype=self._x[0].dtype)
        for p, (req, _) in enumerate(chunk):
            samples[p] = req.sample
            x[p] = self._x[req.region][req.sample]

        w0 = time.perf_counter()
        preds = self.backend.predict(model_region, x, samples)
        infer_wall = time.perf_counter() - w0
        self.wall_infer += infer_wall
        self.n_batches += 1

        dispatch_t = max(t_now, self.busy_until.get(node, 0.0))
        service_sim = n * self.topo.service_time(node)
        self.busy_until[node] = dispatch_t + service_sim
        tr = self.tracer
        region_name = self.engine.scenario.regions[j].name
        for p, (req, dec) in enumerate(chunk):
            req.t_done = dispatch_t + service_sim + dec.network
            req.latency = req.t_done - req.t_arrival
            req.wait = dispatch_t - req.t_arrival
            if preds is not None:
                req.correct = bool(
                    preds[p] == self._y[req.region][req.sample])
            self.completed.append(req)
            if tr.enabled:
                origin_name = self.engine.scenario.regions[req.region].name
                route = ("ground" if kind == "ground"
                         else ("sat" if j == req.region else "isl"))
                tr.span("request", f"req{req.rid}", region=origin_name,
                        round=-1, t_sim=req.t_arrival, dur_sim=req.latency,
                        target=f"{kind}{j}", route=route,
                        wait_s=req.wait,
                        network_s=dec.network, correct=req.correct)
                tr.metrics.histogram("serve.latency_s",
                                     window=4096).observe(req.latency)
                tr.metrics.histogram("serve.wait_s", window=4096).observe(
                    dispatch_t - req.t_arrival)
                if req.correct is not None:
                    tr.metrics.counter("serve.correct").inc(
                        1.0 if req.correct else 0.0)
        if tr.enabled:
            tr.span("serve_batch", f"{kind}{j}/b{self.n_batches}",
                    region=region_name, round=-1, t_sim=dispatch_t,
                    dur_sim=service_sim, dur_wall=infer_wall,
                    node=f"{kind}{j}", n_real=n, n_pad=pad,
                    queue_after=len(self.queues.get(node, ())))
            tr.metrics.counter("serve.batches").inc()
            tr.metrics.histogram("serve.batch_real").observe(n)
            tr.metrics.gauge(f"serve.queue_depth.{kind}{j}").set(
                len(self.queues.get(node, ())))

    # -- reporting ----------------------------------------------------------
    def _report(self, duration: float, requests: int, served_from: int,
                wall_from: float) -> ServeReport:
        done = self.completed[served_from:]
        lats = np.asarray([r.latency for r in done], dtype=np.float64)
        served = len(done)
        wall = self.wall_infer - wall_from
        acc: Optional[float] = None
        acc_by_region: Dict[str, float] = {}
        if self.backend.has_labels and served:
            flags = np.asarray([bool(r.correct) for r in done])
            acc = float(flags.mean())
            for i, region in enumerate(self.engine.scenario.regions):
                mask = np.asarray([r.region == i for r in done])
                if mask.any():
                    acc_by_region[region.name] = float(flags[mask].mean())
        count_by_target: Dict[str, int] = {}
        for r in done:
            kind, j = r.target
            label = kind if (kind == "ground" or j == r.region) else "isl"
            count_by_target[label] = count_by_target.get(label, 0) + 1
        return ServeReport(
            router=self.router.name, duration=duration, requests=requests,
            served=served, batches=self.n_batches,
            qps_sim=served / duration if duration > 0 else 0.0,
            qps_wall=served / wall if wall > 0 else 0.0,
            latency_p50=float(np.percentile(lats, 50)) if served else 0.0,
            latency_p99=float(np.percentile(lats, 99)) if served else 0.0,
            latency_mean=float(lats.mean()) if served else 0.0,
            wait_mean=float(np.mean([r.wait for r in done]))
            if served else 0.0,
            served_accuracy=acc, acc_by_region=acc_by_region,
            count_by_target=count_by_target)
