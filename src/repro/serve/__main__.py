"""CLI: train a few rounds on a scenario, then serve traffic against it.

    python -m repro.serve --scenario multi_region --rounds 2 \
        --duration 600 --router min_rt --trace serve.jsonl

Prints the gateway's :class:`~repro.serve.gateway.ServeReport` summary
plus a per-region served-accuracy table; ``--trace`` writes the shared
training+serving JSONL trace (inspect with ``python -m repro.obs
report``).  Exit code 0 on a completed session, 2 on bad arguments.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve")
    ap.add_argument("--scenario", default="multi_region")
    ap.add_argument("--rounds", type=int, default=2,
                    help="FL training rounds before serving")
    ap.add_argument("--duration", type=float, default=600.0,
                    help="simulated seconds of serving traffic")
    ap.add_argument("--router", default=None,
                    help="override the scenario's router "
                         "(min_rt | static_nearest)")
    ap.add_argument("--backend", default="cnn",
                    choices=("cnn", "transformer"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-devices", type=int, default=6)
    ap.add_argument("--train-fraction", type=float, default=0.01)
    ap.add_argument("--trace", default=None,
                    help="JSONL trace path (training + serving spans)")
    args = ap.parse_args(argv)

    from repro.fl.rounds import FLConfig
    from repro.scenarios import get_scenario
    from repro.serve.gateway import ServeGateway, resolve_serve
    from repro.sim.engine import SAGINEngine

    try:
        scn = get_scenario(args.scenario)
    except ValueError as e:
        print(e, file=sys.stderr)
        return 2

    fl = FLConfig(n_devices=args.n_devices, n_air=1, h_local=1,
                  train_fraction=args.train_fraction, eval_size=256,
                  execution="sequential", seed=args.seed, obs=args.trace)
    engine = SAGINEngine(scn, fl=fl)
    print(f"# training {args.rounds} round(s) on {scn.name} "
          f"({len(scn.regions)} region(s))", flush=True)
    engine.run(args.rounds)

    serve = resolve_serve(fl.serve if fl.serve is not None else scn.serve)
    if args.router is not None:
        serve = dataclasses.replace(serve, router=args.router)
    backend = None
    if args.backend == "transformer":
        from repro.serve.backends import TransformerBackend
        backend = TransformerBackend()
    try:
        gw = ServeGateway(engine, serve=serve, backend=backend)
    except ValueError as e:          # e.g. an unknown --router name
        print(e, file=sys.stderr)
        return 2
    print(f"# serving {args.duration:.0f} simulated seconds "
          f"(router={serve.router}, backend={args.backend})", flush=True)
    report = gw.run(args.duration)
    print(report.summary())
    for name, acc in sorted(report.acc_by_region.items()):
        print(f"  {name}: served_acc={acc:.3f}")
    if args.trace:
        print(f"# trace written to {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
