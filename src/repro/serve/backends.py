"""Model-execution backends for the serving gateway.

The gateway separates *what runs* from *how traffic is shaped*:

* :class:`CNNBackend` (default) serves each region's CURRENT federated
  CNN — the model the region trainer holds right now, which is exactly
  what makes federation staleness visible as served accuracy; one jitted
  argmax-predict per region, compiled once per padded batch width.
* :class:`TransformerBackend` dispatches one-token decode steps through
  :func:`repro.launch.serve.make_serve_step` — the production pjit
  serving path (sharded KV cache, donated between steps) — so the same
  gateway can push transformer traffic.  Requests map to token batches;
  there are no labels, so served accuracy is reported as ``None``.

Backends expose ``predict(model_region, x, samples)`` returning an int
prediction array (or ``None`` when the workload has no ground truth)
and a ``has_labels`` flag; both inputs are padded to the gateway's
geometric batch width so compiled signatures are reused across
dispatches.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CNNBackend:
    """Serve each region's live federated model (read-only).

    ``predict`` reads ``trainers[j].params`` AT DISPATCH TIME — never a
    copy taken at construction — so a merge installed between serve
    ticks is immediately visible, and a stale region under
    ``soft_async``/``partial`` federation serves its stale model.
    """

    has_labels = True

    def __init__(self, trainers: List):
        self.trainers = trainers
        self._predict: Dict[int, object] = {}

    def _fn(self, j: int):
        fn = self._predict.get(j)
        if fn is None:
            apply_fn = self.trainers[j].apply_fn
            fn = jax.jit(lambda p, x: jnp.argmax(apply_fn(p, x), -1))
            self._predict[j] = fn
        return fn

    def predict(self, model_region: int, x: np.ndarray,
                samples: np.ndarray) -> Optional[np.ndarray]:
        params = self.trainers[model_region].params
        preds = self._fn(model_region)(params, jnp.asarray(x))
        return np.asarray(jax.block_until_ready(preds))


class TransformerBackend:
    """One-token decode through the pjit ``make_serve_step`` path.

    Builds one jitted step (plus its KV cache) per padded batch width
    on a single-device ``(data, model)`` mesh; caches are threaded
    through successive dispatches of the same width (the donated-buffer
    discipline of the production path).  Request sample ids map to
    vocabulary tokens.
    """

    has_labels = False

    def __init__(self, model_cfg=None, seq_len: int = 64,
                 donate: bool = True, seed: int = 0):
        from repro.configs import get_config
        cfg = model_cfg if model_cfg is not None else (
            get_config("llama3.2-3b").reduced(n_layers=2, d_model=64))
        self.cfg = cfg
        self.seq_len = int(seq_len)
        self.donate = donate
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.models import transformer as T
        self.params = T.init_params(cfg, jax.random.PRNGKey(seed))
        self._steps: Dict[int, object] = {}   # padded width -> jitted step
        self._caches: Dict[int, object] = {}  # padded width -> live cache
        self._pos: Dict[int, int] = {}

    def _step(self, b: int):
        step = self._steps.get(b)
        if step is None:
            from repro.configs.shapes import InputShape
            from repro.launch.serve import make_serve_step
            from repro.models import transformer as T
            shape = InputShape(f"serve_b{b}", self.seq_len, b, "decode")
            step, _ = make_serve_step(self.cfg, self.mesh, shape,
                                      donate=self.donate)
            self._steps[b] = step
            self._caches[b] = T.init_cache(self.cfg, b, self.seq_len)
            self._pos[b] = 0
        return step

    def predict(self, model_region: int, x: np.ndarray,
                samples: np.ndarray) -> Optional[np.ndarray]:
        b = len(samples)
        step = self._step(b)
        tokens = jnp.asarray(samples % self.cfg.vocab_size,
                             jnp.int32).reshape(b, 1)
        pos = self._pos[b]
        logits, new_cache = step(self.params, self._caches[b], tokens, pos)
        jax.block_until_ready(logits)
        self._caches[b] = new_cache
        self._pos[b] = (pos + 1) % self.seq_len
        return None
