"""Per-request offloading routers: where should this inference run?

Mirrors the paper's adaptive data-offloading decision on the serving
plane.  Every request admitted in region ``i`` has three candidate
execution sites:

* ``("sat", i)`` — the region's serving satellite: fast compute
  (``f ~ U[1,10]`` GHz), one ground-to-space round trip, but exposed to
  uplink dead-air outages;
* ``("isl", j)`` — a neighbouring region's serving satellite, reached
  over the ISL topology (:func:`repro.core.latency.isl_path_hops`):
  pays per-hop transmission at the live ``z_isl * isl_scale`` rate, and
  is served by whatever model region ``j`` currently holds;
* ``("ground", i)`` — the local ground fallback: negligible network
  latency but two orders of magnitude slower compute (``F_GROUND``).

:class:`MinResponseTimeRouter` picks the candidate with the smallest
*estimated* response time — propagation + transmission (outage-aware,
from the live :class:`LinkState`) + queueing (current depth times the
node's per-request service time) + the request's own service — the
serving analogue of the offloading optimizer's latency minimization.
:class:`StaticNearestRouter` is the baseline: always the originating
region's serving satellite, blind to queues and outages (exactly what
the paper's adaptive offloading improves on; the serve benchmark gates
min-rt's p99 win under ``degraded_links``).

Everything here is pure arithmetic over explicit state — no RNG, no
jax — so routing decisions are deterministic given the link snapshot.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.latency import isl_path_hops, tx_time
from repro.core.network import SAT_ALTITUDE

#: Speed of light (m/s) for propagation delays.
C_LIGHT = 3e8

#: Cycles per inference request — two orders of magnitude below the
#: paper's per-sample TRAINING cost (``M_CYCLES`` = 3e9): a forward
#: pass on one sample, no backprop, no local epochs.
INFER_CYCLES = 3e7

#: Nominal ground-to-space uplink rate for one request payload (bits/s);
#: weather scales it through ``LinkState.rate_scale``.
UPLINK_RATE = 20e6

#: Fixed last-mile latency to the local ground fallback (s).
GROUND_RTT = 2e-3

NodeKey = Tuple[str, int]       # ("sat" | "isl" | "ground", region index)

NODE_KINDS = ("sat", "isl", "ground")


@dataclasses.dataclass(frozen=True)
class LinkState:
    """One region's live serving-plane link snapshot.

    Sampled by the gateway from the scenario's
    :class:`~repro.sim.dynamics.DynamicsConfig` every ``link_refresh``
    seconds: ``isl_scale`` (<1 during an ISL fade) stretches every ISL
    hop, ``uplink_delay`` (>0 during dead-air) adds to any route
    through this region's satellite, ``rate_scale`` is the weather
    multiplier on ground/air channel rates.
    """
    isl_scale: float = 1.0
    uplink_delay: float = 0.0
    rate_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """The chosen execution site and its estimated response time (s)."""
    target: NodeKey
    est_response: float
    # estimate components, for spans/debugging
    network: float = 0.0        # propagation + transmission + outage
    queueing: float = 0.0       # depth * service
    service: float = 0.0


class ServeTopology:
    """Static facts the routers price against: per-region satellite and
    ground compute frequencies, request payload size, ISL rate/topology.

    ``sat_f[i]`` is region ``i``'s serving-satellite CPU frequency
    (heterogeneous, from the region's network model); ``req_bits`` is
    one request's payload (one sample, ``ds.sample_bits``).
    """

    def __init__(self, sat_f: List[float], ground_f: float,
                 req_bits: float, z_isl: float, topology: str = "ring"):
        if not sat_f:
            raise ValueError("ServeTopology needs >= 1 region")
        self.sat_f = [float(f) for f in sat_f]
        self.ground_f = float(ground_f)
        self.req_bits = float(req_bits)
        self.z_isl = float(z_isl)
        self.topology = topology
        self.n_regions = len(sat_f)

    def service_time(self, node: NodeKey) -> float:
        """Per-request compute time at a node (``INFER_CYCLES / f``)."""
        kind, j = node
        if kind == "ground":
            return INFER_CYCLES / self.ground_f
        return INFER_CYCLES / self.sat_f[j]

    def candidates(self, origin: int) -> List[NodeKey]:
        """Candidate execution sites for a request from ``origin``: the
        own serving satellite, the adjacent regions' satellites over the
        ISL (the SAME physical node as that region's own traffic — one
        queue per satellite), and the local ground fallback."""
        cands: List[NodeKey] = [("sat", origin)]
        n = self.n_regions
        if n > 1:
            neighbours = {(origin + 1) % n, (origin - 1) % n} - {origin}
            cands += [("sat", j) for j in sorted(neighbours)]
        cands.append(("ground", origin))
        return cands

    def network_time(self, origin: int, node: NodeKey,
                     links: Dict[int, LinkState]) -> float:
        """Network part of the estimate: propagation + transmission +
        realized outage delays along the route."""
        kind, j = node
        if kind == "ground":
            return GROUND_RTT
        ls = links.get(origin, LinkState())
        up = (tx_time(self.req_bits, UPLINK_RATE * max(ls.rate_scale, 1e-6))
              + 2.0 * SAT_ALTITUDE / C_LIGHT + ls.uplink_delay)
        if j == origin:
            return up
        # ISL neighbour: climb to the own satellite first, then hop the
        # payload across at the live (possibly faded) ISL rate
        hops = isl_path_hops(self.topology, origin, j, self.n_regions)
        scale = max(min(ls.isl_scale,
                        links.get(j, LinkState()).isl_scale), 1e-6)
        per_hop = (tx_time(self.req_bits, self.z_isl * scale)
                   + SAT_ALTITUDE / C_LIGHT)
        return up + hops * per_hop


class MinResponseTimeRouter:
    """Adaptive router: smallest estimated response time over all
    candidates, queue- and outage-aware."""

    name = "min_rt"

    def __init__(self, topo: ServeTopology):
        self.topo = topo

    def route(self, origin: int, queue_depth: Dict[NodeKey, int],
              links: Dict[int, LinkState]) -> RouteDecision:
        best: RouteDecision | None = None
        for node in self.topo.candidates(origin):
            service = self.topo.service_time(node)
            network = self.topo.network_time(origin, node, links)
            queueing = queue_depth.get(node, 0) * service
            est = network + queueing + service
            if best is None or est < best.est_response:
                best = RouteDecision(target=node, est_response=est,
                                     network=network, queueing=queueing,
                                     service=service)
        if best is None:        # candidates() always yields >= 2 sites
            raise ValueError(f"no route candidates for origin {origin}")
        return best


class StaticNearestRouter:
    """Baseline: always the originating region's serving satellite —
    the pre-offloading policy the paper's adaptive scheme replaces.
    The estimate still prices the route honestly (outages included),
    it just never influences the choice."""

    name = "static_nearest"

    def __init__(self, topo: ServeTopology):
        self.topo = topo

    def route(self, origin: int, queue_depth: Dict[NodeKey, int],
              links: Dict[int, LinkState]) -> RouteDecision:
        node: NodeKey = ("sat", origin)
        service = self.topo.service_time(node)
        network = self.topo.network_time(origin, node, links)
        queueing = queue_depth.get(node, 0) * service
        return RouteDecision(target=node,
                             est_response=network + queueing + service,
                             network=network, queueing=queueing,
                             service=service)


ROUTERS = {
    "min_rt": MinResponseTimeRouter,
    "static_nearest": StaticNearestRouter,
}


def get_router(name: str, topo: ServeTopology):
    try:
        cls = ROUTERS[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r}; available: "
                         f"{sorted(ROUTERS)}") from None
    return cls(topo)
