"""``repro.serve`` — request-driven serving over the SAGIN FL stack.

Turn a scenario's dynamics into inference traffic and route it the way
the paper routes data:

    from repro.fl import FLConfig
    from repro.serve import ServeConfig, ServeGateway
    from repro.sim import SAGINEngine

    engine = SAGINEngine("multi_region", fl=FLConfig(...))
    engine.run(4)                       # train a few rounds
    gw = ServeGateway(engine, serve=ServeConfig(base_rate=2.0))
    report = gw.run(duration=600.0)     # serve 10 simulated minutes
    print(report.summary())

or ``python -m repro.serve --scenario multi_region`` for the CLI.  See
the module docstrings of :mod:`~repro.serve.workload` (arrivals),
:mod:`~repro.serve.router` (offloading decision) and
:mod:`~repro.serve.gateway` (batched dispatch + accounting).
"""
from .backends import CNNBackend, TransformerBackend  # noqa: F401
from .gateway import ServeGateway, ServeReport, resolve_serve  # noqa: F401
from .router import (LinkState, MinResponseTimeRouter, ROUTERS,  # noqa: F401
                     RouteDecision, ServeTopology, StaticNearestRouter,
                     get_router)
from .workload import (Request, RegionWorkload, ServeConfig,  # noqa: F401
                       serve_rng)

__all__ = [
    "CNNBackend", "TransformerBackend",
    "ServeGateway", "ServeReport", "resolve_serve",
    "LinkState", "MinResponseTimeRouter", "ROUTERS", "RouteDecision",
    "ServeTopology", "StaticNearestRouter", "get_router",
    "Request", "RegionWorkload", "ServeConfig", "serve_rng",
]
