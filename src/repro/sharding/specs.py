"""PartitionSpec rules for every tensor role in the model zoo.

Sharding scheme (DESIGN.md §6):
  * ``model`` axis: tensor-parallel dims — attention heads, FFN hidden,
    experts, vocab; also the Mamba inner dim and RWKV head dim.
  * ``data`` axis: batch (with ``pod``) + FSDP over the d_model dim of
    weight matrices (the paper's air-node clusters).
  * ``pod``  axis: batch only; weights are *replicated* across pods — each
    pod is a satellite-era model replica in the FL mapping, aggregated by
    the lambda-weighted psum (eq. 13) between rounds.

Rules are keyed on weight-leaf names (see repro.models.layers docstring)
and applied by path-walking the param pytree. Stacked block params get a
leading layer axis -> specs are prepended with None automatically based on
leaf rank vs. rule rank.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape

# leaf name -> (spec without the stacked-layer axis)
_PARAM_RULES: Dict[str, Tuple] = {
    # attention (gqa + rwkv time-mix share names; same orientation)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "ww": ("data", "model"),
    "wg": ("data", "model"),
    "wr": ("data", "model"),
    "wo": ("model", "data"),
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "wkv_a": ("data", None),
    "wkv_b": (None, "model"),
    "kv_norm": (None,),
    # dense FFN / shared experts
    "w1": ("data", "model"),
    "w3": ("data", "model"),
    "w2": ("model", "data"),
    # MoE
    "router": ("data", None),
    "we1": ("model", "data", None),
    "we3": ("model", "data", None),
    "we2": ("model", None, "data"),
    # mamba
    "in_proj": ("data", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "dt_bias": ("model",),
    "a_log": ("model", None),
    "d_skip": ("model",),
    "out_proj": ("model", "data"),
    # rwkv extras
    "w_bias": ("model",),
    "u": ("model", None),
    "ln_scale": (None,),
    "mix_r": (None,),
    "mix_k": (None,),
    "mix_v": (None,),
    "mix_w": (None,),
    "mix_g": (None,),
    # rwkv channel-mix
    "wck": ("data", "model"),
    "wcv": ("model", "data"),
    "wcr": ("data", "model"),
    # norms
    "scale": (None,),
}

_TOP_LEVEL = {
    ("embed", "w"): ("model", "data"),
    ("lm_head", "w"): ("data", "model"),
    ("in_proj", "w"): ("data", None),
}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(cfg: ModelConfig, params_shape, fsdp: bool = True,
                 pod_shard_params: bool = False):
    """PartitionSpec pytree matching ``params_shape`` (shapes or arrays).

    ``fsdp=False`` drops the ``data``-axis weight sharding (weights then
    replicate across data; used in perf experiments).
    ``pod_shard_params=True`` additionally FSDP-shards the d_model dim over
    ("data","pod") — a beyond-paper memory optimization (breaks the
    per-pod-replica FL semantics, recorded in EXPERIMENTS.md §Perf).
    """
    data_axis = ("data", "pod") if pod_shard_params else "data"

    def spec_for(path, leaf):
        names = _path_names(path)
        rank = len(leaf.shape)
        # top-level (embed / lm_head / model-input proj)
        for (k0, k1), rule in _TOP_LEVEL.items():
            if k0 in names and names[-1] == k1:
                rule2 = tuple(data_axis if r == "data" and fsdp
                              else (None if r == "data" else r)
                              for r in rule)
                return P(*rule2)
        name = names[-1]
        rule = _PARAM_RULES.get(name)
        if rule is None:
            return P()
        rule = tuple(
            (data_axis if fsdp else None) if r == "data" else r
            for r in rule)
        # prepend None for the stacked block axis
        pad = rank - len(rule)
        if pad < 0:
            return P()
        return P(*([None] * pad + list(rule)))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [spec_for(p, l) for p, l in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_shape), specs)


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


# ---------------------------------------------------------------------------
# Cohort client-axis sharding (the FL mega-constellation mapping) ------------
# ---------------------------------------------------------------------------
def data_axis_size(mesh) -> int:
    """Size of the mesh's ``data`` axis (1 when absent) — the number of
    client-axis shards the cohort engine dispatches across."""
    if mesh is None:
        return 1
    return int(dict(getattr(mesh, "shape", {})).get("data", 1))


def cohort_step_specs():
    """``shard_map`` specs for one bucket dispatch of the mesh-sharded
    cohort engine: ``(in_specs, out_specs)``.

    Inputs  ``(params, xs, ys, mask, weights, lr)``: the model replicates
    while every client-stacked tensor (and the per-client aggregation
    weights) shards its leading client axis over ``data``.  Outputs
    ``(new_params, losses)``: the psum-reduced model is replicated, the
    per-client losses stay client-sharded.
    """
    client = P("data")
    return (P(), client, client, client, client, P()), (P(), client)


def data_pspec(cfg: ModelConfig, shape: InputShape, multi_pod: bool,
               which: str = "inputs"):
    """Sharding for a batch input: batch dim over (pod, data)."""
    baxes = batch_axes(multi_pod)
    b = shape.global_batch
    n_batch_shards = int(np.prod([16 if a == "data" else 2 for a in baxes]))
    batch_spec = baxes if b % n_batch_shards == 0 else (
        "data" if b % 16 == 0 else None)
    if shape.kind == "decode":
        if which == "inputs":
            # (B, 1) or (B, 1, D)
            return P(batch_spec)
        raise ValueError(which)
    # train/prefill: (B, S) or (B, S, D) and labels (B, S)
    return P(batch_spec)


def cache_pspecs(cfg: ModelConfig, cache_shape, shape: InputShape,
                 multi_pod: bool):
    """Sharding for the decode cache pytree.

    decode_32k (B=128): batch over (pod,data), attention-cache seq over
    ``model``. long_500k (B=1): cache seq over ("data","model") — sequence-
    parallel decode; state tensors (mamba/rwkv) shard their inner dim on
    ``model``.
    """
    baxes = batch_axes(multi_pod)
    b = shape.global_batch
    n_batch = int(np.prod([16 if a == "data" else 2 for a in baxes]))
    if b % n_batch == 0:
        bspec: object = baxes
        seq_axes: object = "model"
    elif b % 16 == 0:
        bspec = "data"
        seq_axes = "model"
    else:
        bspec = None
        seq_axes = ("data", "model")

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        rank = len(leaf.shape)
        # stacked leading block axis always present (rank includes it)
        if name in ("k", "v"):          # (L, B, Hkv, S, hd)
            return P(None, bspec, None, seq_axes, None)
        if name in ("c_kv", "k_rope"):  # (L, B, S, r)
            return P(None, bspec, seq_axes, None)
        if name == "h":                 # (L, B, di, st)
            return P(None, bspec, "model", None)
        if name == "conv":              # (L, B, ck-1, di)
            return P(None, bspec, None, "model")
        if name == "wkv":               # (L, B, h, hd, hd)
            return P(None, bspec, "model", None, None)
        if name in ("shift_t", "shift_c"):  # (L, B, D)
            return P(None, bspec, None)
        return P()

    leaves, _ = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [spec_for(p, l) for p, l in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache_shape), specs)
