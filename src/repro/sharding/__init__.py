from .specs import (batch_axes, cache_pspecs, data_pspec, param_pspecs)

__all__ = ["batch_axes", "cache_pspecs", "data_pspec", "param_pspecs"]
