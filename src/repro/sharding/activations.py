"""Activation sharding constraints (MaxText-style).

GSPMD propagation from weight/input shardings alone is free to re-shard
intermediate activations (e.g. replicate batch and shard heads), which both
bloats memory and distorts the roofline. The model code therefore pins key
activations via ``shard(x, ...)``, a no-op unless a mesh context has been
installed with ``set_activation_sharding`` (smoke tests on one device skip
it entirely).

Spec tokens: "batch" -> the (pod,data) batch axes of the installed context
(may be empty for batch-1 decode), "model" -> the tensor axis, None -> any.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_CTX = {"mesh": None, "batch_axes": ()}


def set_activation_sharding(mesh, batch_axes: Tuple[str, ...]):
    _CTX["mesh"] = mesh
    _CTX["batch_axes"] = tuple(batch_axes)


def clear_activation_sharding():
    _CTX["mesh"] = None
    _CTX["batch_axes"] = ()


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes: Tuple[str, ...]):
    set_activation_sharding(mesh, batch_axes)
    try:
        yield
    finally:
        clear_activation_sharding()


def shard(x, *spec):
    """Constrain ``x``; tokens: "batch", "model", None."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    batch = _CTX["batch_axes"]
    out = []
    for s in spec:
        if s == "batch":
            out.append(batch if batch else None)
        else:
            out.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))
