"""Per-node local training (eqs. 3-4, 6): H mini-batch SGD iterations.

``local_update`` is a jitted lax.scan over H steps; ``vmapped_local_update``
runs a stacked batch of clients at once (used by the mesh FL runner, where
the client axis is sharded over the device mesh).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                         axis=-1))


def make_loss_fn(apply_fn: Callable):
    def loss_fn(params, x, y):
        return cross_entropy(apply_fn(params, x), y)
    return loss_fn


@partial(jax.jit, static_argnums=(0,))
def local_update(apply_fn: Callable, params, xs, ys, lr):
    """H local SGD iterations (eq. 3/4/6).

    xs: (H, B, ...), ys: (H, B). Returns (new_params, mean_loss).
    """
    loss_fn = make_loss_fn(apply_fn)

    def step(p, batch):
        x, y = batch
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, loss

    new_params, losses = jax.lax.scan(step, params, (xs, ys))
    return new_params, jnp.mean(losses)


@partial(jax.jit, static_argnums=(0,))
def vmapped_local_update(apply_fn: Callable, stacked_params, xs, ys, lrs):
    """Run many clients at once.

    stacked_params: pytree with leading client axis C.
    xs: (C, H, B, ...), ys: (C, H, B), lrs: (C,).
    """
    def one(params, x, y, lr):
        return local_update(apply_fn, params, x, y, lr)

    return jax.vmap(one)(stacked_params, xs, ys, lrs)


@partial(jax.jit, static_argnums=(0,))
def evaluate(apply_fn: Callable, params, x, y) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Returns (loss, accuracy) over a single large batch."""
    logits = apply_fn(params, x)
    loss = cross_entropy(logits, y)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc
