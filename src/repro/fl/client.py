"""Per-node local training (eqs. 3-4, 6): H mini-batch SGD iterations.

``local_update`` is a jitted lax.scan over H steps; ``vmapped_local_update``
runs a stacked batch of clients at once (used by the mesh FL runner, where
the client axis is sharded over the device mesh).

``masked_local_update`` / ``cohort_local_update`` are the batched round
engine's versions: they accept a per-sample validity mask so clients with
heterogeneous pool sizes can share one padded ``(C, H, Bmax, ...)`` cohort
tensor. Masked slots contribute exactly zero loss and gradient, so a
client's update equals what ``local_update`` computes on its unpadded
batches (the numerical-equivalence contract of the batched engine).

``cohort_round_step`` fuses ``cohort_local_update`` with the eq.-(13)
aggregate into ONE compiled dispatch (the single-bucket fast path of
:class:`repro.fl.cohort_engine.CohortEngine`); its ``_donated`` twin
additionally donates the incoming params buffer so the global model is
updated in place on accelerator backends.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                         axis=-1))


def make_loss_fn(apply_fn: Callable):
    def loss_fn(params, x, y):
        return cross_entropy(apply_fn(params, x), y)
    return loss_fn


@partial(jax.jit, static_argnums=(0,))
def local_update(apply_fn: Callable, params, xs, ys, lr):
    """H local SGD iterations (eq. 3/4/6).

    xs: (H, B, ...), ys: (H, B). Returns (new_params, mean_loss).
    """
    loss_fn = make_loss_fn(apply_fn)

    def step(p, batch):
        x, y = batch
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, loss

    new_params, losses = jax.lax.scan(step, params, (xs, ys))
    return new_params, jnp.mean(losses)


@partial(jax.jit, static_argnums=(0,))
def vmapped_local_update(apply_fn: Callable, stacked_params, xs, ys, lrs):
    """Run many clients at once.

    stacked_params: pytree with leading client axis C.
    xs: (C, H, B, ...), ys: (C, H, B), lrs: (C,).
    """
    def one(params, x, y, lr):
        return local_update(apply_fn, params, x, y, lr)

    return jax.vmap(one)(stacked_params, xs, ys, lrs)


def masked_cross_entropy(logits, labels, mask):
    """Mean NLL over the valid (mask == 1) samples of a padded batch.

    With an all-ones mask this equals ``cross_entropy``; padded slots are
    excluded from both the numerator and the denominator, and an all-zero
    mask (a padding client) yields loss 0 with zero gradient.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


@partial(jax.jit, static_argnums=(0,))
def masked_local_update(apply_fn: Callable, params, xs, ys, mask, lr):
    """``local_update`` over padded batches.

    xs: (H, B, ...), ys: (H, B), mask: (H, B). Returns
    (new_params, mean_loss) where padded slots are ignored.
    """
    def step(p, batch):
        x, y, m = batch

        def loss_fn(p):
            return masked_cross_entropy(apply_fn(p, x), y, m)

        loss, g = jax.value_and_grad(loss_fn)(p)
        p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
        return p, loss

    new_params, losses = jax.lax.scan(step, params, (xs, ys, mask))
    return new_params, jnp.mean(losses)


@partial(jax.jit, static_argnums=(0,))
def cohort_local_update(apply_fn: Callable, params, xs, ys, mask, lr):
    """One compiled step training a whole cohort of clients.

    ``params`` is the single global model (broadcast to every client, no
    host-side replication); xs: (C, H, B, ...), ys/mask: (C, H, B).
    Returns (stacked_params with leading client axis C, per-client mean
    losses of shape (C,)). Padding clients (all-zero mask rows) come back
    with unchanged params and loss 0.
    """
    def one(x, y, m):
        return masked_local_update(apply_fn, params, x, y, m, lr)

    return jax.vmap(one)(xs, ys, mask)


def _cohort_round_impl(apply_fn: Callable, params, xs, ys, mask, weights,
                       lr):
    """Fused single-bucket round: local update + eq.-(13) aggregate in
    one compiled call.  Returns (new_global_params, per-client losses)."""
    from .aggregation import fedavg_stacked

    def one(x, y, m):
        return masked_local_update(apply_fn, params, x, y, m, lr)

    stacked, losses = jax.vmap(one)(xs, ys, mask)
    return fedavg_stacked(stacked, weights), losses


cohort_round_step = jax.jit(_cohort_round_impl, static_argnums=(0,))
# Donating variant of the fused round step: ``params`` is consumed and
# the new global params are written in place (zero-copy round-to-round
# model residency on accelerator backends; donation is a no-op warning
# on CPU, hence the split).  Callers must not reuse the donated params.
cohort_round_step_donated = jax.jit(_cohort_round_impl, static_argnums=(0,),
                                    donate_argnums=(1,))


@partial(jax.jit, static_argnums=(0,))
def evaluate(apply_fn: Callable, params, x, y) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Returns (loss, accuracy) over a single large batch."""
    logits = apply_fn(params, x)
    loss = cross_entropy(logits, y)
    acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, acc


@partial(jax.jit, static_argnums=(0,))
def stacked_evaluate(apply_fn: Callable, stacked_params, x,
                     y) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(losses, accuracies), each of shape (C,), for a stack of models
    (leading axis C) on ONE shared eval batch — the apples-to-apples
    comparison of per-region models against the merged global model."""
    return jax.vmap(lambda p: evaluate(apply_fn, p, x, y))(stacked_params)
