from .aggregation import (aggregation_weights, fedavg, fedavg_stacked,
                          fedavg_stacked_multi, hierarchical_weighted_psum,
                          staleness_merge_weights, staleness_weighted_merge)
from .baselines import (ALL_SCHEMES, BASELINES, SCHEME_HOOKS,
                        compare_schemes, run_scheme)
from .client import (cohort_local_update, cohort_round_step, cross_entropy,
                     evaluate, local_update, masked_cross_entropy,
                     masked_local_update, stacked_evaluate,
                     vmapped_local_update)
from .cohort_engine import CohortEngine, CohortEngineStats
from .rounds import FLConfig, FLResult, RegionTrainer, run_fl

__all__ = ["aggregation_weights", "fedavg", "fedavg_stacked",
           "fedavg_stacked_multi", "hierarchical_weighted_psum",
           "staleness_merge_weights", "staleness_weighted_merge",
           "ALL_SCHEMES", "BASELINES", "SCHEME_HOOKS", "compare_schemes",
           "run_scheme", "cohort_local_update", "cohort_round_step",
           "cross_entropy", "evaluate", "local_update",
           "masked_cross_entropy", "masked_local_update",
           "stacked_evaluate", "vmapped_local_update", "CohortEngine",
           "CohortEngineStats", "FLConfig", "FLResult", "RegionTrainer",
           "run_fl"]
