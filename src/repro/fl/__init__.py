"""Federated training: round loop, cohort execution, aggregation,
federation policies, baseline schemes.

Re-exports resolve lazily (PEP 562): light consumers — notably
``repro.scenarios``, which needs only ``repro.fl.federation``'s pure
dataclasses — don't pay for the jax-importing training modules until a
training symbol is actually touched.
"""
import importlib

# symbol -> defining submodule (relative)
_EXPORTS = {name: ".aggregation" for name in (
    "aggregation_weights", "fedavg", "fedavg_pytrees", "fedavg_stacked",
    "fedavg_stacked_multi", "hierarchical_weighted_psum",
    "staleness_merge_weights", "staleness_weighted_merge")}
_EXPORTS.update({name: ".baselines" for name in (
    "ALL_SCHEMES", "BASELINES", "SCHEME_HOOKS", "compare_schemes",
    "run_scheme")})
_EXPORTS.update({name: ".client" for name in (
    "cohort_local_update", "cohort_round_step", "cross_entropy",
    "evaluate", "local_update", "masked_cross_entropy",
    "masked_local_update", "stacked_evaluate", "vmapped_local_update")})
_EXPORTS.update({name: ".cohort_engine" for name in (
    "CohortEngine", "CohortEngineStats")})
_EXPORTS.update({name: ".federation" for name in (
    "FederationConfig", "FederationState", "MergePlan", "MergePolicy",
    "RegionFedState", "get_policy", "list_policies", "register_policy",
    "resolve_federation")})
_EXPORTS.update({name: ".rounds" for name in (
    "FLConfig", "FLResult", "RegionTrainer", "run_fl")})

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        submodule = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None
    value = getattr(importlib.import_module(submodule, __name__), name)
    globals()[name] = value  # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
