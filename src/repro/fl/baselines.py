"""The paper's comparison schemes (Section VI-A) as executable
data-placement policies.

Every scheme name maps to an orchestrator strategy hook (a
``(orchestrator, round) -> OffloadPlan`` callable registered in
``repro.core.strategies``) rather than a bare string; model aggregation
is FedAvg (eq. 13) in every scheme, as in the paper.

- ``none``         : no data offloading (space/air only aggregate).
- ``air_ground``   : offloading only between air and ground layers.
- ``ground_space`` : offloading only between ground and space (air relays).
- ``static``       : adaptive optimization at round 0 only, then frozen.
- ``proportional`` : samples proportional to each node's compute power.
- ``adaptive``     : the proposed method.

Run ``PYTHONPATH=src python -m repro.fl.baselines`` for a quick
all-schemes latency comparison on the paper topology.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.strategies import StrategyFn, resolve_strategy

BASELINES = ["none", "air_ground", "ground_space", "static", "proportional"]
ALL_SCHEMES = BASELINES + ["adaptive"]

#: scheme name -> data-placement policy hook for ``SAGINOrchestrator``
#: (resolve_strategy raises at import time if a scheme lacks a policy)
SCHEME_HOOKS: Dict[str, StrategyFn] = {
    name: resolve_strategy(name) for name in ALL_SCHEMES
}


def run_scheme(name: str, n_rounds: int = 3, n_devices: int = 8,
               n_air: int = 2, seed: int = 0, **orch_kwargs) -> List:
    """Run one scheme's orchestration for a few rounds; returns the
    per-round :class:`~repro.core.scheduler.RoundRecord` list."""
    from repro.core import SAGINOrchestrator, build_default_sagin

    sagin = build_default_sagin(n_devices=n_devices, n_air=n_air, seed=seed)
    orch = SAGINOrchestrator(sagin, strategy=name, sat_f_seed=seed,
                             **orch_kwargs)
    return orch.run(n_rounds)


def compare_schemes(n_rounds: int = 3, n_devices: int = 8, n_air: int = 2,
                    seed: int = 0) -> Dict[str, List[float]]:
    """Per-round realized latencies of every scheme on the same topology."""
    return {name: [r.realized_latency
                   for r in run_scheme(name, n_rounds, n_devices, n_air,
                                       seed)]
            for name in ALL_SCHEMES}


def main() -> None:
    import numpy as np
    lats = compare_schemes()
    print(f"{'scheme':>14s}  mean round latency (s)")
    for name in ALL_SCHEMES:
        print(f"{name:>14s}  {np.mean(lats[name]):10.1f}")


if __name__ == "__main__":
    main()
