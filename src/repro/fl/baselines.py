"""The paper's comparison schemes (Section VI-A), as strategy names for the
orchestrator/runner. Each maps to a data-placement policy; model aggregation
is FedAvg (eq. 13) in every scheme, as in the paper.

- ``none``         : no data offloading (space/air only aggregate).
- ``air_ground``   : offloading only between air and ground layers.
- ``ground_space`` : offloading only between ground and space (air relays).
- ``static``       : adaptive optimization at round 0 only, then frozen.
- ``proportional`` : samples proportional to each node's compute power.
- ``adaptive``     : the proposed method.
"""
BASELINES = ["none", "air_ground", "ground_space", "static", "proportional"]
ALL_SCHEMES = BASELINES + ["adaptive"]
