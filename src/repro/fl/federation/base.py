"""Federation-policy API: WHO merges WHAT, WHEN, at WHAT ISL price.

The cross-region merge used to be an if-branch inside
:class:`~repro.sim.engine.SAGINEngine`: a hard-coded full-participation
barrier with a fixed hub at region 0.  This module turns that decision
surface into data:

* :class:`FederationConfig` — the declarative knob set (policy name,
  cadence, ISL topology, staleness half-life, quorum, hub election
  criterion), threaded through ``Scenario.federation`` and
  ``FLConfig.federation``.
* :class:`FederationState` — everything the engine knows at a merge
  boundary: per-region wall clocks (hence model ages), data masses,
  and the live ISL state realized by ``sim.dynamics``.  The engine
  EMITS this; it no longer knows merge semantics.
* :class:`MergePlan` — a policy's decision: participants, normalized
  weights, staleness, recipients, the elected hub, and the per-recipient
  ISL price.  The engine installs whatever the plan says.
* :class:`MergePolicy` — ``plan(state) -> MergePlan | None`` plus
  ``apply(models, plan)``, which rides the existing stacked/Pallas
  aggregation path (``fedavg_stacked``, the single-stack form of
  ``fedavg_stacked_multi`` — the ``fedavg_agg`` kernel on TPU).

Policies register by name (:func:`register_policy`); see
``repro.fl.federation.policies`` for the four built-ins
(``synchronous``, ``soft_async``, ``partial``, ``elected_hub``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Type, TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.scenarios.registry import Scenario

ELECTION_CRITERIA = ("data_mass", "centrality")


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    """Declarative cross-region federation knobs.

    ``every=None`` disables merging entirely (independent per-region
    models, the historic ``merge_every=None`` behavior); otherwise the
    engine consults the named policy at every ``every``-round boundary
    (and at the final round).  ``quorum`` and ``elect_by`` are only read
    by the policies that need them (``partial`` / ``elected_hub``).
    """
    policy: str = "synchronous"
    every: Optional[int] = None         # merge cadence in rounds
    topology: str = "ring"              # base ISL route ("ring" | "star")
    half_life: Optional[float] = None   # staleness discount half-life (s)
    quorum: float = 0.5                 # partial: min live fraction to merge
    elect_by: str = "data_mass"         # elected_hub: data_mass | centrality

    def __post_init__(self):
        from repro.core.latency import MERGE_TOPOLOGIES
        if self.every is not None and self.every < 1:
            raise ValueError(f"federation every must be a positive round "
                             f"count or None, got {self.every}")
        if self.topology not in MERGE_TOPOLOGIES:
            raise ValueError(f"federation topology must be one of "
                             f"{MERGE_TOPOLOGIES}, got {self.topology!r}")
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError(f"federation quorum must be in (0, 1], got "
                             f"{self.quorum}")
        if self.elect_by not in ELECTION_CRITERIA:
            raise ValueError(f"federation elect_by must be one of "
                             f"{ELECTION_CRITERIA}, got {self.elect_by!r}")


@dataclasses.dataclass(frozen=True)
class RegionFedState:
    """One region's view at a merge boundary, as the engine emits it."""
    index: int
    name: str
    wall_clock: float       # region clock after its last completed round
    data_mass: float        # total samples held (offloading conserves it)
    model_bits: float       # payload of one model over the ISLs
    z_isl: float            # nominal ISL rate (bits/s)
    isl_scale: float = 1.0  # realized ISL rate multiplier (<1: outage/fade)
    rounds_done: int = 0

    @property
    def isl_up(self) -> bool:
        """True when the region's ISL ran clean in its last round."""
        return self.isl_scale >= 1.0


@dataclasses.dataclass(frozen=True)
class FederationState:
    """Everything a policy may consult to plan one merge.

    ``trigger`` is the region index whose boundary fired planning for
    asynchronous policies; ``None`` means a full barrier (every region
    arrived).  The live ISL adjacency derives from the per-region
    outage state ``sim.dynamics`` realized in each region's last round.
    """
    config: FederationConfig
    regions: Tuple[RegionFedState, ...]
    barrier_round: int
    trigger: Optional[int] = None

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    def live_regions(self) -> List[int]:
        """Indices of regions whose ISL is currently clean."""
        return [r.index for r in self.regions if r.isl_up]

    def isl_adjacency(self) -> np.ndarray:
        """Live ISL adjacency: ``A[i, j]`` is True when regions ``i`` and
        ``j`` can exchange models this instant (both endpoints' serving
        satellites have functional ISLs)."""
        up = np.array([r.isl_up for r in self.regions], dtype=bool)
        adj = np.logical_and.outer(up, up)
        np.fill_diagonal(adj, False)
        return adj


@dataclasses.dataclass(frozen=True)
class MergePlan:
    """A policy's decision for one merge; the engine just executes it.

    ``weights``/``staleness`` align with ``participants`` (weights sum
    to 1); ``isl_costs`` aligns with ``recipients`` — a recipient's
    clock advances to ``time + cost`` when the merged model installs.
    """
    policy: str
    time: float                      # merge instant on the global clock
    hub: int                         # aggregating region (its satellite)
    participants: Tuple[int, ...]    # regions whose models enter the merge
    weights: Tuple[float, ...]       # normalized, aligned w/ participants
    staleness: Tuple[float, ...]     # model age (s), aligned w/ participants
    recipients: Tuple[int, ...]      # regions that install the merged model
    isl_costs: Tuple[float, ...]     # ISL price (s), aligned w/ recipients


class MergePolicy:
    """Base policy: subclasses decide ``plan``; ``apply`` is shared.

    ``requires_barrier=True`` policies are planned once every region has
    parked at the boundary (synchronous rendezvous); ``False`` policies
    are planned per region, the moment it crosses its own boundary
    (``state.trigger`` names it), with no parking.
    """
    name: str = ""
    requires_barrier: bool = True

    def __init__(self, config: FederationConfig):
        self.config = config

    def plan(self, state: FederationState) -> Optional[MergePlan]:
        """Decide one merge; ``None`` skips it (no models move)."""
        raise NotImplementedError

    def apply(self, models: Sequence, plan: MergePlan):
        """Aggregate the participants' models per the plan's weights.

        Rides ``fl.aggregation.fedavg_pytrees`` — the same stacked
        device-side dispatch ``staleness_weighted_merge`` uses (the
        Pallas ``fedavg_agg`` kernel path on TPU), so policy merges and
        the legacy merge path stay bit-identical by construction.  A
        single-participant merge is the identity.
        """
        if len(models) != len(plan.participants):
            raise ValueError(f"{len(models)} models for "
                             f"{len(plan.participants)} participants")
        from repro.fl.aggregation import fedavg_pytrees
        return fedavg_pytrees(list(models), plan.weights)


# ---------------------------------------------------------------------------
# Registry -------------------------------------------------------------------
# ---------------------------------------------------------------------------
POLICIES: Dict[str, Type[MergePolicy]] = {}


def register_policy(cls: Type[MergePolicy]) -> Type[MergePolicy]:
    """Class decorator: register a policy under its ``name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty name")
    if cls.name in POLICIES:
        raise ValueError(f"federation policy {cls.name!r} already "
                         f"registered")
    POLICIES[cls.name] = cls
    return cls


def get_policy(config: FederationConfig) -> MergePolicy:
    """Instantiate the policy ``config.policy`` names."""
    try:
        cls = POLICIES[config.policy]
    except KeyError:
        raise ValueError(f"unknown federation policy {config.policy!r}; "
                         f"available: {list_policies()}") from None
    return cls(config)


def list_policies() -> List[str]:
    return sorted(POLICIES)


def resolve_federation(fl_federation,
                       scenario: Optional["Scenario"]
                       ) -> Optional[FederationConfig]:
    """Resolution order for the engine: ``FLConfig.federation`` wins over
    ``Scenario.federation`` (itself synthesized from the deprecated
    ``merge_*`` fields when legacy scenarios are in play).

    A bare policy-name string in ``FLConfig.federation`` keeps the
    scenario's cadence/topology/half-life and swaps only the policy; it
    is an error when no cadence is configured anywhere (a named policy
    that would silently never merge), so pass a full
    ``FederationConfig(policy=..., every=N)`` in that case.
    """
    base = scenario.resolved_federation() if scenario is not None else None
    if fl_federation is None:
        return base
    if isinstance(fl_federation, str):
        resolved = dataclasses.replace(base or FederationConfig(),
                                       policy=fl_federation)
        if resolved.every is None:
            raise ValueError(
                f"FLConfig.federation={fl_federation!r} names a policy "
                f"but no merge cadence is configured (the scenario has "
                f"no federation cadence); pass FederationConfig(policy="
                f"{fl_federation!r}, every=N) instead")
        return resolved
    if not isinstance(fl_federation, FederationConfig):
        raise TypeError(f"FLConfig.federation must be a FederationConfig, "
                        f"a policy name, or None; got "
                        f"{type(fl_federation).__name__}")
    return fl_federation
