"""Pluggable federation policies for the cross-region merge.

See ``repro.fl.federation.base`` for the API surface
(:class:`FederationConfig`, :class:`FederationState`, :class:`MergePlan`,
:class:`MergePolicy`, the registry) and
``repro.fl.federation.policies`` for the built-ins (``synchronous``,
``soft_async``, ``partial``, ``elected_hub``).
"""
from .base import (ELECTION_CRITERIA, FederationConfig, FederationState,
                   MergePlan, MergePolicy, POLICIES, RegionFedState,
                   get_policy, list_policies, register_policy,
                   resolve_federation)
from .policies import (ElectedHubPolicy, PartialPolicy, SoftAsyncPolicy,
                       SynchronousPolicy, plan_under_partition)

__all__ = ["ELECTION_CRITERIA", "FederationConfig", "FederationState",
           "MergePlan", "MergePolicy", "POLICIES", "RegionFedState",
           "get_policy", "list_policies", "register_policy",
           "resolve_federation", "ElectedHubPolicy", "PartialPolicy",
           "SoftAsyncPolicy", "SynchronousPolicy", "plan_under_partition"]
