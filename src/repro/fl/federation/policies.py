"""The four built-in federation policies.

====================  ======================================================
``synchronous``       Today's barrier: every region rendezvouses, hub fixed
                      at region 0, data-share weights with the FedMeld-style
                      staleness discount.  Bit-identical to the pre-refactor
                      ``SAGINEngine`` merge (golden-locked in
                      ``tests/test_cross_region.py``).
``soft_async``        FedMeld-style soft dispersal: no barrier.  When a
                      region crosses its own merge boundary it pulls
                      whatever peer models are fresh over live ISLs,
                      merges staleness-discounted, and alone installs the
                      result; peers keep training undisturbed.
``partial``           Barrier that proceeds under ISL outages: only regions
                      whose ISL ran clean in their last round participate
                      (data-mass weights renormalized over the quorum);
                      disconnected regions neither wait nor pay the toll.
                      Skips the merge below ``quorum``.
``elected_hub``       Synchronous rendezvous, but the aggregating hub is
                      elected per merge — by data mass or by live-ISL
                      centrality (Olive-Branch-style topology awareness) —
                      so ISL pricing follows the actual aggregation point.
====================  ======================================================

Every policy prices its own ISL hops from the ``core.latency``
primitives (``isl_path_hops`` / ``global_merge_latency``); the engine no
longer calls the latency model at merge time.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

from repro.core.latency import global_merge_latency, isl_path_hops, tx_time

from .base import (FederationState, MergePlan, MergePolicy, RegionFedState,
                   register_policy)


def _merge_weights(regions: Sequence[RegionFedState],
                   participants: Sequence[int],
                   staleness: Sequence[float],
                   half_life: Optional[float]) -> Tuple[float, ...]:
    """Data-mass x staleness-discount weights over the participants,
    renormalized (``fl.aggregation.staleness_merge_weights``)."""
    from repro.fl.aggregation import staleness_merge_weights
    sizes = [regions[i].data_mass for i in participants]
    w = staleness_merge_weights(sizes, staleness, half_life)
    return tuple(float(x) for x in w)


@register_policy
class SynchronousPolicy(MergePolicy):
    """Full-participation barrier at a fixed hub (region 0)."""
    name = "synchronous"
    requires_barrier = True

    def elect_hub(self, state: FederationState) -> int:
        return 0

    def plan(self, state: FederationState) -> Optional[MergePlan]:
        cfg = self.config
        regions = state.regions
        n = state.n_regions
        hub = self.elect_hub(state)
        participants = tuple(range(n))
        t_merge = max(r.wall_clock for r in regions)
        staleness = tuple(t_merge - r.wall_clock for r in regions)
        weights = _merge_weights(regions, participants, staleness,
                                 cfg.half_life)
        costs = tuple(global_merge_latency(r.model_bits, r.z_isl,
                                           cfg.topology, r.index, n,
                                           hub=hub)
                      for r in regions)
        return MergePlan(policy=self.name, time=t_merge, hub=hub,
                         participants=participants, weights=weights,
                         staleness=staleness, recipients=participants,
                         isl_costs=costs)


@register_policy
class ElectedHubPolicy(SynchronousPolicy):
    """Synchronous barrier with a per-merge elected hub.

    ``elect_by="data_mass"`` puts the aggregation where the most data
    lives (least model mass moves relative to data mass);
    ``elect_by="centrality"`` picks the region with the most live ISLs
    (ties broken by data mass, then lowest index).
    """
    name = "elected_hub"

    def elect_hub(self, state: FederationState) -> int:
        regions = state.regions
        if self.config.elect_by == "centrality":
            degree = state.isl_adjacency().sum(axis=1)
            key = [(-int(degree[r.index]), -r.data_mass, r.index)
                   for r in regions]
        else:  # data_mass
            key = [(-r.data_mass, r.index) for r in regions]
        return min(range(len(regions)), key=key.__getitem__)


@register_policy
class PartialPolicy(MergePolicy):
    """Barrier merge over whatever quorum the ISL dynamics expose.

    Regions whose ISL was degraded in their last round sit the merge
    out entirely: they contribute no model, receive none, pay no toll,
    and — crucially — their wall clocks are NOT dragged to the barrier,
    so an outage never stalls the regions it did not hit.  The data-mass
    weights renormalize over the participating quorum.  The hub is the
    lowest-index live region (region 0 when its link is clean).
    """
    name = "partial"
    requires_barrier = True

    def plan(self, state: FederationState) -> Optional[MergePlan]:
        cfg = self.config
        regions = state.regions
        n = state.n_regions
        live = state.live_regions()
        need = max(2, math.ceil(cfg.quorum * n))
        if len(live) < need:
            return None
        participants = tuple(live)
        hub = live[0]
        t_merge = max(regions[i].wall_clock for i in participants)
        staleness = tuple(t_merge - regions[i].wall_clock
                          for i in participants)
        weights = _merge_weights(regions, participants, staleness,
                                 cfg.half_life)
        costs = tuple(global_merge_latency(regions[i].model_bits,
                                           regions[i].z_isl, cfg.topology,
                                           i, n, hub=hub)
                      for i in participants)
        return MergePlan(policy=self.name, time=t_merge, hub=hub,
                         participants=participants, weights=weights,
                         staleness=staleness, recipients=participants,
                         isl_costs=costs)


@register_policy
class SoftAsyncPolicy(MergePolicy):
    """FedMeld-style soft merge at each region's OWN boundary.

    No rendezvous: the triggering region merges its model with the most
    recent snapshot of every peer reachable over a live ISL, each peer
    discounted by how stale its snapshot is relative to the trigger's
    clock (a peer that is AHEAD of the trigger contributes at zero
    staleness — its model is the freshest thing available).  Only the
    trigger installs the result and pays the fetch: peer models arrive
    in parallel, so the toll is the slowest one-way model transfer.
    Peers' models, clocks, and training are untouched — the global model
    disperses through the constellation instead of being rebuilt at a
    barrier.
    """
    name = "soft_async"
    requires_barrier = False

    def plan(self, state: FederationState) -> Optional[MergePlan]:
        cfg = self.config
        regions = state.regions
        n = state.n_regions
        i = state.trigger
        if i is None:
            raise ValueError("soft_async plans per trigger region; the "
                             "engine must set FederationState.trigger")
        me = regions[i]
        if not me.isl_up:
            return None  # my ISL is down: keep training, merge next time
        peers = [j for j in range(n) if j != i and regions[j].isl_up]
        if not peers:
            return None
        participants = tuple(sorted([i] + peers))
        t_now = me.wall_clock
        staleness = tuple(0.0 if j == i
                          else max(0.0, t_now - regions[j].wall_clock)
                          for j in participants)
        weights = _merge_weights(regions, participants, staleness,
                                 cfg.half_life)
        fetch = max(isl_path_hops(cfg.topology, j, i, n)
                    * tx_time(regions[j].model_bits, regions[j].z_isl)
                    for j in peers)
        return MergePlan(policy=self.name, time=t_now, hub=i,
                         participants=participants, weights=weights,
                         staleness=staleness, recipients=(i,),
                         isl_costs=(fetch,))


def plan_under_partition(policy: MergePolicy, state: FederationState,
                         partitioned: Sequence[int],
                         max_retries: int = 3,
                         backoff_base: float = 5.0,
                         backoff_cap: float = 60.0
                         ) -> Tuple[Optional[MergePlan], float]:
    """Plan a merge while the ISLs of ``partitioned`` regions are down
    (a fault-injected merge-time partition, ``repro.resilience``).

    The degraded state marks the partitioned regions' ISLs dead
    (``isl_scale=0``).  Policies that already tolerate outages
    (``partial``; ``soft_async`` plans per trigger) simply plan on the
    degraded state at zero extra cost.  Barrier policies that REQUIRE
    full participation (``synchronous`` / ``elected_hub``) first retry
    the rendezvous ``max_retries`` times with capped exponential backoff
    (``min(backoff_base * 2^k, backoff_cap)`` seconds of simulated ISL
    re-probing per attempt — the partition is modeled as outlasting the
    retry budget), then degrade gracefully to the ``partial`` policy's
    quorum plan over the connected regions.

    Returns ``(plan, delay)``: the (possibly fallback) plan with the
    retry delay already folded into its merge instant, or ``None`` when
    even the quorum fails — plus the simulated seconds burned retrying.
    """
    import dataclasses as _dc

    partitioned = set(partitioned)
    degraded = _dc.replace(state, regions=tuple(
        _dc.replace(r, isl_scale=0.0) if r.index in partitioned else r
        for r in state.regions))
    tolerant = (not policy.requires_barrier
                or isinstance(policy, PartialPolicy))
    if tolerant or not partitioned:
        return policy.plan(degraded), 0.0
    delay = sum(min(backoff_base * (2.0 ** k), backoff_cap)
                for k in range(max_retries))
    fallback = PartialPolicy(policy.config)
    plan = fallback.plan(degraded)
    if plan is None:
        return None, delay
    return _dc.replace(plan, time=plan.time + delay), delay


def _policy_names() -> List[str]:  # pragma: no cover - debug helper
    from .base import list_policies
    return list_policies()
