"""End-to-end FL simulation driver (Section VI).

Couples the analytic SAGIN orchestration (latency, offloading, handover)
with *real* federated training on a (synthetic) dataset: every node that
holds samples runs H local SGD iterations, models are aggregated with the
eq.-(13) lambda weights, and the wall clock advances by the optimized round
latency. Produces accuracy-versus-training-time curves (Figs. 4, 6, 7).

The unit of execution is :class:`RegionTrainer` — ONE region's complete
FL job (dataset, pools, model, orchestrator), advanced one round at a
time via :meth:`RegionTrainer.step`.  :func:`run_fl` is the thin
single-region wrapper that steps a trainer ``n_rounds`` times; the
multi-region :class:`~repro.sim.engine.SAGINEngine` steps many trainers
through its event heap and merges their models across regions
(``fl.aggregation.staleness_weighted_merge``).

Region addressing: with a scenario, all of a region's streams — dataset
sample draw, partition shuffle, orchestrator satellite draws, dynamics
events — are rooted at ``region_seed(cfg.seed, cfg.region_index)``
(see :func:`repro.sim.engine.region_streams`), so
``run_fl(FLConfig(scenario=s, region_index=i))`` reproduces engine
region ``i`` exactly.  The MODEL INIT alone stays keyed on the global
``cfg.seed``: hierarchical FL requires every region to descend from one
broadcast initial model for cross-region merges to be meaningful.

Execution modes (``FLConfig.execution``):

* ``"batched"`` — the cohort engine
  (:class:`repro.fl.cohort_engine.CohortEngine`). Every data-holding
  node's (H, B) batch stack is drawn through the shared RNG stream and
  partitioned into geometric batch-width buckets
  (``repro.data.pipeline.build_bucketed_cohort``): each occupied bucket
  trains in one compiled ``cohort_local_update`` dispatch padded only
  to ITS OWN width, and all buckets' stacked params aggregate in a
  single device-side ``fedavg_stacked_multi`` call (the Pallas
  ``fedavg_agg`` kernel path on TPU) — no host round-trip of
  parameters inside the round, stacked buffers donated on accelerator
  backends. Both bucket axes are quantized to geometric grids
  (``cohort_batch_align * 2^k`` batch slots,
  ``cohort_client_align * 2^k`` clients), so churn/offloading drift
  re-lands on already-compiled bucket signatures and recompiles stay at
  zero after warm-up; padded FLOPs stay within a constant factor of
  real FLOPs at ANY pool skew (the PR-1 global-``Bmax`` layout, kept as
  ``cohort_bucketing="global"`` for comparison, degrades with skew
  instead).  With more than one visible device (or
  ``cohort_sharding="mesh"``) each bucket's client axis additionally
  shards over the mesh's ``data`` axis through ``shard_map`` with
  in-mesh psum aggregation — see the cohort-engine module docstring.
* ``"sequential"`` — the reference loop: one ``local_update`` dispatch
  per node, host-side ``fedavg`` over a model list.
* ``"auto"`` (default) — ``"batched"`` on accelerator backends where the
  vmapped cohort step is the whole point, ``"sequential"`` on CPU where
  XLA's grouped per-client conv gradients make the vmapped step slower
  than the loop for conv payloads (see ``benchmarks/cohort_scaling.py``
  for the regimes where batched wins even on CPU).

Both modes draw mini-batches from the same RNG stream in the same node
order (ground 0..K-1, then air, then satellite), so at equal seeds they
produce the same accuracy trajectory up to float reduction-order noise.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SAGINOrchestrator, build_default_sagin
from repro.core.handover import replan_after_loss
from repro.core.network import SAGIN
from repro.data import FederatedPools, make_dataset, partition
from repro.models.cnn import build_model, model_bits

from .aggregation import fedavg, fedavg_stacked
from .client import cohort_local_update, evaluate, local_update
from .federation import FederationConfig, RegionFedState

if TYPE_CHECKING:  # pragma: no cover - circular-import guard
    from repro.core.constellation import AccessInterval
    from repro.obs import ObsConfig, Tracer
    from repro.scenarios.registry import Scenario
    from repro.serve.workload import ServeConfig


@dataclasses.dataclass
class FLConfig:
    dataset: str = "mnist"
    iid: bool = True
    alpha: float = 0.8
    n_devices: int = 50
    n_air: int = 5
    n_rounds: int = 30
    h_local: int = 5
    lr: float = 0.05
    batch_cap: int = 32
    strategy: str = "adaptive"     # adaptive|none|air_ground|ground_space|static|proportional
    rayleigh: bool = True
    train_fraction: float = 0.05   # shrink dataset for CPU-speed runs
    eval_size: int = 1024
    seed: int = 0
    use_constellation: bool = False  # True: drive T_i from Walker-Star
    scenario: Optional[str] = None   # named preset from repro.scenarios
    region_index: int = 0            # which scenario region this FL job serves
    execution: str = "auto"        # auto|batched|sequential (module docstring)
    cohort_batch_align: int = 32   # batched mode: bucket-width grid unit
    cohort_bucketing: str = "geometric"  # geometric|global (module docstring)
    cohort_client_align: int = 4   # batched mode: bucket client-count grid
    # batched mode: arm contracts.no_recompile() around every round whose
    # bucket layout is already warm — a recompile on a seen signature
    # raises ContractViolation instead of silently re-tracing each round
    guard_recompiles: bool = False
    # batched mode: shard each bucket's client axis over the device
    # mesh's "data" axis ("mesh"), never shard ("off"), or shard exactly
    # when more than one device is visible ("auto", the default — a
    # single-device host keeps the bit-identical legacy path)
    cohort_sharding: str = "auto"  # auto|mesh|off
    # Cross-region federation override for SAGINEngine FL mode: a
    # FederationConfig replaces the scenario's wholesale; a bare policy
    # name (e.g. "soft_async") keeps the scenario's cadence/topology/
    # half-life and swaps only the policy; None defers to the scenario.
    # Ignored by single-region run_fl (nothing to merge with).
    federation: Optional["FederationConfig | str"] = None
    # Observability (repro.obs): an ObsConfig, a bare JSONL output path
    # string, or None (disabled — the default, a no-op null tracer).
    # Wins over Scenario.obs when both are set.  The tracer only
    # observes: trajectories are bit-identical with obs on or off.
    obs: Optional["ObsConfig | str"] = None
    # Serving-gateway wiring (repro.serve): a ServeConfig shaping the
    # request workload / router / batching a ServeGateway attached to
    # this run uses.  Wins over Scenario.serve; None defers to the
    # scenario (and ultimately to ServeConfig() defaults).  Training
    # itself never reads this — serving is strictly read-only.
    serve: Optional["ServeConfig"] = None
    # Quarantine non-finite client updates before aggregation (weights
    # renormalize over the finite survivors).  None (default) arms it
    # exactly when a fault injector is attached (the chaos path) and
    # keeps the clean path free of the per-client finiteness sync;
    # True/False force it either way.
    quarantine: Optional[bool] = None

    def resolved_execution(self) -> str:
        if self.execution == "auto":
            return ("batched" if jax.default_backend() != "cpu"
                    else "sequential")
        return self.execution


@dataclasses.dataclass
class FLResult:
    config: FLConfig
    times: List[float]             # cumulative training time (s); under the
    #                              engine's merge barriers this also includes
    #                              barrier wait + ISL merge costs
    accuracies: List[float]        # on this region's held-out eval batch
    losses: List[float]            # mean TRAIN loss across this round's
    #                              training nodes; NaN for a round in which
    #                              no node trained (never silently the eval
    #                              loss).  The NaN sentinel is kept for
    #                              backward compatibility — consult
    #                              ``participated`` instead of nan-sniffing.
    latencies: List[float]         # realized per-round latency
    cases: List[int]
    layer_portions: List[Dict[str, float]]  # data share per layer per round
    # True when >= 1 node trained in the round (equivalently: losses[r]
    # is finite).  The explicit mask downstream consumers should use for
    # participation instead of inferring it from the NaN loss sentinel.
    participated: List[bool] = dataclasses.field(default_factory=list)

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for t, a in zip(self.times, self.accuracies):
            if a >= target:
                return t
        return None


def _build_orchestrator(cfg: FLConfig, sagin: SAGIN,
                        scenario: Optional["Scenario"] = None,
                        intervals: Optional[Sequence["AccessInterval"]] = None
                        ) -> SAGINOrchestrator:
    """Orchestrator from the config: scenario preset, bare Walker-Star, or
    the static satellite list, in that order of precedence.

    With a scenario, coverage windows come from the vectorized
    multi-region propagation pass and the preset's stochastic dynamics
    are attached, so the wall clock advances by *realized* latencies.
    The engine passes ``scenario``/``intervals`` explicitly to share one
    propagation pass (and to support unregistered ad-hoc scenarios); a
    standalone job resolves the preset by name and propagates only its
    own region.
    """
    if cfg.scenario is not None or scenario is not None:
        from repro.sim.engine import region_streams
        from repro.sim.propagation import access_intervals_multi

        scn = scenario if scenario is not None else _resolve_scenario(cfg)
        try:
            region = scn.regions[cfg.region_index]
        except IndexError:
            raise ValueError(
                f"scenario {scn.name!r} has {len(scn.regions)} region(s); "
                f"region_index={cfg.region_index} is out of range") from None
        if intervals is None:
            # propagate only this job's region (the engine shares one pass
            # across regions; a single-region FL job shouldn't pay for all)
            intervals = access_intervals_multi(
                scn.build_constellation(), [region], t_end=scn.horizon,
                dt=scn.dt)[region.name]
        rng, dynamics = region_streams(cfg.seed, cfg.region_index,
                                       scn.dynamics)
        # an explicitly non-default FLConfig.strategy wins; otherwise the
        # scenario's declared scheme applies (as in SAGINEngine)
        strategy = (cfg.strategy if cfg.strategy != "adaptive"
                    else scn.strategy)
        return SAGINOrchestrator(sagin, intervals=intervals, rng=rng,
                                 dynamics=dynamics, strategy=strategy)
    constellation = None
    if cfg.use_constellation:
        from repro.core import WalkerStar
        constellation = WalkerStar()
    return SAGINOrchestrator(sagin, constellation=constellation,
                             sat_f_seed=cfg.seed, strategy=cfg.strategy)


def _resolve_scenario(cfg: FLConfig) -> "Scenario":
    from repro.scenarios import get_scenario
    return get_scenario(cfg.scenario)


def _train_node(apply_fn, params, ds, idx, h, lr, batch_cap, rng):
    from repro.data.pipeline import batch_for_local_steps
    batches = batch_for_local_steps(ds.x_train, ds.y_train, idx, h, rng,
                                    max_batch=batch_cap)
    if batches is None:
        return None
    xs, ys = batches
    new_params, loss = local_update(apply_fn, params, jnp.asarray(xs),
                                    jnp.asarray(ys), lr)
    return new_params, float(loss)


def _node_pools(cfg: FLConfig, pools, offline=()) -> List[np.ndarray]:
    """Index pools of every data-holding node, in canonical node order
    (ground 0..K-1, air 0..N-1, satellite) — the order both execution
    modes must share for RNG-stream equivalence.  Devices churned out
    for the round (``offline``) sit out of training entirely."""
    out = []
    offline = set(offline)
    for k in range(cfg.n_devices):
        if k in offline:
            continue
        idx = pools.ground_all(k)
        if len(idx):
            out.append(idx)
    for n in range(cfg.n_air):
        if len(pools.air[n]):
            out.append(pools.air[n])
    if len(pools.sat):
        out.append(pools.sat)
    return out


def _round_sequential(cfg: FLConfig, apply_fn, params, ds, node_pools,
                      total, rng, corrupt=(), quarantine=False):
    """Reference engine: one jitted dispatch per node, host-side fedavg.

    Returns ``(params, losses, n_quarantined)``.  ``corrupt`` holds the
    canonical node positions whose trained models are NaN-filled AFTER
    training (fault injection; RNG draws untouched); with ``quarantine``
    any non-finite model is dropped before ``fedavg`` — the weights
    renormalize over the survivors, and a round whose every update was
    dropped keeps the previous global model.
    """
    from .aggregation import tree_all_finite
    corrupt = set(corrupt)
    new_models, weights, losses = [], [], []
    n_quarantined = 0
    for pos, idx in enumerate(node_pools):
        out = _train_node(apply_fn, params, ds, idx, cfg.h_local,
                          cfg.lr, cfg.batch_cap, rng)
        if out is None:
            continue
        model, loss = out
        if pos in corrupt:
            model = jax.tree_util.tree_map(
                lambda a: jnp.full_like(a, jnp.nan), model)
            loss = float("nan")
        if quarantine and not tree_all_finite(model):
            n_quarantined += 1
            continue
        new_models.append(model)
        weights.append(len(idx) / total)
        losses.append(loss)
    if new_models:
        params = fedavg(new_models, weights)
    return params, losses, n_quarantined


def _round_batched(cfg: FLConfig, apply_fn, params, ds, node_pools,
                   total, rng, engine=None, corrupt=(), quarantine=False):
    """Cohort engine: size-bucketed compiled dispatches + one device-side
    stacked eq.-(13) aggregation (Pallas ``fedavg_agg`` path on TPU).

    ``engine`` is the job's persistent
    :class:`~repro.fl.cohort_engine.CohortEngine` (``RegionTrainer``
    owns one; ``None`` builds a throwaway — jax's jit cache still
    de-duplicates compilation across throwaways).
    ``cfg.cohort_bucketing="global"`` keeps the PR-1 single-cohort
    global-``Bmax`` layout for comparison benchmarks.

    Returns ``(params, losses, n_quarantined)``; ``corrupt`` /
    ``quarantine`` are the fault-injection and non-finite-update gates
    of :meth:`~repro.fl.cohort_engine.CohortEngine.round` (geometric
    bucketing only — the comparison-grade global layout has no
    quarantine hook).
    """
    if cfg.cohort_bucketing == "global":
        if corrupt or quarantine:
            raise ValueError(
                "fault injection / quarantine require "
                "cohort_bucketing='geometric'; the 'global' comparison "
                "layout has no masking hook")
        from repro.data.pipeline import build_cohort
        cohort = build_cohort(ds.x_train, ds.y_train, node_pools,
                              cfg.h_local, rng, max_batch=cfg.batch_cap,
                              pad_clients=cfg.n_devices + cfg.n_air + 1,
                              batch_align=cfg.cohort_batch_align)
        if cohort is None:
            return params, [], 0
        stacked, client_losses = cohort_local_update(
            apply_fn, params, jnp.asarray(cohort.xs),
            jnp.asarray(cohort.ys), jnp.asarray(cohort.mask), cfg.lr)
        weights = jnp.asarray(cohort.sizes / total, jnp.float32)
        params = fedavg_stacked(stacked, weights)
        valid = cohort.sizes > 0
        losses = [float(l) for l in np.asarray(client_losses)[valid]]
        return params, losses, 0
    if cfg.cohort_bucketing != "geometric":
        raise ValueError(f"FLConfig.cohort_bucketing must be 'geometric' "
                         f"or 'global', got {cfg.cohort_bucketing!r}")
    if engine is None:
        from .cohort_engine import CohortEngine
        engine = CohortEngine(apply_fn, batch_align=cfg.cohort_batch_align,
                              client_align=cfg.cohort_client_align,
                              guard=cfg.guard_recompiles,
                              sharding=cfg.cohort_sharding)
    cohort = engine.build(ds.x_train, ds.y_train, node_pools, cfg.h_local,
                          rng, max_batch=cfg.batch_cap)
    if cohort is None:
        return params, [], 0
    params, losses = engine.round(params, cohort, cfg.lr, total,
                                  corrupt=corrupt, quarantine=quarantine)
    return params, losses, engine.last_quarantined


class RegionTrainer:
    """One region's complete FL job, advanced one round at a time.

    Owns the region's dataset, index pools, model parameters, and SAGIN
    orchestrator; :meth:`step` executes one full round (orchestration,
    data placement, local training, aggregation, evaluation) and appends
    to :attr:`result`.  Construction is the exact sequence the historic
    ``run_fl`` body performed, so stepping a trainer ``n_rounds`` times
    is trajectory-identical to the pre-refactor loop at equal seeds.

    The engine passes ``scenario``/``intervals`` so every region shares
    one propagation pass; standalone use needs only the config.  After a
    cross-region merge the engine calls :meth:`install_global` to adopt
    the global model and the post-merge wall clock.
    """

    def __init__(self, cfg: FLConfig,
                 scenario: Optional["Scenario"] = None,
                 intervals: Optional[Sequence["AccessInterval"]] = None,
                 tracer: Optional["Tracer"] = None):
        from repro.obs import resolve_obs
        self.cfg = cfg
        scn = scenario
        if scn is None and cfg.scenario is not None:
            scn = _resolve_scenario(cfg)
        # an explicit tracer (the engine's shared one) wins over the
        # config; scenario-level obs applies when the config is silent
        if tracer is None:
            obs = cfg.obs
            if obs is None and scn is not None:
                obs = scn.obs
            tracer = resolve_obs(obs)
        self.tracer = tracer
        if scn is not None:
            from repro.sim.engine import region_seed
            rseed = region_seed(cfg.seed, cfg.region_index)
            self.region = (scn.regions[cfg.region_index]
                           if cfg.region_index < len(scn.regions) else None)
        else:
            rseed = cfg.seed
            self.region = None
        self.region_seed = rseed
        self.rng = np.random.default_rng(rseed)
        # regions share the TASK (class prototypes keyed on the global
        # seed) but draw disjoint-by-construction sample streams
        self.ds = make_dataset(cfg.dataset, seed=cfg.seed,
                               train_fraction=cfg.train_fraction,
                               sample_seed=rseed)
        parts = partition(self.ds, n_devices=cfg.n_devices, iid=cfg.iid,
                          alpha=cfg.alpha, seed=rseed)
        self.pools = FederatedPools.from_partitions(parts, cfg.n_air)

        # model init is keyed on the GLOBAL seed: every region descends
        # from the same broadcast initial model (merge prerequisite)
        key = jax.random.PRNGKey(cfg.seed)
        self.params, self.apply_fn = build_model(
            self.ds.name, key, image_shape=self.ds.x_train.shape[1:])
        q_bits = self.ds.sample_bits
        self.sagin = build_default_sagin(
            n_devices=cfg.n_devices, n_air=cfg.n_air, alpha=cfg.alpha,
            q_bits=q_bits, model_bits=model_bits(self.params),
            rayleigh=cfg.rayleigh, seed=rseed)
        # sync actual per-device sizes into the network model
        for k, p in enumerate(parts):
            self.sagin.devices[k].n_samples = p.n_samples
            self.sagin.devices[k].n_sensitive = p.n_sensitive

        self.orch = _build_orchestrator(cfg, self.sagin, scenario=scn,
                                        intervals=intervals)
        self._region_name = (self.region.name if self.region is not None
                             else f"region{cfg.region_index}")
        # fault injection (repro.resilience): the engine attaches its
        # shared FaultInjector here; None = clean run, zero overhead
        self.faults = None
        # last realized ISL scale, mirrored out of the round record so
        # federation snapshots survive checkpoint/resume (orchestrator
        # records are not checkpointed)
        self._last_isl_scale = 1.0
        # dynamics emits `outage` events against the tracer's round
        # context (set below in step()) instead of plumbing region
        # identity through the orchestrator call chain
        if self.orch.dynamics is not None:
            self.orch.dynamics.tracer = self.tracer

        self.execution = cfg.resolved_execution()
        if self.execution not in ("batched", "sequential"):
            raise ValueError(
                f"FLConfig.execution must be 'auto', 'batched' or "
                f"'sequential', got {cfg.execution!r}")
        # Params live on device for the whole job (host conversion only
        # at merge barriers and eval readouts).  The batched path gets a
        # persistent cohort engine: its signature bookkeeping spans
        # rounds, and with donation enabled (non-CPU backends) the round
        # step consumes the params buffer — device_put up front makes
        # that buffer privately owned by this trainer.
        self.params = jax.device_put(self.params)
        self.cohort_engine = None
        if self.execution == "batched" and cfg.cohort_bucketing != "global":
            from .cohort_engine import CohortEngine
            self.cohort_engine = CohortEngine(
                self.apply_fn, batch_align=cfg.cohort_batch_align,
                client_align=cfg.cohort_client_align,
                guard=cfg.guard_recompiles, tracer=self.tracer,
                sharding=cfg.cohort_sharding)

        self.result = FLResult(cfg, [], [], [], [], [], [])
        eval_idx = self.rng.choice(len(self.ds.x_test),
                                   size=min(cfg.eval_size,
                                            len(self.ds.x_test)),
                                   replace=False)
        self.x_eval = jnp.asarray(self.ds.x_test[eval_idx])
        self.y_eval = jnp.asarray(self.ds.y_test[eval_idx])

    @property
    def wall_clock(self) -> float:
        return self.orch.wall_clock

    @property
    def total_samples(self) -> int:
        """This region's data mass (constant: offloading conserves it)."""
        return self.pools.total()

    def federation_snapshot(self, index: int) -> RegionFedState:
        """This region's view for federation-policy planning: clock,
        data mass, model payload, and the ISL state its dynamics
        realized in the last completed round.  The trainer emits state;
        merge SEMANTICS live entirely in ``repro.fl.federation``."""
        return RegionFedState(
            index=index,
            name=self.region.name if self.region is not None else str(index),
            wall_clock=self.orch.wall_clock,
            data_mass=float(self.total_samples),
            model_bits=float(self.sagin.model_bits),
            z_isl=float(self.sagin.z_isl),
            isl_scale=self._last_isl_scale,
            rounds_done=len(self.result.times))

    def install_global(self, params, wall_clock: float):
        """Adopt the post-merge global model and post-merge clock; the
        next :meth:`step` resumes local training from the global model.

        The engine hands the SAME merged pytree to every region; when
        this trainer's cohort engine donates buffers, its next round
        would consume a buffer siblings still reference, so take a
        private device copy first."""
        if self.cohort_engine is not None and self.cohort_engine.donate:
            params = jax.tree_util.tree_map(
                lambda a: jnp.array(a, copy=True), params)
        self.params = params
        self.orch.wall_clock = wall_clock

    def step(self, r: int):
        """Execute FL round ``r``: orchestrate, place data, train every
        data-holding node, aggregate, evaluate.  Returns the round's
        :class:`~repro.core.scheduler.RoundRecord` and appends the
        training metrics to :attr:`result`."""
        cfg = self.cfg
        tr = self.tracer
        if tr.enabled:
            # context BEFORE orch.step: dynamics samples (and emits
            # `outage` events) inside it, at this round's start clock
            tr.set_context(region=self._region_name, round=r,
                           t_sim=self.orch.wall_clock)
        rec = self.orch.step(r)
        specs = (self.faults.at(r, cfg.region_index)
                 if self.faults is not None else ())
        crash = self._apply_latency_faults(rec, specs)
        _apply_plan_to_pools(rec.plan, self.pools, self.sagin)
        _sync_sizes(self.pools, self.sagin)

        # ---- local training at every node that holds data ----------------
        total = self.pools.total()
        node_pools = _node_pools(cfg, self.pools,
                                 offline=rec.offline_devices)
        # nan_update: the first ceil(severity) canonical nodes' trained
        # models are NaN-filled AFTER training (the RNG stream is
        # untouched, so the chaos trajectory stays seed-reproducible)
        nan_spec = next((s for s in specs if s.kind == "nan_update"), None)
        corrupt: Sequence[int] = ()
        if nan_spec is not None and node_pools:
            n_bad = min(max(1, int(nan_spec.severity)), len(node_pools))
            corrupt = tuple(range(n_bad))
        quarantine = (cfg.quarantine if cfg.quarantine is not None
                      else self.faults is not None)
        if crash is not None:
            # trainer process died mid-round: the round's training is
            # lost, recovery warm-restarts from the last committed model
            # (params unchanged) after a restart penalty on the clock
            penalty = crash.severity * rec.realized_latency
            self.faults.record_injected("trainer_crash",
                                        penalty_s=penalty)
            rec.realized_latency += penalty
            self.orch.wall_clock += penalty
            losses, n_quar = [], 0
            self.faults.record_recovered("trainer_crash",
                                         penalty_s=penalty)
        elif self.execution == "batched":
            self.params, losses, n_quar = _round_batched(
                cfg, self.apply_fn, self.params, self.ds, node_pools,
                total, self.rng, engine=self.cohort_engine,
                corrupt=corrupt, quarantine=quarantine)
        else:
            self.params, losses, n_quar = _round_sequential(
                cfg, self.apply_fn, self.params, self.ds, node_pools,
                total, self.rng, corrupt=corrupt, quarantine=quarantine)
        if corrupt and self.faults is not None:
            self.faults.record_injected("nan_update",
                                        n_corrupt=len(corrupt))
            if quarantine and n_quar >= len(corrupt):
                self.faults.record_recovered("nan_update",
                                             quarantined=n_quar)
        if n_quar:
            tr.metrics.counter("quarantine.updates").inc(n_quar)

        _, acc = evaluate(self.apply_fn, self.params, self.x_eval,
                          self.y_eval)
        res = self.result
        res.times.append(self.orch.wall_clock)
        res.accuracies.append(float(acc))
        res.losses.append(float(np.mean(losses)) if losses
                          else float("nan"))
        res.participated.append(bool(losses))
        res.latencies.append(rec.realized_latency)
        res.cases.append(rec.plan.case)
        n_ground = sum(len(self.pools.ground_all(k))
                       for k in range(cfg.n_devices))
        n_air = sum(len(a) for a in self.pools.air)
        res.layer_portions.append({
            "ground": n_ground / total, "air": n_air / total,
            "space": len(self.pools.sat) / total})
        self._last_isl_scale = (float(rec.events.isl_scale)
                                if rec.events is not None else 1.0)
        if tr.enabled:
            self._emit_round_spans(r, rec, res)
        return rec

    def _apply_latency_faults(self, rec, specs):
        """Apply this round's latency-shaped faults to the round record
        and the wall clock; returns the ``trainer_crash`` spec (handled
        at the training dispatch) or ``None``.

        ``sat_loss`` kills the serving satellite at
        ``severity * tau_S`` into the space schedule and re-plans onto
        the successor chain (:func:`repro.core.handover.replan_after_loss`
        — the unplanned mid-window handover); ``straggler`` stretches
        the realized round latency by ``severity``x.  Both are absorbed
        as extra realized latency — the round still completes, which IS
        the recovery."""
        crash = None
        for spec in specs:
            if spec.kind == "sat_loss":
                loss_t = spec.severity * rec.schedule.total_latency
                recovered, _ = replan_after_loss(rec.schedule, loss_t,
                                                 self.sagin)
                delta = max(0.0, recovered.total_latency
                            - rec.schedule.total_latency)
                self.faults.record_injected("sat_loss", loss_time=loss_t,
                                            delta_s=delta)
                rec.schedule = recovered
                rec.realized_latency += delta
                self.orch.wall_clock += delta
                self.faults.record_recovered("sat_loss", delta_s=delta)
            elif spec.kind == "straggler":
                delta = max(0.0, (spec.severity - 1.0)
                            * rec.realized_latency)
                self.faults.record_injected("straggler",
                                            slowdown=spec.severity)
                rec.realized_latency += delta
                self.orch.wall_clock += delta
                self.faults.record_recovered("straggler", delta_s=delta)
            elif spec.kind == "trainer_crash":
                crash = spec
        return crash

    def _emit_round_spans(self, r: int, rec, res: FLResult):
        """Trace one completed round: offload transfer, handover legs,
        and the round span itself (``repro.obs``; enabled path only).
        Purely observational — reads the round record, writes spans."""
        tr = self.tracer
        t0 = rec.wall_clock_start
        plan = rec.plan
        q_bits = float(self.sagin.q_bits)
        up = sum(sum(cp.d_ground_air.values()) + cp.d_air_space
                 for cp in plan.clusters)
        down = sum(sum(cp.d_air_ground.values()) + cp.d_space_air
                   for cp in plan.clusters)
        tr.span("offload", f"offload case{plan.case}", t_sim=t0,
                case=plan.case, up_samples=up, down_samples=down,
                bytes_moved=(up + down) * q_bits / 8.0)
        tr.metrics.counter("offload.bytes").inc((up + down) * q_bits / 8.0)
        tr.metrics.counter("offload.samples_up").inc(up)
        tr.metrics.counter("offload.samples_down").inc(down)

        sched = rec.schedule
        prev = None
        for leg in sched.legs:
            if prev is not None and leg.handover_delay > 0:
                tr.span("handover", f"sat{prev}->sat{leg.sat_index}",
                        t_sim=t0 + leg.start_time - leg.handover_delay,
                        dur_sim=leg.handover_delay,
                        samples=leg.samples_processed)
            prev = leg.sat_index
        if sched.n_handovers:
            tr.metrics.counter("handover.count").inc(sched.n_handovers)

        ev = rec.events
        uplink_delay = (sum(ev.uplink_delays.values())
                        if ev is not None else 0.0)
        tr.span("round", f"{self._region_name}/r{r}", t_sim=t0,
                dur_sim=rec.realized_latency,
                case=plan.case, latency_analytic=rec.latency,
                # the no-participant loss sentinel is NaN — not valid
                # strict JSON, so map it to None in the trace
                loss=(res.losses[-1] if res.participated[-1] else None),
                acc=res.accuracies[-1],
                participated=res.participated[-1],
                n_handovers=sched.n_handovers, t_space=sched.total_latency,
                uplink_delay=uplink_delay)
        tr.metrics.histogram("round.realized_latency_s").observe(
            rec.realized_latency)
        tr.metrics.histogram("round.overhead_s").observe(
            rec.realized_latency - rec.latency)


def run_fl(cfg: FLConfig, tracer=None) -> FLResult:
    """Single-region FL job: a :class:`RegionTrainer` stepped to the end.

    ``tracer`` (a :class:`repro.obs.Tracer`) overrides ``cfg.obs`` —
    ``run_fl_all_regions`` shares one tracer across regions this way;
    when this function owns the tracer (built from ``cfg.obs``) it also
    flushes the trace at the end of the run.
    """
    own_tracer = tracer is None
    trainer = RegionTrainer(cfg, tracer=tracer)
    for r in range(cfg.n_rounds):
        trainer.step(r)
    if own_tracer:
        trainer.tracer.flush()
    return trainer.result


def _apply_plan_to_pools(plan, pools: FederatedPools, sagin: SAGIN):
    """Mirror the optimizer's (fractional) plan as integer index moves."""
    for cp in plan.clusters:
        n = cp.n
        # downward: satellite -> air -> ground
        if cp.d_space_air > 0:
            pools.move_sat_to_air(n, int(round(cp.d_space_air)))
        for k, d in sorted(cp.d_air_ground.items()):
            pools.move_air_to_ground(n, k, int(round(d)))
        # upward: ground -> air -> satellite
        for k, d in sorted(cp.d_ground_air.items()):
            pools.move_ground_to_air(k, n, int(round(d)))
        if cp.d_air_space > 0:
            pools.move_air_to_sat(n, int(round(cp.d_air_space)))


def _sync_sizes(pools: FederatedPools, sagin: SAGIN):
    """Make the analytic model's sizes match the realized pools."""
    for k, dev in enumerate(sagin.devices):
        dev.n_samples = len(pools.ground_all(k))
        dev.n_sensitive = len(pools.ground_sensitive[k])
    for n, air in enumerate(sagin.air_nodes):
        air.n_samples = len(pools.air[n])
    sagin.n_sat_samples = len(pools.sat)
