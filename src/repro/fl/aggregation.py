"""Hierarchical model aggregation (eq. 13) and the cross-region merge.

Three implementations of the same weighted average:

1. ``fedavg``            — host-side pytree einsum over a client list.
2. ``fedavg_stacked``    — jitted over stacked client params; dispatches to
                           the Pallas ``fedavg_agg`` kernel on TPU.
                           ``fedavg_stacked_multi`` is its multi-bucket
                           form: one device-side call that concatenates
                           the size-bucketed cohort engine's per-bucket
                           stacks and aggregates the union (optionally
                           donating the stacked buffers).
3. ``hierarchical_psum`` — the mesh-native version used by the multi-pod
                           runner: lambda-weighted psum over the ``data``
                           axis (air-level aggregation) then the ``pod``
                           axis (space-level aggregation), inside shard_map.

On top of these, ``staleness_weighted_merge`` is the GLOBAL tier: it
averages per-region models (one per :class:`~repro.fl.rounds.RegionTrainer`)
into a single model over the inter-satellite links, weighting each
region by its data share discounted for model staleness — regions reach
an event-stepped merge barrier at different wall times, and a model that
sat waiting for ``s`` seconds contributes ``2^(-s / half_life)`` of its
share (FedMeld-style age discount).  The merge stacks the region pytrees
and reuses ``fedavg_stacked``, i.e. the Pallas ``fedavg_agg`` kernel
path on TPU.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(params_list: List, weights: Sequence[float]):
    """eq. (13) over a python list of client pytrees."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def combine(*leaves):
        stacked = jnp.stack(leaves)
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(combine, *params_list)


@partial(jax.jit, static_argnames=("interpret",))
def fedavg_stacked(stacked_params, weights, interpret: bool = False):
    """eq. (13) over stacked params (leading client axis C).

    Uses the fused Pallas aggregation kernel on TPU, jnp elsewhere;
    ``interpret=True`` forces the Pallas kernel in interpret mode (CPU
    validation of the TPU path).
    """
    from repro.kernels.fedavg_agg import ops as agg_ops
    w = weights / jnp.sum(weights)
    return jax.tree_util.tree_map(
        lambda leaf: agg_ops.weighted_aggregate(leaf, w,
                                                interpret=interpret),
        stacked_params)


def _fedavg_multi_impl(stacked_parts, weights, interpret: bool = False):
    """Concatenate per-bucket stacked params along the client axis and
    run ONE eq.-(13) weighted aggregate over the union — the device-side
    reduction of the bucketed cohort engine (no host round-trip between
    the bucket updates and the aggregate)."""
    if len(stacked_parts) == 1:
        stacked = stacked_parts[0]
    else:
        stacked = jax.tree_util.tree_map(
            lambda *leaves: jnp.concatenate(leaves, axis=0), *stacked_parts)
    return fedavg_stacked(stacked, weights, interpret=interpret)


_fedavg_multi = jax.jit(_fedavg_multi_impl, static_argnames=("interpret",))
# Donating variant: the per-bucket stacked params are intermediates the
# cohort engine owns, so their buffers can be consumed by the aggregate
# (the new global params are written in place of the round's client
# params).  Donation is a no-op warning on CPU, hence the split.
_fedavg_multi_donated = jax.jit(_fedavg_multi_impl,
                                static_argnames=("interpret",),
                                donate_argnums=(0,))


def fedavg_stacked_multi(stacked_parts: Sequence, weights,
                         interpret: bool = False, donate: bool = False):
    """eq. (13) over a tuple of stacked-param pytrees (one per size
    bucket, leading client axes C_b) in a single compiled device-side
    call; ``weights`` has length ``sum(C_b)`` in bucket order (padding
    clients carry weight 0).  ``donate=True`` donates the stacked
    buffers (only meaningful on accelerator backends)."""
    fn = _fedavg_multi_donated if donate else _fedavg_multi
    return fn(tuple(stacked_parts), weights, interpret=interpret)


@jax.jit
def client_finite_mask(stacked_params) -> jnp.ndarray:
    """Per-client finiteness over stacked params (leading client axis C).

    Returns a boolean ``(C,)`` vector: ``True`` where EVERY leaf element
    of that client's model is finite.  One fused device-side reduction —
    the quarantine gate the cohort engine applies before aggregation, so
    a NaN/Inf client update never reaches the eq.-(13) weighted sum.
    """
    def leaf_ok(leaf):
        return jnp.all(jnp.isfinite(leaf.reshape(leaf.shape[0], -1)),
                       axis=1)

    masks = [leaf_ok(leaf)
             for leaf in jax.tree_util.tree_leaves(stacked_params)]
    return functools.reduce(jnp.logical_and, masks)


def tree_all_finite(params) -> bool:
    """Host-side: True when every leaf element of ``params`` is finite.

    The sequential round loop's quarantine gate (one model at a time);
    forces a device sync, so it only runs when quarantine is armed.
    """
    return all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree_util.tree_leaves(params))


def fedavg_pytrees(params_list: List, weights,
                   interpret: bool = False):
    """eq. (13) over a python list of model pytrees via the DEVICE-side
    path: stacks the models along a leading axis and dispatches to
    :func:`fedavg_stacked` (the Pallas ``fedavg_agg`` kernel on TPU)
    with float32 weights.  A single-model "merge" is the identity.

    This is the one aggregation dispatch both
    :func:`staleness_weighted_merge` and the federation policies'
    ``MergePolicy.apply`` ride — keeping them bit-identical by
    construction (the synchronous-policy golden lock depends on it).
    """
    if len(params_list) == 1:
        return params_list[0]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *params_list)
    return fedavg_stacked(stacked, jnp.asarray(weights, jnp.float32),
                          interpret=interpret)


def staleness_merge_weights(sizes: Sequence[float],
                            staleness: Sequence[float],
                            half_life: Optional[float] = None) -> np.ndarray:
    """Normalized cross-region merge weights.

    ``weight_i ∝ sizes_i * 2^(-staleness_i / half_life)``: the data-share
    lambda of eq. (13) lifted to whole regions, discounted for the age of
    each region's model at the merge instant.  ``half_life=None`` (or
    ``inf``) disables the discount — pure data-share FedAvg.

    Edge semantics:

    * ``half_life=0`` is a HARD cutoff: only the freshest models (those
      at the minimum staleness — age 0 at a barrier) keep weight.
    * If the discount drives EVERY weight to zero (all models many
      half-lives stale, ``exp2`` underflow), the weights renormalize
      over the freshest models' data shares instead of emitting
      zero/NaN weights — a merge always redistributes unit mass.
    """
    w = np.asarray(sizes, dtype=np.float64)
    if np.any(w < 0) or w.sum() <= 0:
        raise ValueError(f"region sizes must be non-negative with positive "
                         f"total, got {list(sizes)}")
    s = np.asarray(staleness, dtype=np.float64)
    if s.shape != w.shape:
        raise ValueError(f"sizes/staleness length mismatch: "
                         f"{w.shape} vs {s.shape}")
    if np.any(s < 0):
        raise ValueError(f"staleness must be non-negative, got {list(s)}")
    if half_life is not None and np.isfinite(half_life):
        if half_life < 0:
            raise ValueError(f"half_life must be non-negative, "
                             f"got {half_life}")
        if half_life == 0:
            w = np.where(s == s.min(), w, 0.0)
        else:
            w = w * np.exp2(-s / half_life)
    if w.sum() <= 0:
        # all-stale underflow: fall back to data shares over the
        # freshest model(s); if those hold no data, to plain data shares
        w = np.where(s == s.min(), np.asarray(sizes, np.float64), 0.0)
        if w.sum() <= 0:
            w = np.asarray(sizes, np.float64)
    return w / w.sum()


def staleness_weighted_merge(params_list: List, sizes: Sequence[float],
                             staleness: Sequence[float],
                             half_life: Optional[float] = None,
                             interpret: bool = False,
                             return_weights: bool = False):
    """Merge per-region models into ONE global model.

    Stacks the region pytrees along a leading region axis and dispatches
    to :func:`fedavg_stacked` (the Pallas ``fedavg_agg`` kernel path on
    TPU) with :func:`staleness_merge_weights`.  ``return_weights=True``
    additionally returns the realized weights — the engine records them
    in its :class:`~repro.sim.engine.MergeEvent` without recomputing.
    """
    if len(params_list) != len(list(sizes)):
        raise ValueError(f"{len(params_list)} models but "
                         f"{len(list(sizes))} sizes")
    w = staleness_merge_weights(sizes, staleness, half_life)
    merged = fedavg_pytrees(params_list, w, interpret=interpret)
    return (merged, w) if return_weights else merged


def hierarchical_weighted_psum(local_params, lam, axis_names):
    """Mesh-native eq. (13): weighted sum over one or more mesh axes.

    Call inside ``shard_map``. ``lam`` is this shard's aggregation weight
    (its data portion); weights must sum to 1 across the axes.
    """
    def agg(leaf):
        contrib = (lam * leaf.astype(jnp.float32))
        for ax in axis_names:
            contrib = jax.lax.psum(contrib, ax)
        return contrib.astype(leaf.dtype)

    return jax.tree_util.tree_map(agg, local_params)


def shard_weighted_aggregate(stacked_params, weights, axis_names=("data",)):
    """In-mesh eq. (13) over a SHARD of stacked client params.

    Call inside ``shard_map``: ``stacked_params`` is this shard's slice
    of the bucket's client-stacked pytree (leading axis ``C_shard``) and
    ``weights`` its slice of the GLOBALLY normalized client weights
    (padding clients carry weight 0, so the full-axis weights sum to 1).
    Each shard reduces its clients through the stacked ``fedavg_agg``
    path (Pallas kernel on TPU), then the partial sums combine across
    ``axis_names`` via :func:`hierarchical_weighted_psum` — no host
    round-trip between the local update and the aggregate.
    """
    from repro.kernels.fedavg_agg import ops as agg_ops

    local = jax.tree_util.tree_map(
        lambda leaf: agg_ops.weighted_aggregate(leaf, weights),
        stacked_params)
    return hierarchical_weighted_psum(local, jnp.float32(1.0), axis_names)


def aggregation_weights(ground_sizes: Sequence[int],
                        air_sizes: Sequence[int],
                        sat_size: int) -> jnp.ndarray:
    """lambda weights of eq. (13): portions of the *global* dataset."""
    sizes = jnp.asarray(list(ground_sizes) + list(air_sizes) + [sat_size],
                        jnp.float32)
    return sizes / jnp.sum(sizes)
