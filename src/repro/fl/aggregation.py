"""Hierarchical model aggregation (eq. 13).

Three implementations of the same weighted average:

1. ``fedavg``            — host-side pytree einsum over a client list.
2. ``fedavg_stacked``    — jitted over stacked client params; dispatches to
                           the Pallas ``fedavg_agg`` kernel on TPU.
3. ``hierarchical_psum`` — the mesh-native version used by the multi-pod
                           runner: lambda-weighted psum over the ``data``
                           axis (air-level aggregation) then the ``pod``
                           axis (space-level aggregation), inside shard_map.
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence

import jax
import jax.numpy as jnp


def fedavg(params_list: List, weights: Sequence[float]):
    """eq. (13) over a python list of client pytrees."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def combine(*leaves):
        stacked = jnp.stack(leaves)
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(combine, *params_list)


@partial(jax.jit, static_argnames=("interpret",))
def fedavg_stacked(stacked_params, weights, interpret: bool = False):
    """eq. (13) over stacked params (leading client axis C).

    Uses the fused Pallas aggregation kernel on TPU, jnp elsewhere;
    ``interpret=True`` forces the Pallas kernel in interpret mode (CPU
    validation of the TPU path).
    """
    from repro.kernels.fedavg_agg import ops as agg_ops
    w = weights / jnp.sum(weights)
    return jax.tree_util.tree_map(
        lambda leaf: agg_ops.weighted_aggregate(leaf, w,
                                                interpret=interpret),
        stacked_params)


def hierarchical_weighted_psum(local_params, lam, axis_names):
    """Mesh-native eq. (13): weighted sum over one or more mesh axes.

    Call inside ``shard_map``. ``lam`` is this shard's aggregation weight
    (its data portion); weights must sum to 1 across the axes.
    """
    def agg(leaf):
        contrib = (lam * leaf.astype(jnp.float32))
        for ax in axis_names:
            contrib = jax.lax.psum(contrib, ax)
        return contrib.astype(leaf.dtype)

    return jax.tree_util.tree_map(agg, local_params)


def aggregation_weights(ground_sizes: Sequence[int],
                        air_sizes: Sequence[int],
                        sat_size: int) -> jnp.ndarray:
    """lambda weights of eq. (13): portions of the *global* dataset."""
    sizes = jnp.asarray(list(ground_sizes) + list(air_sizes) + [sat_size],
                        jnp.float32)
    return sizes / jnp.sum(sizes)
