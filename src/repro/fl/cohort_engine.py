"""Size-bucketed, device-resident cohort execution engine.

The PR-1 batched path padded every client to the round's global
``Bmax``: under the paper's adaptive offloading — which deliberately
concentrates samples on the best-placed node — the cohort tensor
becomes mostly zero-mask padding and the vmapped step burns its FLOPs
on masked slots.  :class:`CohortEngine` replaces that layout with the
geometric width buckets of
:func:`repro.data.pipeline.build_bucketed_cohort`:

* one compiled ``cohort_local_update`` dispatch per OCCUPIED bucket
  (clients padded only to their own bucket's width, so padded elements
  stay within a constant factor of real elements at any skew);
* ONE device-side aggregate over the union of all buckets' stacked
  params (:func:`repro.fl.aggregation.fedavg_stacked_multi`, the Pallas
  ``fedavg_agg`` kernel path on TPU) — parameters never round-trip
  through the host between local update and aggregation, and the
  stacked buffers are donated on accelerator backends;
* a bucket-signature cache keyed on ``(C_bucket, H, B_bucket,
  sample_shape, dtype)``: because both bucket axes are quantized to
  geometric grids, churn/offloading drift lands on already-seen
  signatures and recompiles stay at ZERO after warm-up (the
  ``signatures`` set is the engine's own bookkeeping; the actual
  compilation cache is jax's jit cache, which the stable signatures
  keep hitting).

With donation enabled, the single-bucket case (uniform pools) takes a
fused fast path — ``cohort_round_step_donated`` — that runs local
update + aggregate in one compiled call with the params buffer donated,
so the global model updates in place.

Donation contract: with ``donate=True`` (default on non-CPU backends)
:meth:`CohortEngine.round` CONSUMES the params argument — callers must
replace their reference with the returned params and must not hand the
same buffer to two consumers (``RegionTrainer`` keeps a private device
copy for exactly this reason).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.data.pipeline import BucketedCohort, build_bucketed_cohort

from .aggregation import fedavg_stacked_multi
from .client import cohort_local_update, cohort_round_step_donated


@dataclasses.dataclass
class CohortEngineStats:
    """Cumulative counters over an engine's lifetime (all rounds)."""
    rounds: int = 0
    bucket_dispatches: int = 0
    compiled_signatures: int = 0   # distinct bucket shapes seen so far
    real_elements: int = 0         # batch elements actually drawn
    layout_elements: int = 0       # batch elements the padded layout ran

    @property
    def padding_ratio(self) -> float:
        """layout / real batch elements — padded-FLOPs overhead factor."""
        return (self.layout_elements / self.real_elements
                if self.real_elements else 1.0)


class CohortEngine:
    """Executes FL rounds over size-bucketed cohorts, device-resident.

    One engine instance per FL job (``RegionTrainer`` owns one); the
    instance carries the signature bookkeeping and perf counters across
    rounds.  The compiled steps themselves live in jax's global jit
    cache, so even a throwaway engine benefits from previously compiled
    bucket signatures.
    """

    def __init__(self, apply_fn: Callable, batch_align: int = 32,
                 client_align: int = 4, donate: Optional[bool] = None,
                 guard: bool = False, tracer=None):
        from repro.obs import NULL_TRACER
        self.apply_fn = apply_fn
        # repro.obs tracer (RegionTrainer shares its own); the disabled
        # default costs one branch per round + one per bucket dispatch
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.batch_align = max(1, int(batch_align))
        self.client_align = max(1, int(client_align))
        # buffer donation is unsupported on CPU (jax warns and ignores);
        # default it off there and on everywhere else
        self.donate = (jax.default_backend() != "cpu"
                       if donate is None else bool(donate))
        # with guard=True, any round whose full bucket layout has been
        # executed before runs under contracts.no_recompile(): a lowering
        # on a warm signature raises instead of silently re-tracing
        self.guard = bool(guard)
        self.signatures: set = set()
        self.round_signatures: set = set()
        self.stats = CohortEngineStats()

    # -- cohort construction ------------------------------------------------
    def build(self, x: np.ndarray, y: np.ndarray,
              pools: Sequence[np.ndarray], n_steps: int,
              rng: np.random.Generator, max_batch: int
              ) -> Optional[BucketedCohort]:
        """Plan + materialize this round's bucketed cohort (host side)."""
        return build_bucketed_cohort(x, y, pools, n_steps, rng,
                                     max_batch=max_batch,
                                     batch_align=self.batch_align,
                                     client_align=self.client_align)

    # -- execution ----------------------------------------------------------
    def _round_signature(self, cohort: BucketedCohort) -> tuple:
        """Everything jax's jit caches key on for one round of this
        engine: the per-bucket shapes/dtypes (local-update dispatches)
        plus the donate flag (selects the fused vs. split program)."""
        return (tuple(cb.xs.shape + (str(cb.xs.dtype),)
                      for cb in cohort.buckets), self.donate)

    def _record(self, cohort: BucketedCohort):
        for cb in cohort.buckets:
            sig = cb.xs.shape + (str(cb.xs.dtype),)
            self.signatures.add(sig)
        self.round_signatures.add(self._round_signature(cohort))
        st = self.stats
        st.rounds += 1
        st.bucket_dispatches += len(cohort.buckets)
        st.compiled_signatures = len(self.signatures)
        st.real_elements += cohort.real_elements
        st.layout_elements += cohort.layout_elements

    def round(self, params, cohort: BucketedCohort, lr: float,
              total: int) -> Tuple[object, List[float]]:
        """Train every bucket and aggregate — one FL round on device.

        Returns ``(new_global_params, losses)`` with ``losses`` the real
        clients' mean local losses in canonical cohort order.  With
        ``self.donate`` the params argument is consumed (see module
        docstring).

        With ``self.guard``, a round whose layout signature is already
        warm runs under :func:`repro.analysis.contracts.no_recompile`;
        a recompile there raises ``ContractViolation`` instead of
        silently burning compile time every round.
        """
        tr = self.tracer
        if tr.enabled:
            # recompiles = bucket shapes not yet in the signature cache
            # (the PR-6 no_recompile contract's counter, as a metric)
            fresh = sum(1 for cb in cohort.buckets
                        if cb.xs.shape + (str(cb.xs.dtype),)
                        not in self.signatures)
            m = tr.metrics
            m.counter("cohort.recompiled_signatures").inc(fresh)
            m.counter("cohort.bucket_dispatches").inc(len(cohort.buckets))
            m.counter("cohort.real_elements").inc(cohort.real_elements)
            m.counter("cohort.layout_elements").inc(cohort.layout_elements)
        warm = self.guard and (self._round_signature(cohort)
                               in self.round_signatures)
        self._record(cohort)
        if tr.enabled:
            tr.metrics.gauge("cohort.padding_ratio").set(
                self.stats.padding_ratio)
        if warm:
            with contracts.no_recompile(label="CohortEngine.round"):
                return self._execute(params, cohort, lr, total)
        return self._execute(params, cohort, lr, total)

    def _trace_dispatch(self, cb, result, t0: float):
        """Emit one ``bucket_dispatch`` span (enabled tracer only).

        ``dur_wall`` is host dispatch time; with
        ``ObsConfig.device_timing`` the result is fenced with
        ``jax.block_until_ready`` first, so it is true device time
        (changes performance, never values — the fence only forces the
        synchronization that would happen later anyway).
        """
        tr = self.tracer
        if tr.device_timing:
            jax.block_until_ready(result)
        c, h, b = cb.xs.shape[0], cb.xs.shape[1], cb.xs.shape[2]
        tr.span("bucket_dispatch", f"C{c}xH{h}xB{b}",
                dur_wall=time.perf_counter() - t0,
                clients=c, batch_width=b,
                real=int(np.count_nonzero(cb.mask)),
                layout=int(cb.mask.size))
        tr.metrics.histogram("cohort.dispatch_wall_s").observe(
            time.perf_counter() - t0)

    def _execute(self, params, cohort: BucketedCohort, lr: float,
                 total: int) -> Tuple[object, List[float]]:
        lr = jnp.float32(lr)
        trace = self.tracer.enabled
        # eq.-(13) weights over the concatenated client axis, bucket
        # order; padding clients hold size 0 and therefore weight 0
        w = np.concatenate([cb.sizes for cb in cohort.buckets])
        weights = jnp.asarray(w / max(1, total), jnp.float32)

        if len(cohort.buckets) == 1 and self.donate:
            # fused fast path: local update + aggregate in ONE dispatch
            # with the params buffer donated (in-place model update).
            # Without donation the split path below wins — XLA:CPU
            # schedules the two smaller programs better than one fused
            # one, and there is no buffer to reuse anyway.
            cb = cohort.buckets[0]
            t0 = time.perf_counter() if trace else 0.0
            new_params, losses = cohort_round_step_donated(
                self.apply_fn, params, jnp.asarray(cb.xs),
                jnp.asarray(cb.ys), jnp.asarray(cb.mask), weights, lr)
            if trace:
                self._trace_dispatch(cb, (new_params, losses), t0)
            loss_parts = [losses]
        else:
            stacked_parts, loss_parts = [], []
            for cb in cohort.buckets:
                t0 = time.perf_counter() if trace else 0.0
                stacked, losses = cohort_local_update(
                    self.apply_fn, params, jnp.asarray(cb.xs),
                    jnp.asarray(cb.ys), jnp.asarray(cb.mask), lr)
                if trace:
                    self._trace_dispatch(cb, (stacked, losses), t0)
                stacked_parts.append(stacked)
                loss_parts.append(losses)
            new_params = fedavg_stacked_multi(stacked_parts, weights,
                                              donate=self.donate)

        out = np.zeros(cohort.n_clients, dtype=np.float64)
        for plan, losses in zip(cohort.plans, loss_parts):
            vals = np.asarray(losses)[:len(plan.members)]
            out[list(plan.members)] = vals
        return new_params, [float(v) for v in out]
