"""Size-bucketed, device-resident cohort execution engine.

The PR-1 batched path padded every client to the round's global
``Bmax``: under the paper's adaptive offloading — which deliberately
concentrates samples on the best-placed node — the cohort tensor
becomes mostly zero-mask padding and the vmapped step burns its FLOPs
on masked slots.  :class:`CohortEngine` replaces that layout with the
geometric width buckets of
:func:`repro.data.pipeline.build_bucketed_cohort`:

* one compiled ``cohort_local_update`` dispatch per OCCUPIED bucket
  (clients padded only to their own bucket's width, so padded elements
  stay within a constant factor of real elements at any skew);
* ONE device-side aggregate over the union of all buckets' stacked
  params (:func:`repro.fl.aggregation.fedavg_stacked_multi`, the Pallas
  ``fedavg_agg`` kernel path on TPU) — parameters never round-trip
  through the host between local update and aggregation, and the
  stacked buffers are donated on accelerator backends;
* a bucket-signature cache keyed on ``(C_bucket, H, B_bucket,
  sample_shape, dtype)``: because both bucket axes are quantized to
  geometric grids, churn/offloading drift lands on already-seen
  signatures and recompiles stay at ZERO after warm-up (the
  ``signatures`` set is the engine's own bookkeeping; the actual
  compilation cache is jax's jit cache, which the stable signatures
  keep hitting).

With donation enabled, the single-bucket case (uniform pools) takes a
fused fast path — ``cohort_round_step_donated`` — that runs local
update + aggregate in one compiled call with the params buffer donated,
so the global model updates in place.

Donation contract: with ``donate=True`` (default on non-CPU backends)
:meth:`CohortEngine.round` CONSUMES the params argument — callers must
replace their reference with the returned params and must not hand the
same buffer to two consumers (``RegionTrainer`` keeps a private device
copy for exactly this reason).

Mesh-sharded mode (``sharding="mesh"``, or ``"auto"`` with more than
one visible device) additionally shards every bucket's CLIENT axis over
the mesh's ``data`` axis: the planner pads client counts to multiples
of the shard count (:func:`repro.data.pipeline.plan_buckets`'s
``client_multiple``), each occupied bucket dispatches through
``shard_map`` (version-stable ``repro.compat.shard_map``) running the
per-shard local updates, and the shards' partial eq.-(13) sums combine
in-mesh via :func:`repro.fl.aggregation.shard_weighted_aggregate`
(stacked ``fedavg_agg`` + ``psum``) — parameters still never round-trip
through the host between local update and aggregate.  Bucket signatures
extend with the shard count (signature ⊕ mesh shape) so the
``no_recompile`` guard covers the sharded path too.  On a 1-device mesh
the engine degrades to the exact single-device code path — bit-identical
to ``sharding="off"`` by construction (golden-locked in
``tests/test_mesh_cohort.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.data.pipeline import BucketedCohort, build_bucketed_cohort

from .aggregation import (client_finite_mask, fedavg_stacked_multi,
                          shard_weighted_aggregate)
from .client import cohort_local_update, cohort_round_step_donated

SHARDING_MODES = ("auto", "mesh", "off")


@jax.jit
def _tree_sum(parts):
    """Sum a tuple of per-bucket partial-aggregate pytrees leaf-wise."""
    return jax.tree_util.tree_map(
        lambda *leaves: functools.reduce(jnp.add, leaves), *parts)


@dataclasses.dataclass
class CohortEngineStats:
    """Cumulative counters over an engine's lifetime (all rounds)."""
    rounds: int = 0
    bucket_dispatches: int = 0
    compiled_signatures: int = 0   # distinct bucket shapes seen so far
    real_elements: int = 0         # batch elements actually drawn
    layout_elements: int = 0       # batch elements the padded layout ran
    # mesh-sharded path only (all zero / 1.0 on a 1-shard engine):
    sharded_dispatches: int = 0    # bucket dispatches through shard_map
    shard_pad_clients: int = 0     # padding client slots in sharded layouts
    last_shard_imbalance: float = 1.0  # max/mean real elements per shard
    max_shard_imbalance: float = 1.0   # worst round so far

    @property
    def padding_ratio(self) -> float:
        """layout / real batch elements — padded-FLOPs overhead factor."""
        return (self.layout_elements / self.real_elements
                if self.real_elements else 1.0)


class CohortEngine:
    """Executes FL rounds over size-bucketed cohorts, device-resident.

    One engine instance per FL job (``RegionTrainer`` owns one); the
    instance carries the signature bookkeeping and perf counters across
    rounds.  The compiled steps themselves live in jax's global jit
    cache, so even a throwaway engine benefits from previously compiled
    bucket signatures.
    """

    def __init__(self, apply_fn: Callable, batch_align: int = 32,
                 client_align: int = 4, donate: Optional[bool] = None,
                 guard: bool = False, tracer=None, mesh=None,
                 sharding: str = "auto"):
        from repro.obs import NULL_TRACER
        from repro.sharding.specs import data_axis_size
        self.apply_fn = apply_fn
        # repro.obs tracer (RegionTrainer shares its own); the disabled
        # default costs one branch per round + one per bucket dispatch
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.batch_align = max(1, int(batch_align))
        self.client_align = max(1, int(client_align))
        # buffer donation is unsupported on CPU (jax warns and ignores);
        # default it off there and on everywhere else
        self.donate = (jax.default_backend() != "cpu"
                       if donate is None else bool(donate))
        # with guard=True, any round whose full bucket layout has been
        # executed before runs under contracts.no_recompile(): a lowering
        # on a warm signature raises instead of silently re-tracing
        self.guard = bool(guard)
        # client-axis mesh sharding: "off" never shards, "mesh" shards
        # over the given (or default) mesh's data axis, "auto" shards
        # only when more than one device is visible
        if sharding not in SHARDING_MODES:
            raise ValueError(f"sharding={sharding!r} not in "
                             f"{SHARDING_MODES}")
        self.sharding = sharding
        if sharding == "off":
            mesh = None
        elif mesh is None and (sharding == "mesh"
                               or len(jax.devices()) > 1):
            from repro.launch.mesh import make_cohort_mesh
            mesh = make_cohort_mesh()
        if mesh is not None and data_axis_size(mesh) < 1:
            raise ValueError(f"mesh {mesh} has no usable 'data' axis")
        self.mesh = mesh
        # number of client-axis shards each bucket dispatch splits into;
        # 1 (including any 1-device mesh) routes through the exact
        # single-device code path — the bit-identical degrade contract
        self.shards = data_axis_size(mesh)
        self._sharded_step = (self._make_sharded_step()
                              if self.shards > 1 else None)
        self.signatures: set = set()
        self.round_signatures: set = set()
        self.stats = CohortEngineStats()
        # clients quarantined (non-finite update dropped before the
        # aggregate) in the most recent round() call
        self.last_quarantined = 0

    # -- cohort construction ------------------------------------------------
    def build(self, x: np.ndarray, y: np.ndarray,
              pools: Sequence[np.ndarray], n_steps: int,
              rng: np.random.Generator, max_batch: int
              ) -> Optional[BucketedCohort]:
        """Plan + materialize this round's bucketed cohort (host side).

        On a sharded engine the planner additionally pads every bucket's
        client axis to a multiple of the shard count so ``shard_map``
        splits it without a remainder shard."""
        return build_bucketed_cohort(x, y, pools, n_steps, rng,
                                     max_batch=max_batch,
                                     batch_align=self.batch_align,
                                     client_align=self.client_align,
                                     client_multiple=self.shards)

    # -- execution ----------------------------------------------------------
    def _bucket_signature(self, cb) -> tuple:
        """Shard-stable compilation key for one bucket dispatch: the
        bucket's shape/dtype ⊕ the mesh shape (shard count).  The same
        bucket layout compiles separately per mesh, so both must key the
        signature cache."""
        return cb.xs.shape + (str(cb.xs.dtype), self.shards)

    def _round_signature(self, cohort: BucketedCohort) -> tuple:
        """Everything jax's jit caches key on for one round of this
        engine: the per-bucket shapes/dtypes (local-update dispatches)
        plus the donate flag (selects the fused vs. split program) and
        the shard count (selects the sharded vs. single-device program).
        """
        return (tuple(self._bucket_signature(cb) for cb in cohort.buckets),
                self.donate)

    def _shard_real_elements(self, cohort: BucketedCohort) -> np.ndarray:
        """Real (unmasked) batch elements each shard executes this round.

        ``shard_map`` splits every bucket's client axis into
        ``self.shards`` contiguous blocks; padding clients sit at the
        tail, so the trailing shards run the masked slack.
        """
        per = np.zeros(self.shards, dtype=np.int64)
        for cb in cohort.buckets:
            c = cb.mask.shape[0]
            per_client = cb.mask.reshape(c, -1).sum(axis=1)
            per += per_client.reshape(self.shards,
                                      c // self.shards).sum(axis=1).astype(
                                          np.int64)
        return per

    def _record(self, cohort: BucketedCohort):
        for cb in cohort.buckets:
            self.signatures.add(self._bucket_signature(cb))
        self.round_signatures.add(self._round_signature(cohort))
        st = self.stats
        st.rounds += 1
        st.bucket_dispatches += len(cohort.buckets)
        st.compiled_signatures = len(self.signatures)
        st.real_elements += cohort.real_elements
        st.layout_elements += cohort.layout_elements
        if self.shards > 1:
            st.sharded_dispatches += len(cohort.buckets)
            st.shard_pad_clients += sum(
                cb.xs.shape[0] - len(plan.members)
                for cb, plan in zip(cohort.buckets, cohort.plans))
            per = self._shard_real_elements(cohort)
            imb = (float(per.max() * self.shards / per.sum())
                   if per.sum() else 1.0)
            st.last_shard_imbalance = imb
            st.max_shard_imbalance = max(st.max_shard_imbalance, imb)
            if self.tracer.enabled:
                self.tracer.metrics.histogram(
                    "cohort.shard_imbalance").observe(imb)
                self.tracer.metrics.gauge(
                    "cohort.shard_pad_clients").set(st.shard_pad_clients)

    def round(self, params, cohort: BucketedCohort, lr: float,
              total: int, corrupt: Sequence[int] = (),
              quarantine: bool = False) -> Tuple[object, List[float]]:
        """Train every bucket and aggregate — one FL round on device.

        Returns ``(new_global_params, losses)`` with ``losses`` the real
        clients' mean local losses in canonical cohort order.  With
        ``self.donate`` the params argument is consumed (see module
        docstring).

        ``corrupt`` (fault injection: canonical client positions whose
        trained models are NaN-filled AFTER the local update — RNG
        streams untouched) and ``quarantine`` (drop non-finite client
        updates before aggregation, renormalizing the eq.-(13) weights
        over the survivors; the drop count lands in
        :attr:`last_quarantined`) route the round through the split
        single-device path — the fused donated and mesh-sharded programs
        have no masking hook — so a faulted round on a sharded engine
        degrades to one device for that round (documented trade: chaos
        rounds are rare and correctness beats throughput under faults).

        With ``self.guard``, a round whose layout signature is already
        warm runs under :func:`repro.analysis.contracts.no_recompile`;
        a recompile there raises ``ContractViolation`` instead of
        silently burning compile time every round.
        """
        tr = self.tracer
        faulted = bool(corrupt) or quarantine
        self.last_quarantined = 0
        if tr.enabled:
            # recompiles = bucket shapes not yet in the signature cache
            # (the PR-6 no_recompile contract's counter, as a metric)
            fresh = sum(1 for cb in cohort.buckets
                        if self._bucket_signature(cb)
                        not in self.signatures)
            m = tr.metrics
            m.counter("cohort.recompiled_signatures").inc(fresh)
            m.counter("cohort.bucket_dispatches").inc(len(cohort.buckets))
            m.counter("cohort.real_elements").inc(cohort.real_elements)
            m.counter("cohort.layout_elements").inc(cohort.layout_elements)
        # a faulted round may select a different compiled program than
        # the warm one (fused -> split), so the guard stands down for it
        warm = (self.guard and not faulted
                and self._round_signature(cohort) in self.round_signatures)
        self._record(cohort)
        if tr.enabled:
            tr.metrics.gauge("cohort.padding_ratio").set(
                self.stats.padding_ratio)
        if faulted:
            def execute(p, c, l, t):
                return self._execute(p, c, l, t, corrupt=corrupt,
                                     quarantine=quarantine)
        else:
            execute = (self._execute_sharded if self.shards > 1
                       else self._execute)
        if warm:
            with contracts.no_recompile(label="CohortEngine.round"):
                return execute(params, cohort, lr, total)
        return execute(params, cohort, lr, total)

    def _trace_dispatch(self, cb, result, t0: float):
        """Emit one ``bucket_dispatch`` span (enabled tracer only).

        ``dur_wall`` is host dispatch time; with
        ``ObsConfig.device_timing`` the result is fenced with
        ``jax.block_until_ready`` first, so it is true device time
        (changes performance, never values — the fence only forces the
        synchronization that would happen later anyway).
        """
        tr = self.tracer
        if tr.device_timing:
            jax.block_until_ready(result)
        c, h, b = cb.xs.shape[0], cb.xs.shape[1], cb.xs.shape[2]
        attrs = dict(clients=c, batch_width=b,
                     real=int(np.count_nonzero(cb.mask)),
                     layout=int(cb.mask.size),
                     mesh_shape=[self.shards])
        if self.shards > 1:
            # per-shard real elements of THIS bucket: shard i runs
            # clients [i*c/n, (i+1)*c/n) — the report's per-shard
            # dispatch-time breakdown apportions dur_wall by these
            per_client = cb.mask.reshape(c, -1).sum(axis=1)
            attrs["shard_real"] = [
                int(v) for v in per_client.reshape(
                    self.shards, c // self.shards).sum(axis=1)]
        tr.span("bucket_dispatch", f"C{c}xH{h}xB{b}",
                dur_wall=time.perf_counter() - t0, **attrs)
        tr.metrics.histogram("cohort.dispatch_wall_s").observe(
            time.perf_counter() - t0)

    def _execute(self, params, cohort: BucketedCohort, lr: float,
                 total: int, corrupt: Sequence[int] = (),
                 quarantine: bool = False) -> Tuple[object, List[float]]:
        # host numpy tensors and scalars go into the jitted steps as-is:
        # jit commits them through the C++ shard_args path, which is one
        # copy and no python dispatch — an explicit jnp.asarray per
        # tensor costs ~70us of pure overhead per call at small C (and
        # produces the very same committed f32 buffers)
        lr = np.float32(lr)
        trace = self.tracer.enabled
        corrupt = set(corrupt)
        # eq.-(13) weights over the concatenated client axis, bucket
        # order; padding clients hold size 0 and therefore weight 0
        w = np.concatenate([cb.sizes for cb in cohort.buckets])
        weights = (w / max(1, total)).astype(np.float32)
        dropped: List[int] = []

        if len(cohort.buckets) == 1 and self.donate and not (
                corrupt or quarantine):
            # fused fast path: local update + aggregate in ONE dispatch
            # with the params buffer donated (in-place model update).
            # Without donation the split path below wins — XLA:CPU
            # schedules the two smaller programs better than one fused
            # one, and there is no buffer to reuse anyway.
            cb = cohort.buckets[0]
            t0 = time.perf_counter() if trace else 0.0
            new_params, losses = cohort_round_step_donated(
                self.apply_fn, params, cb.xs, cb.ys, cb.mask, weights, lr)
            if trace:
                self._trace_dispatch(cb, (new_params, losses), t0)
            loss_parts = [losses]
        else:
            stacked_parts, loss_parts = [], []
            for bi, cb in enumerate(cohort.buckets):
                t0 = time.perf_counter() if trace else 0.0
                stacked, losses = cohort_local_update(
                    self.apply_fn, params, cb.xs, cb.ys, cb.mask, lr)
                if trace:
                    self._trace_dispatch(cb, (stacked, losses), t0)
                if corrupt:
                    # fault injection: NaN-fill the victims' trained
                    # models AFTER the update — every RNG draw is the
                    # one the clean run makes
                    rows = [row for row, m in
                            enumerate(cohort.plans[bi].members)
                            if m in corrupt]
                    for row in rows:
                        stacked = jax.tree_util.tree_map(
                            lambda a: a.at[row].set(jnp.nan), stacked)
                        losses = losses.at[row].set(jnp.nan)
                stacked_parts.append(stacked)
                loss_parts.append(losses)
            if quarantine:
                weights, dropped = self._quarantine_weights(
                    cohort, stacked_parts, weights)
                self.last_quarantined = len(dropped)
            if quarantine and weights.sum() <= 0:
                # every real update was non-finite: keep the previous
                # model (the split path never donated params)
                new_params = params
            else:
                new_params = fedavg_stacked_multi(stacked_parts, weights,
                                                  donate=self.donate)

        losses = self._scatter_losses(cohort, loss_parts)
        if dropped:
            bad = set(dropped)
            losses = [v for i, v in enumerate(losses) if i not in bad]
        return new_params, losses

    def _quarantine_weights(self, cohort: BucketedCohort,
                            stacked_parts: List, weights: np.ndarray
                            ) -> Tuple[np.ndarray, List[int]]:
        """Zero the aggregation weight of every non-finite client update.

        One fused :func:`client_finite_mask` reduction per bucket; the
        zeroed weights renormalize inside ``fedavg_stacked`` (it divides
        by the weight sum), so the eq.-(13) mass redistributes over the
        finite survivors.  Returns the adjusted weights and the
        quarantined clients' canonical positions.
        """
        w = np.array(weights, copy=True)
        dropped: List[int] = []
        off = 0
        for cb, stacked, plan in zip(cohort.buckets, stacked_parts,
                                     cohort.plans):
            finite = np.asarray(client_finite_mask(stacked))
            for row in np.nonzero(~finite)[0]:
                if row < len(plan.members):  # real client (not padding)
                    w[off + row] = 0.0
                    dropped.append(int(plan.members[row]))
            off += cb.xs.shape[0]
        return w, dropped

    # -- mesh-sharded execution ---------------------------------------------
    def _make_sharded_step(self):
        """Compile-once factory for the sharded bucket dispatch: a jitted
        ``shard_map`` program running the per-shard local updates and the
        in-mesh eq.-(13) partial aggregate — one program per bucket
        signature ⊕ mesh shape (jax's jit cache keys the shapes).

        The jit carries explicit ``in_shardings`` so the host numpy
        bucket tensors are committed straight into their mesh layout by
        the call itself (no staging ``device_put`` round-trip), and
        donates them — they are rebuilt from the drifted pools every
        round, so XLA may reuse their buffers for the program's
        temporaries instead of allocating a second bucket-sized
        working set.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import shard_map
        from repro.sharding.specs import cohort_step_specs
        apply_fn = self.apply_fn
        in_specs, out_specs = cohort_step_specs()
        repl = NamedSharding(self.mesh, P())
        split = NamedSharding(self.mesh, P("data"))

        def bucket_step(params, xs, ys, mask, weights, lr):
            # per-shard slice of the bucket: local updates over this
            # shard's clients, then the shard's weighted partial sum
            # combined across the data axis — no host round-trip
            stacked, losses = cohort_local_update(apply_fn, params, xs,
                                                  ys, mask, lr)
            part = shard_weighted_aggregate(stacked, weights,
                                            axis_names=("data",))
            return part, losses

        return jax.jit(
            shard_map(bucket_step, mesh=self.mesh, in_specs=in_specs,
                      out_specs=out_specs),
            in_shardings=(repl, split, split, split, split, repl),
            donate_argnums=(1, 2, 3, 4))

    def _execute_sharded(self, params, cohort: BucketedCohort, lr: float,
                         total: int) -> Tuple[object, List[float]]:
        """Dispatch every bucket through the sharded step.

        Weights are GLOBALLY normalized on the host (padding clients
        carry weight 0), so each bucket's shard_map call returns that
        bucket's partial eq.-(13) sum; multi-bucket rounds combine the
        partials with one extra leaf-wise add.  The model stays
        replicated across the mesh between rounds — only the first
        round (or an externally installed model) pays the broadcast.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P
        trace = self.tracer.enabled
        lr = jnp.float32(lr)
        repl = NamedSharding(self.mesh, P())
        params = jax.device_put(params, repl)
        w = np.concatenate([cb.sizes for cb in cohort.buckets])
        w = w.astype(np.float64)
        weights = (w / max(1.0, w.sum())).astype(np.float32)

        parts, loss_parts = [], []
        off = 0
        for cb in cohort.buckets:
            c = cb.xs.shape[0]
            wb = weights[off:off + c]
            off += c
            t0 = time.perf_counter() if trace else 0.0
            # host numpy tensors go in directly: the step's in_shardings
            # commit them onto the mesh, and the buffers are donated
            part, losses = self._sharded_step(
                params, cb.xs, cb.ys, cb.mask, wb, lr)
            if trace:
                self._trace_dispatch(cb, (part, losses), t0)
            parts.append(part)
            loss_parts.append(losses)
        new_params = parts[0] if len(parts) == 1 else _tree_sum(
            tuple(parts))
        return new_params, self._scatter_losses(cohort, loss_parts)

    @staticmethod
    def _scatter_losses(cohort: BucketedCohort,
                        loss_parts: List) -> List[float]:
        """Map per-bucket loss vectors back to canonical client order."""
        out = np.zeros(cohort.n_clients, dtype=np.float64)
        for plan, losses in zip(cohort.plans, loss_parts):
            vals = np.asarray(losses)[:len(plan.members)]
            out[list(plan.members)] = vals
        return [float(v) for v in out]
