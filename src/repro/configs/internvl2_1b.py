"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + Qwen2-0.5B language decoder. [arXiv:2404.16821]

The InternViT vision encoder + MLP projector are a STUB per the
assignment: ``input_specs`` provides pre-projected patch embeddings
(B, S, d_model); this config implements the language decoder that
consumes them.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    input_mode="embeddings",
    sliding_window=8192,   # long_500k variant
    source="arXiv:2404.16821",
)
