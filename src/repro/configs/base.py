"""Architecture configuration schema for the model zoo.

Every assigned architecture is a ``ModelConfig`` instance; the decoder-only
transformer in ``repro.models.transformer`` composes layers from it. Reduced
variants (for CPU smoke tests) come from ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense|ssm|moe|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                # 0 -> d_model // n_heads

    # attention flavor
    attention: str = "gqa"         # gqa|mla|none
    qk_norm: bool = False
    rope_theta: float = 1e6
    sliding_window: Optional[int] = None   # enables long_500k for dense archs

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    moe_every: int = 1             # MoE FFN every k-th layer (jamba: 2)

    # hybrid (jamba): one attention layer per ``attn_every`` layers
    attn_every: int = 0            # 0 -> pure attention stack
    # ssm
    ssm_type: str = ""             # rwkv6|mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                # mamba inner expansion

    norm_type: str = "rmsnorm"     # rmsnorm|nonparametric_ln
    input_mode: str = "tokens"     # tokens|embeddings (audio/vlm stubs)
    tie_embeddings: bool = False

    # numerics
    param_dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing over layers

    # implementation strategy knobs (EXPERIMENTS.md §Perf iterates these)
    moe_grouped: bool = True       # per-sequence dispatch (data-sharded);
                                   # False: global-token dispatch (naive)
    mamba_scan_chunk: int = 64     # chunked+vectorized ssm scan (cumprod/
                                   # cumsum closed form); 0 = naive scan.
                                   # <=64 keeps 1/cumprod(da) in f32 range.

    # citation for the config values
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        evenly on the model mesh axis (affects internvl2's 151655)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        if self.n_heads:
            return self.d_model // self.n_heads
        return 64

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        return (self.arch_type in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.attention == "mla")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                     # embed
        if not self.tie_embeddings:
            total += v * d                # lm head
        per_layer = 0
        hd = self.head_dim
        for li in range(self.n_layers):
            is_attn = self._layer_is_attention(li)
            if is_attn and self.attention == "gqa":
                per = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                       + self.n_heads * hd * d)
            elif is_attn and self.attention == "mla":
                r = self.kv_lora_rank
                qd = self.qk_nope_head_dim + self.qk_rope_head_dim
                per = (d * self.n_heads * qd
                       + d * (r + self.qk_rope_head_dim)
                       + r * self.n_heads * (self.qk_nope_head_dim
                                             + self.v_head_dim)
                       + self.n_heads * self.v_head_dim * d)
            elif self.ssm_type == "mamba":
                di = self.expand * d
                per = (d * 2 * di + di * self.d_conv
                       + di * (self.d_state * 2 + 1 + d)  # dt,B,C + out? approx
                       + di * self.d_state + di * d)
            elif self.ssm_type == "rwkv6":
                per = 6 * d * d + 2 * d   # r,k,v,w,g,out (+ u, mix params)
            else:
                per = 0
            # ffn
            if self.n_experts and ((li % self.moe_every) == self.moe_every - 1):
                f = self.moe_d_ff or self.d_ff
                per += self.n_experts * 3 * d * f
                per += self.n_shared_experts * 3 * d * f
                per += d * self.n_experts  # router
            elif self.ssm_type != "rwkv6":
                per += 3 * d * self.d_ff
            else:
                per += 2 * d * int(3.5 * d)  # rwkv channel-mix
            per_layer += per
        return total + per_layer

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only active experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        n_moe_layers = len([li for li in range(self.n_layers)
                            if (li % self.moe_every) == self.moe_every - 1])
        inactive = (self.n_experts - self.n_experts_active)
        return self.param_count() - n_moe_layers * inactive * 3 * d * f

    def _layer_is_attention(self, li: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.attn_every:
            return (li % self.attn_every) == 0
        return True

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                n_experts: int = 4) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads if self.n_heads else 0,
            n_kv_heads=kv if self.n_kv_heads else 0,
            d_head=(d_model // heads) if self.n_heads else 0,
            d_ff=2 * d_model,
            vocab_size=min(self.vocab_size, 512),
            kv_lora_rank=min(self.kv_lora_rank, 64),
            qk_nope_head_dim=32 if self.attention == "mla" else self.qk_nope_head_dim,
            qk_rope_head_dim=16 if self.attention == "mla" else self.qk_rope_head_dim,
            v_head_dim=32 if self.attention == "mla" else self.v_head_dim,
            n_experts=min(self.n_experts, n_experts),
            n_experts_active=min(self.n_experts_active,
                                 min(self.n_experts, n_experts)),
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=d_model if self.moe_d_ff else 0,
            attn_every=min(self.attn_every, n_layers) if self.attn_every else 0,
            sliding_window=(64 if self.sliding_window is not None else None),
            param_dtype="float32",
            remat=False,
        )
        if self.attn_every:
            changes["n_layers"] = max(n_layers, self.attn_every)
            changes["attn_every"] = changes["n_layers"]
        return dataclasses.replace(self, **changes)
