"""The paper's own FL payloads (Section VI-A): small CNNs + VGG-11.

These are not ModelConfigs (they are vision CNNs, see repro.models.cnn);
this module records their metadata for the latency model.
"""
PAPER_MODELS = {
    "mnist": {"model": "cnn-2conv-2fc", "dataset": "mnist"},
    "fmnist": {"model": "cnn-2conv-1fc", "dataset": "fmnist"},
    "cifar10": {"model": "vgg11", "dataset": "cifar10"},
}
