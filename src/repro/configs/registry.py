"""Architecture registry: ``get_config(arch_id)`` for --arch selection."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from .qwen3_32b import CONFIG as QWEN3_32B
from .rwkv6_1p6b import CONFIG as RWKV6_1P6B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE
from .llama3p2_3b import CONFIG as LLAMA3P2_3B
from .musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from .olmo_1b import CONFIG as OLMO_1B
from .internvl2_1b import CONFIG as INTERNVL2_1B
from .deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE
from .deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from .jamba_1p5_large_398b import CONFIG as JAMBA_1P5_LARGE

REGISTRY: Dict[str, ModelConfig] = {
    "qwen3-32b": QWEN3_32B,
    "rwkv6-1.6b": RWKV6_1P6B,
    "qwen3-moe-235b-a22b": QWEN3_MOE,
    "llama3.2-3b": LLAMA3P2_3B,
    "musicgen-medium": MUSICGEN_MEDIUM,
    "olmo-1b": OLMO_1B,
    "internvl2-1b": INTERNVL2_1B,
    "deepseek-v2-lite-16b": DEEPSEEK_V2_LITE,
    "deepseek-coder-33b": DEEPSEEK_CODER_33B,
    "jamba-1.5-large-398b": JAMBA_1P5_LARGE,
}

ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return REGISTRY[arch_id]
