"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4)
per-expert d_ff=1536, vocab=151936, 128 experts top-8, qk-norm.
[hf:Qwen/Qwen3-30B-A3B scaled per assignment]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,             # per-expert hidden dim (assignment value)
    moe_d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    n_experts=128,
    n_experts_active=8,
    sliding_window=8192,   # long_500k variant
    source="hf:Qwen/Qwen3-30B-A3B",
)
