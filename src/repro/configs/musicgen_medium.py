"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24, i.e. MHA)
d_ff=6144 vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec tokenizer/frontend is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, S, d_model);
the decoder transformer here is the real implementation.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    input_mode="embeddings",
    sliding_window=8192,   # long_500k variant
    source="arXiv:2306.05284",
)
