"""Assigned input shapes and ShapeDtypeStruct input_specs for the dry-run.

  train_4k     seq=4096    global_batch=256   (training)      -> train_step
  prefill_32k  seq=32768   global_batch=32    (prefill)       -> prefill
  decode_32k   seq=32768   global_batch=128   (decode)        -> serve_step
  long_500k    seq=524288  global_batch=1     (long decode)   -> serve_step

Decode shapes lower ``serve_step`` (ONE token, cache of seq_len).
long_500k requires a sub-quadratic attention path (SSM / hybrid / MLA
latent cache / sliding window) — ``supports()`` encodes the policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train|prefill|decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def supports(cfg: ModelConfig, shape: InputShape) -> bool:
    """Policy for which (arch x shape) combos are built (DESIGN.md §5)."""
    if shape.name == "long_500k":
        return cfg.is_subquadratic
    return True


def input_specs(cfg: ModelConfig, shape: InputShape,
                batch_override: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    f = jnp.dtype(cfg.param_dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), f)
        out = {"inputs": inputs}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return out
    # decode: ONE new token; the cache spec is created separately
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), f)
    return {"inputs": inputs}


def cache_specs(cfg: ModelConfig, shape: InputShape,
                batch_override: Optional[int] = None):
    """ShapeDtypeStruct pytree matching transformer.init_cache."""
    from repro.models import transformer as T
    b = batch_override or shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, b, shape.seq_len))
    return cache_shape
