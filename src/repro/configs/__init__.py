from .base import ModelConfig
from .registry import ARCH_IDS, REGISTRY, get_config
from .shapes import SHAPES, InputShape, cache_specs, input_specs, supports

__all__ = ["ModelConfig", "ARCH_IDS", "REGISTRY", "get_config", "SHAPES",
           "InputShape", "cache_specs", "input_specs", "supports"]
