"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H (kv=16) per-expert
d_ff=1408 vocab=102400, MLA kv_lora=512, MoE 64 routed experts top-6
+ 2 shared. [arXiv:2405.04434]

Note: the assignment line reads "MoE 64e top-6 ... 2 shared+160 routed
top-6"; we follow the explicit ``MoE 64e top-6`` spec (see DESIGN.md §5).
MLA's rank-512 latent KV cache makes the full 500k-token decode cache
small (~0.6 GB bf16 at B=1 across layers), so long_500k runs natively.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_experts_active=6,
    n_shared_experts=2,
    source="arXiv:2405.04434",
)
