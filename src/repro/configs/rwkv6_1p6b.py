"""rwkv6-1.6b [ssm]: 24L d_model=2048 attention-free, d_ff=7168
vocab=65536 — Finch, data-dependent decay. [arXiv:2404.05892]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    ssm_type="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    source="arXiv:2404.05892",
)
