"""olmo-1b [dense]: 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 —
non-parametric LayerNorm. [arXiv:2402.00838]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    arch_type="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparametric_ln",
    sliding_window=8192,   # long_500k variant
    source="arXiv:2402.00838",
)
