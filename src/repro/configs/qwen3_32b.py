"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk-norm. [hf:Qwen/Qwen3-8B family scaling; head_dim=128 as in
all Qwen3 models]. Sliding-window variant (8192) enables long_500k decode."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    sliding_window=8192,   # only used by the long_500k decode shape
    source="hf:Qwen/Qwen3-8B",
)
