"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba+attention 1:7 interleave, MoE 16 experts
top-2 applied every other layer (as in the Jamba paper). [arXiv:2403.19887]

Scan unit: one 8-layer block (1 attention + 7 Mamba layers; FFNs alternate
dense / 16-expert MoE). Sub-quadratic (Mamba-majority + the attention
layers' sliding window) => runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    moe_d_ff=24576,
    vocab_size=65536,
    attn_every=8,
    n_experts=16,
    n_experts_active=2,
    moe_every=2,
    d_state=16,
    expand=2,
    sliding_window=8192,   # bounds the attention cache for long_500k
    source="arXiv:2403.19887",
)
