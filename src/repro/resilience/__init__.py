"""``repro.resilience`` — deterministic fault injection + recovery.

The injection harness (:class:`FaultPlan`, :class:`FaultInjector`) lives
here; the recovery behaviors live in the hot paths they protect:
unplanned-handover re-planning in ``repro.core.handover``, the
partition-tolerant merge fallback in ``repro.fl.federation.policies``,
the non-finite-update quarantine in ``repro.fl.rounds`` /
``repro.fl.cohort_engine``, and full-engine checkpoint/resume in
``repro.checkpoint.engine``.  The ``chaos`` scenario preset
(``repro.scenarios``) wires all of them into one run.
"""
from .faults import (DEFAULT_SEVERITY, FAULT_KINDS, FaultInjector,  # noqa: F401
                     FaultPlan, FaultSpec)

__all__ = ["DEFAULT_SEVERITY", "FAULT_KINDS", "FaultInjector", "FaultPlan",
           "FaultSpec"]
