"""Deterministic, seeded fault injection for the SAGIN FL stack.

The paper's claim — adaptive offloading + seamless handover keep FL
training on track under inconsistent coverage — is only testable if
failures can be *injected* deterministically and the recovery paths
exercised on demand.  This module is the injection half; the recovery
behaviors live in the hot paths they protect
(``core.handover.replan_after_loss``,
``fl.federation.policies.plan_under_partition``, the quarantine path in
``fl.rounds``/``fl.cohort_engine``).

Typed faults (:data:`FAULT_KINDS`):

=================  =========================================================
``sat_loss``       The serving satellite dies mid-coverage at fraction
                   ``severity`` of the round's space schedule; recovery
                   re-plans an unplanned handover to the successor
                   satellite (``core.handover.replan_after_loss``).
``isl_partition``  The region's ISL is partitioned at the merge boundary
                   ``round``; recovery retries with capped backoff then
                   falls back to the ``partial``-quorum plan.
``straggler``      The round's realized latency stretches by factor
                   ``severity`` (slow node / congested uplink); absorbed
                   by the event-stepped clock.
``nan_update``     The first ``int(severity)`` trained client models of
                   the round are replaced with NaNs *after* training
                   (RNG streams untouched); recovery quarantines
                   non-finite deltas before aggregation and renormalizes
                   the eq.-(13) weights.
``trainer_crash``  The region's trainer dies for the round: no node
                   trains, the model warm-restarts unchanged next round,
                   and the clock pays ``severity`` x the round latency
                   as restart penalty.
=================  =========================================================

A :class:`FaultPlan` is an immutable schedule of :class:`FaultSpec`
entries addressed by ``(round, region)``; handcraft one (the ``chaos``
scenario preset does) or draw one from seeded per-round Bernoulli rates
with :meth:`FaultPlan.generate` — identical seeds give identical plans.
The shared :class:`FaultInjector` holds the run's injected/recovered
counters (checkpointable via ``state_dict``) and emits ``fault`` /
``recovery`` spans through ``repro.obs``.

Determinism contract: injection never draws from any run RNG stream —
plans are fixed before the run starts, and corruption applies to
already-computed models — so a faulted run and a clean run share every
draw up to the first behavioral divergence the fault itself causes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

FAULT_KINDS = ("sat_loss", "isl_partition", "straggler", "nan_update",
               "trainer_crash")

#: Default ``severity`` per kind when :meth:`FaultPlan.generate` draws a
#: fault (see the kind table above for each kind's severity semantics).
DEFAULT_SEVERITY = {
    "sat_loss": 0.5,       # dies halfway through the space schedule
    "isl_partition": 1.0,
    "straggler": 2.5,      # 2.5x realized round latency
    "nan_update": 1.0,     # one corrupted client model
    "trainer_crash": 0.5,  # restart penalty: 0.5x the round latency
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``round`` is the per-region FL round index for in-round kinds, and
    the BARRIER round (rounds completed at the boundary) for
    ``isl_partition``.  ``severity`` semantics are per kind (see the
    module table).
    """
    kind: str
    round: int
    region: int
    severity: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if self.round < 0:
            raise ValueError(f"fault round must be >= 0, got {self.round}")
        if self.severity <= 0:
            raise ValueError(f"fault severity must be positive, got "
                             f"{self.severity}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Immutable schedule of typed faults for one run."""
    faults: Tuple[FaultSpec, ...] = ()

    @classmethod
    def generate(cls, seed: int, n_rounds: int, n_regions: int,
                 rates: Dict[str, float],
                 severity: Optional[Dict[str, float]] = None) -> "FaultPlan":
        """Draw a plan from per-(round, region) Bernoulli rates.

        ``rates`` maps fault kind -> per-round-per-region probability;
        the plan's own ``default_rng(seed)`` drives every draw (one
        uniform per (kind, round, region) cell in sorted-kind order), so
        identical arguments give identical plans and the draws never
        touch any run RNG stream.
        """
        sev = dict(DEFAULT_SEVERITY)
        if severity:
            sev.update(severity)
        for kind in rates:
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in rates; "
                                 f"expected one of {FAULT_KINDS}")
        rng = np.random.default_rng(seed)
        specs = []
        for kind in sorted(rates):
            p = float(rates[kind])
            u = rng.random((n_rounds, n_regions))
            for rnd, reg in np.argwhere(u < p).tolist():
                specs.append(FaultSpec(kind=kind, round=rnd, region=reg,
                                       severity=sev[kind]))
        specs.sort(key=lambda s: (s.round, s.region, s.kind))
        return cls(faults=tuple(specs))

    def at(self, round: int, region: int) -> Tuple[FaultSpec, ...]:
        """In-round faults scheduled for ``(round, region)`` —
        ``isl_partition`` is excluded (it fires at merge boundaries; see
        :meth:`partitioned_regions`)."""
        return tuple(f for f in self.faults
                     if f.round == round and f.region == region
                     and f.kind != "isl_partition")

    def partitioned_regions(self, barrier_round: int) -> Tuple[int, ...]:
        """Regions whose ISL is partitioned at this merge boundary."""
        return tuple(sorted({f.region for f in self.faults
                             if f.kind == "isl_partition"
                             and f.round == barrier_round}))

    def __len__(self) -> int:
        return len(self.faults)


class FaultInjector:
    """Run-wide fault bookkeeping: the one shared instance the engine
    hands to every region trainer.

    Carries the plan, the injected/recovered counters per kind (the
    numbers ``python -m repro.obs report`` surfaces), and the run's
    tracer for ``fault``/``recovery`` span emission.  Counter state is
    checkpointable (:meth:`state_dict`) so a resumed run keeps counting
    where it left off.
    """

    def __init__(self, plan: FaultPlan, tracer=None):
        from repro.obs import NULL_TRACER
        self.plan = plan
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.injected = {k: 0 for k in FAULT_KINDS}
        self.recovered = {k: 0 for k in FAULT_KINDS}

    # -- schedule queries ----------------------------------------------------
    def at(self, round: int, region: int) -> Tuple[FaultSpec, ...]:
        return self.plan.at(round, region)

    def partition_at(self, barrier_round: int) -> Tuple[int, ...]:
        return self.plan.partitioned_regions(barrier_round)

    # -- recording -----------------------------------------------------------
    def record_injected(self, kind: str, **attrs) -> None:
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        self.injected[kind] += 1
        tr = self.tracer
        if tr.enabled:
            tr.event("fault", kind, fault=kind, **attrs)
            tr.metrics.counter(f"fault.injected.{kind}").inc()

    def record_recovered(self, kind: str, **attrs) -> None:
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}")
        self.recovered[kind] += 1
        tr = self.tracer
        if tr.enabled:
            tr.event("recovery", kind, fault=kind, **attrs)
            tr.metrics.counter(f"fault.recovered.{kind}").inc()

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"injected": dict(self.injected),
                "recovered": dict(self.recovered)}

    def load_state_dict(self, state: dict) -> None:
        for k in FAULT_KINDS:
            self.injected[k] = int(state["injected"].get(k, 0))
            self.recovered[k] = int(state["recovered"].get(k, 0))
