"""Vectorized SAGIN constellation propagation and coverage extraction.

Re-implements the seed's per-satellite/per-region Python loops
(``core/constellation.py``) as batched array operations over
``(n_regions, n_times, n_sats)``.  The two key optimisations:

1. **Basis factoring.** Every circular-orbit position is linear in
   ``(cos nt, sin nt)`` (angle addition on ``u = u0 + nt``) and every
   rotating ground target is affine in ``(cos Ot, sin Ot)``.  The whole
   ``(R, T, N)`` satellite-target dot-product field therefore factors
   into one ``(T, 6) @ (6, N)`` GEMM per region over precomputed
   constant bases — transcendentals are evaluated on ``O(T + N)``
   values instead of ``O(T * N)`` per region.
2. **Visibility without arcsin.** On a spherical Earth the elevation is
   monotone in the satellite-target central angle, so the minimum
   elevation maps to a scalar dot-product threshold
   ``a R cos(psi_max)`` with ``psi_max = acos(R cos e / a) - e``.
   Thresholding the GEMM output directly replaces the seed's
   per-sample norm + arcsin passes.

Interval extraction is a single padded-diff over the whole ``(T, N)``
visibility mask per region instead of a Python loop over satellites;
the emitted :class:`AccessInterval` lists are bit-identical in ordering
and boundary convention to the seed implementation (kept below as
:func:`access_intervals_loop` for equivalence tests and benchmarks).

Backend: ``jax.numpy`` on accelerator backends, NumPy on CPU (where the
un-jitted dispatch overhead of eager jax loses to NumPy for these
shapes); select explicitly with ``backend="numpy"|"jax"``.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.constellation import (AccessInterval, R_EARTH, OMEGA_EARTH,
                                      WalkerStar, elevation_angles)


@dataclasses.dataclass(frozen=True)
class Region:
    """A ground target region served by its own FL orchestration."""
    name: str
    lat_deg: float
    lon_deg: float
    min_elevation_deg: float = 15.0


def resolve_backend(backend: str = "auto"):
    """Return the array namespace for the batched propagation math."""
    if backend == "numpy":
        return np
    if backend == "jax":
        import jax.numpy as jnp
        return jnp
    if backend != "auto":
        raise ValueError(f"backend must be 'auto', 'numpy' or 'jax', "
                         f"got {backend!r}")
    try:
        import jax
        if jax.default_backend() != "cpu":
            return jax.numpy
    except Exception:  # pragma: no cover - jax is a hard dep in this repo
        pass
    return np


# ---------------------------------------------------------------------------
# Batched geometry -----------------------------------------------------------
# ---------------------------------------------------------------------------
def _freeze(arr: np.ndarray) -> np.ndarray:
    """Mark a memoized array read-only: cached basis operands are shared
    across every caller, so accidental in-place edits must fail loudly."""
    arr.flags.writeable = False
    return arr


@lru_cache(maxsize=128)
def constellation_basis(ws: WalkerStar) -> np.ndarray:
    """Linear basis B, shape (2, n_sats, 3), with
    ``pos(t) = cos(nt) * B[0] + sin(nt) * B[1]``.

    Derived by angle addition on the argument of latitude
    ``u = u0 + n t`` of ``WalkerStar.positions_eci``; the basis is a
    pure function of the (frozen, hashable) constellation geometry, so
    it is memoized per constellation — the engine's event loop calls
    into the propagation pass once per region step, and rebuilding the
    GEMM operands each time was pure waste.  The returned array is
    read-only.
    """
    inc = np.deg2rad(ws.inclination_deg)
    S, P = ws.sats_per_plane, ws.n_planes
    raan = np.pi * np.arange(P) / P                              # (P,)
    base_u = 2 * np.pi * np.arange(S) / S                        # (S,)
    phase = 2 * np.pi * ws.phasing / ws.n_sats
    u0 = base_u[None, :] + phase * np.arange(P)[:, None]         # (P,S)
    cu, su = np.cos(u0), np.sin(u0)
    a = ws.semi_major
    ci, si = np.cos(inc), np.sin(inc)
    cr = np.cos(raan)[:, None]
    sr = np.sin(raan)[:, None]
    # cos(nt) coefficients of (x, y, z)
    b0 = np.stack([a * (cu * cr - su * ci * sr),
                   a * (cu * sr + su * ci * cr),
                   a * su * si], axis=-1)                        # (P,S,3)
    # sin(nt) coefficients of (x, y, z)
    b1 = np.stack([a * (-su * cr - cu * ci * sr),
                   a * (-su * sr + cu * ci * cr),
                   a * cu * si], axis=-1)
    return _freeze(np.stack([b0.reshape(ws.n_sats, 3),
                             b1.reshape(ws.n_sats, 3)]))         # (2,N,3)


def region_basis(regions: Sequence[Region]) -> np.ndarray:
    """Affine basis D, shape (R, 3, 3), with
    ``tgt_r(t) = cos(Ot) * D[r, 0] + sin(Ot) * D[r, 1] + D[r, 2]``.

    Memoized per region tuple (``Region`` is frozen/hashable); the
    returned array is read-only.
    """
    return _region_basis_cached(tuple(regions))


@lru_cache(maxsize=128)
def _region_basis_cached(regions: Tuple[Region, ...]) -> np.ndarray:
    lat = np.deg2rad([r.lat_deg for r in regions])
    lon = np.deg2rad([r.lon_deg for r in regions])
    cl, sl = np.cos(lat), np.sin(lat)
    co, so = np.cos(lon), np.sin(lon)
    zeros = np.zeros_like(cl)
    d0 = np.stack([R_EARTH * cl * co, R_EARTH * cl * so, zeros], axis=-1)
    d1 = np.stack([-R_EARTH * cl * so, R_EARTH * cl * co, zeros], axis=-1)
    d2 = np.stack([zeros, zeros, R_EARTH * sl], axis=-1)
    return _freeze(np.stack([d0, d1, d2], axis=1))               # (R,3,3)


@lru_cache(maxsize=128)
def _target_gram(ws: WalkerStar, regions: Tuple[Region, ...]) -> np.ndarray:
    """Contracted basis G, shape (R, 6, n_sats) — the constant GEMM
    operand of :func:`target_dots`, memoized per (constellation,
    regions) pair.  Read-only."""
    b = constellation_basis(ws)                                  # (2,N,3)
    d = region_basis(regions)                                    # (R,3,3)
    g = np.einsum("kns,rms->rkmn", b, d)                         # (R,2,3,N)
    return _freeze(g.reshape(len(regions), 6, ws.n_sats))


def positions_eci_batch(ws: WalkerStar, t: np.ndarray, xp=np):
    """ECI satellite positions, shape (T, n_sats, 3): one small GEMM."""
    t = xp.atleast_1d(xp.asarray(np.asarray(t, dtype=np.float64)))
    basis = xp.asarray(constellation_basis(ws))                  # (2,N,3)
    w = ws.mean_motion
    coeff = xp.stack([xp.cos(w * t), xp.sin(w * t)], axis=-1)    # (T,2)
    pos = coeff @ basis.reshape(2, -1)                           # (T, N*3)
    return pos.reshape(len(t), ws.n_sats, 3)


def targets_eci_batch(regions: Sequence[Region], t: np.ndarray, xp=np):
    """ECI positions of rotating ground targets, shape (R, T, 3)."""
    t = xp.atleast_1d(xp.asarray(np.asarray(t, dtype=np.float64)))
    basis = xp.asarray(region_basis(regions))                    # (R,3,3)
    coeff = xp.stack([xp.cos(OMEGA_EARTH * t), xp.sin(OMEGA_EARTH * t),
                      xp.ones_like(t)], axis=-1)                 # (T,3)
    return xp.einsum("tm,rms->rts", coeff, basis)


def target_dots(ws: WalkerStar, regions: Sequence[Region], t: np.ndarray,
                xp=np):
    """Satellite-target dot products ``r_sat . r_tgt``, (R, T, n_sats).

    ``dot(r,t,n) = sum_{k,m} C(t,k) E(t,m) G(r,k,m,n)`` where C/E are the
    orbital/Earth-rotation harmonics and G contracts the two constant
    bases — i.e. one (T, 6) @ (6, N) GEMM per region.
    """
    t = xp.atleast_1d(xp.asarray(np.asarray(t, dtype=np.float64)))
    g = xp.asarray(_target_gram(ws, tuple(regions)))             # (R,6,N)
    w = ws.mean_motion
    c = xp.stack([xp.cos(w * t), xp.sin(w * t)], axis=-1)        # (T,2)
    e = xp.stack([xp.cos(OMEGA_EARTH * t), xp.sin(OMEGA_EARTH * t),
                  xp.ones_like(t)], axis=-1)                     # (T,3)
    f = (c[:, :, None] * e[:, None, :]).reshape(len(t), 6)       # (T,6)
    return f @ g                                                 # (R,T,N)


def sin_elevations(ws: WalkerStar, regions: Sequence[Region], t: np.ndarray,
                   xp=np):
    """sin(elevation) of every satellite from every region, (R, T, n_sats).

    ``sin(elev) = (dot / R_E - R_E) / |r_sat - r_tgt|`` with
    ``|r_sat - r_tgt|^2 = a^2 + R_E^2 - 2 dot`` (law of cosines).
    """
    dot = target_dots(ws, regions, t, xp)
    a = ws.semi_major
    dist = xp.sqrt(a * a + R_EARTH * R_EARTH - 2.0 * dot)
    return (dot / R_EARTH - R_EARTH) / dist


def coverage_dot_threshold(ws: WalkerStar, min_elevation_deg: float) -> float:
    """Dot-product threshold equivalent to the elevation mask.

    Elevation >= e  <=>  central angle <= psi_max  <=>
    ``r_sat . r_tgt >= a R cos(psi_max)`` with
    ``psi_max = acos((R/a) cos e) - e`` (law of sines in the
    Earth-center / target / satellite triangle).
    """
    e = np.deg2rad(min_elevation_deg)
    a = ws.semi_major
    psi_max = np.arccos(R_EARTH / a * np.cos(e)) - e
    return float(a * R_EARTH * np.cos(psi_max))


def visibility(ws: WalkerStar, regions: Sequence[Region], t: np.ndarray,
               backend: str = "auto") -> np.ndarray:
    """Boolean visibility mask, (R, T, n_sats), as a NumPy array."""
    xp = resolve_backend(backend)
    dot = target_dots(ws, regions, t, xp)
    thresh = xp.asarray([coverage_dot_threshold(ws, r.min_elevation_deg)
                         for r in regions])
    return np.asarray(dot >= thresh[:, None, None])


# ---------------------------------------------------------------------------
# Vectorized interval extraction ---------------------------------------------
# ---------------------------------------------------------------------------
def _require_x64_for_intervals(xp) -> None:
    """Interval extraction on the jax backend demands float64: without
    x64 every ``xp.asarray(..., float64)`` silently downcasts to float32
    and coverage-window boundaries shift by a ``dt`` sample depending on
    the host.  Fail loudly instead."""
    if xp is np:
        return
    import jax
    if not jax.config.jax_enable_x64:
        raise ValueError(
            "access_intervals_multi with the jax backend requires "
            "float64: call jax.config.update('jax_enable_x64', True) "
            "before propagation, or use backend='numpy' (the default). "
            "Without x64, visibility is computed in float32 and interval "
            "boundaries silently shift by one dt sample.")


def intervals_from_visibility(visible: np.ndarray,
                              t: np.ndarray) -> List[AccessInterval]:
    """Extract coverage windows from a (T, n_sats) visibility mask.

    One padded diff over the whole mask replaces the seed's per-satellite
    loop; boundary conventions match the seed exactly (interval end is
    the first non-visible sample, clamped to ``t[-1]`` for windows still
    open at the horizon), including the (start, sat) ordering.
    """
    v = np.asarray(visible, dtype=bool)
    if not v.any():
        # all-invisible mask (tight elevation mask, polar region, short
        # horizon): skip the diff + double lexsort entirely
        return []
    T, N = v.shape
    pad = np.zeros((1, N), dtype=np.int8)
    d = np.diff(v.astype(np.int8), axis=0, prepend=pad, append=pad)
    start_t, start_s = np.nonzero(d == 1)     # first visible sample index
    end_t, end_s = np.nonzero(d == -1)        # first non-visible sample index
    # pair rises with falls per satellite (lexsort: time within satellite)
    so = np.lexsort((start_t, start_s))
    eo = np.lexsort((end_t, end_s))
    start_t, start_s = start_t[so], start_s[so]
    end_t = np.minimum(end_t[eo], T - 1)      # horizon-open windows
    out = [AccessInterval(sat=int(s), start=float(t[a]), end=float(t[b]))
           for s, a, b in zip(start_s, start_t, end_t)]
    out.sort(key=lambda iv: iv.start)         # stable: ties stay sat-ascending
    return out


def access_intervals_multi(ws: WalkerStar, regions: Sequence[Region],
                           t_end: float = 6 * 3600.0, dt: float = 10.0,
                           backend: str = "numpy"
                           ) -> Dict[str, List[AccessInterval]]:
    """Coverage windows for every region from ONE shared propagation pass.

    Defaults to the NumPy backend: interval boundaries are
    precision-critical control-plane state, and jax without x64 computes
    visibility in float32, which can shift a boundary by one ``dt``
    sample depending on the host.  Pass ``backend="jax"``/``"auto"`` to
    opt in to accelerator-resident visibility — that path REQUIRES x64
    (``jax.config.update("jax_enable_x64", True)``) and raises a clear
    error otherwise, instead of silently shifting boundaries.
    """
    _require_x64_for_intervals(resolve_backend(backend))
    t = np.arange(0.0, t_end, dt)
    vis = visibility(ws, regions, t, backend=backend)            # (R,T,N)
    return {r.name: intervals_from_visibility(vis[i], t)
            for i, r in enumerate(regions)}


def access_intervals_vec(ws: WalkerStar, lat_deg: float = 40.0,
                         lon_deg: float = -86.0, t_end: float = 6 * 3600.0,
                         dt: float = 10.0, min_elevation_deg: float = 15.0,
                         backend: str = "numpy") -> List[AccessInterval]:
    """Single-region entry point with the seed ``access_intervals`` API."""
    region = Region("target", lat_deg, lon_deg, min_elevation_deg)
    return access_intervals_multi(ws, [region], t_end=t_end, dt=dt,
                                  backend=backend)["target"]


# ---------------------------------------------------------------------------
# Seed reference implementation (per-satellite Python loop) ------------------
# ---------------------------------------------------------------------------
def access_intervals_loop(ws: WalkerStar, lat_deg: float = 40.0,
                          lon_deg: float = -86.0, t_end: float = 6 * 3600.0,
                          dt: float = 10.0,
                          min_elevation_deg: float = 15.0
                          ) -> List[AccessInterval]:
    """The seed's per-satellite loop, preserved verbatim as the reference
    for equivalence tests and the ``benchmarks/sim_scale.py`` baseline."""
    t = np.arange(0.0, t_end, dt)
    elev = elevation_angles(ws, lat_deg, lon_deg, t)
    visible = elev >= np.deg2rad(min_elevation_deg)
    out: List[AccessInterval] = []
    for s in range(ws.n_sats):
        v = visible[:, s]
        if not v.any():
            continue
        starts = list(np.flatnonzero(v[1:] & ~v[:-1]) + 1)
        ends = list(np.flatnonzero(~v[1:] & v[:-1]) + 1)
        if v[0]:
            starts = [0] + starts
        if v[-1]:
            ends = ends + [len(t) - 1]
        for i0, i1 in zip(starts, ends):
            out.append(AccessInterval(sat=s, start=float(t[i0]),
                                      end=float(t[i1])))
    out.sort(key=lambda iv: iv.start)
    return out
