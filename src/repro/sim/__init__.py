"""Vectorized SAGIN dynamics simulator: propagation, stochastic network
events, and the event-stepped multi-region engine (network-only or full
hierarchical FL with cross-region merging)."""
from .dynamics import DynamicsConfig, NetworkDynamics, RoundEvents
from .engine import (MergeEvent, RegionTrace, SAGINEngine, region_seed,
                     region_streams, run_fl_all_regions)
from .propagation import (Region, access_intervals_loop,
                          access_intervals_multi, access_intervals_vec,
                          coverage_dot_threshold, positions_eci_batch,
                          sin_elevations, targets_eci_batch, visibility)

__all__ = ["DynamicsConfig", "NetworkDynamics", "RoundEvents", "MergeEvent",
           "RegionTrace", "SAGINEngine", "region_seed", "region_streams",
           "run_fl_all_regions", "Region", "access_intervals_loop",
           "access_intervals_multi", "access_intervals_vec",
           "coverage_dot_threshold", "positions_eci_batch",
           "sin_elevations", "targets_eci_batch", "visibility"]
