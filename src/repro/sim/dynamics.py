"""Stochastic SAGIN network dynamics: outages, weather, jitter, churn.

The seed's round model is purely analytic and deterministic; this module
adds the event processes that make a scenario *dynamic*:

* **ISL outages** — with probability ``isl_outage_prob`` per round the
  inter-satellite link degrades to ``isl_outage_scale`` of its nominal
  rate (rain fade / pointing loss on the optical/Ka link), stretching
  every handover in that round.
* **Uplink outages** — per cluster, the air->space uplink suffers a
  dead-air window of ``uplink_outage_delay`` seconds with probability
  ``uplink_outage_prob`` (blockage, beam re-acquisition).
* **Weather attenuation** — a lognormal multiplicative factor with
  sigma ``weather_std`` on all ground/air channel rates for the round.
* **Satellite compute jitter** — lognormal factor with sigma
  ``sat_freq_jitter_std`` on each serving satellite's CPU frequency
  (thermal throttling, shared payloads).  Unlike the other processes
  this one is *observable*: the orchestrator refreshes satellite state
  every round anyway, so the planner sees the jittered frequency.
* **Device churn** — each ground device is offline for the round with
  probability ``churn_prob``; offline devices neither move data nor
  train.

**Bursty (Markov) outages** — the i.i.d. per-round draws above cannot
model the *correlated* failure bursts real optical ISLs and Ka uplinks
exhibit (a pointing loss persists across rounds; rain cells last
minutes).  Setting ``isl_markov=(p_fail, p_recover)`` (and/or
``uplink_markov``) replaces the corresponding i.i.d. draw with a
2-state Gilbert–Elliott chain per link: a *good* link fails with
``p_fail`` per round, a *bad* link recovers with ``p_recover``, giving
a stationary outage fraction ``p_fail / (p_fail + p_recover)`` and
mean burst length ``1 / p_recover`` rounds.  Exactly ONE uniform is
drawn per link per round regardless of state, so trajectories stay
deterministic under identical seeds and the draw count never depends
on the realized states.  The chain state is mutable run state — it is
part of :meth:`NetworkDynamics.state_dict` so engine checkpoints
resume mid-burst bit-identically.

Every process draws from one explicit :class:`numpy.random.Generator`
threaded through the constructor — identical seeds give identical
multi-round event trajectories, and the engine derives independent
per-region streams with :meth:`NetworkDynamics.spawn`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


def _validate_markov(name: str, pair) -> None:
    if pair is None:
        return
    if len(pair) != 2:
        raise ValueError(f"{name} must be a (p_fail, p_recover) pair, "
                         f"got {pair!r}")
    p_fail, p_recover = pair
    if not (0.0 <= p_fail <= 1.0 and 0.0 < p_recover <= 1.0):
        raise ValueError(f"{name}=(p_fail={p_fail}, p_recover={p_recover}) "
                         f"needs p_fail in [0, 1] and p_recover in (0, 1]")


@dataclasses.dataclass(frozen=True)
class DynamicsConfig:
    """Per-round event-process rates; all zero means static (seed) behavior."""
    isl_outage_prob: float = 0.0
    isl_outage_scale: float = 0.25      # z_isl multiplier while degraded
    uplink_outage_prob: float = 0.0     # per cluster, per round
    uplink_outage_delay: float = 20.0   # seconds of dead air per outage
    weather_std: float = 0.0            # lognormal sigma on channel rates
    sat_freq_jitter_std: float = 0.0    # lognormal sigma on satellite f
    churn_prob: float = 0.0             # per ground device, per round
    # Gilbert–Elliott bursty outages: (p_fail, p_recover) per round.
    # When set, the chain REPLACES the corresponding i.i.d. draw above
    # (the iid prob is ignored for that link class).
    isl_markov: Optional[Tuple[float, float]] = None
    uplink_markov: Optional[Tuple[float, float]] = None

    def __post_init__(self):
        _validate_markov("isl_markov", self.isl_markov)
        _validate_markov("uplink_markov", self.uplink_markov)

    def any_active(self) -> bool:
        return (self.isl_outage_prob > 0 or self.uplink_outage_prob > 0
                or self.weather_std > 0 or self.sat_freq_jitter_std > 0
                or self.churn_prob > 0 or self.isl_markov is not None
                or self.uplink_markov is not None)


@dataclasses.dataclass
class RoundEvents:
    """Realized events for one global round."""
    round_index: int
    sat_freq_scale: np.ndarray          # (n_sats,) observable at planning
    isl_scale: float = 1.0              # z_isl multiplier (<1 during outage)
    rate_scale: float = 1.0             # weather multiplier on channel rates
    uplink_delays: Dict[int, float] = dataclasses.field(default_factory=dict)
    offline_devices: Tuple[int, ...] = ()

    @property
    def quiet(self) -> bool:
        """True when no *unobservable* perturbation realized this round.

        Satellite compute jitter is deliberately excluded: it is applied
        to the satellites before planning, so the plan already prices it
        and re-pricing the round would return the analytic latency.
        """
        return (self.isl_scale == 1.0 and self.rate_scale == 1.0
                and not self.uplink_delays and not self.offline_devices)


class NetworkDynamics:
    """Samples :class:`RoundEvents` from an explicit, threaded RNG.

    ``tracer`` is the run's :class:`repro.obs.Tracer` (attached by
    ``RegionTrainer``/``SAGINEngine``; the shared null tracer by
    default): every realized *unobservable* perturbation is emitted as
    an ``outage`` event against the tracer's current region/round
    context.  Emission happens AFTER all draws — tracing never touches
    the RNG stream, so trajectories are identical with obs on or off.
    """

    def __init__(self, config: DynamicsConfig,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        from repro.obs import NULL_TRACER
        self.config = config
        self.rng = rng if rng is not None else np.random.default_rng(seed)
        self.tracer = NULL_TRACER
        # Gilbert–Elliott chain states (mutable run state; checkpointed)
        self._isl_bad = False
        self._uplink_bad: Optional[np.ndarray] = None  # (n_clusters,) bool

    def spawn(self) -> "NetworkDynamics":
        """Independent child stream (one per region in the engine)."""
        child = NetworkDynamics(self.config, rng=self.rng.spawn(1)[0])
        child.tracer = self.tracer
        return child

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable run state: RNG stream + burst-chain states."""
        return {
            "rng": self.rng.bit_generator.state,
            "isl_bad": bool(self._isl_bad),
            "uplink_bad": (None if self._uplink_bad is None
                           else [bool(b) for b in self._uplink_bad]),
        }

    def load_state_dict(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._isl_bad = bool(state["isl_bad"])
        ub = state.get("uplink_bad")
        self._uplink_bad = (None if ub is None
                            else np.asarray(ub, dtype=bool))

    # -- burst chains --------------------------------------------------------
    @staticmethod
    def _ge_step(bad, u, p_fail: float, p_recover: float):
        """One Gilbert–Elliott transition from ONE uniform per link.

        Good links fail when ``u < p_fail``; bad links recover when
        ``u < p_recover``.  Works elementwise on arrays.
        """
        return np.where(bad, u >= p_recover, u < p_fail)

    def sample_round(self, r: int, n_sats: int, n_clusters: int,
                     n_devices: int) -> RoundEvents:
        cfg = self.config
        rng = self.rng
        ev = RoundEvents(round_index=r, sat_freq_scale=np.ones(n_sats))
        if cfg.sat_freq_jitter_std > 0:
            ev.sat_freq_scale = rng.lognormal(
                mean=-0.5 * cfg.sat_freq_jitter_std ** 2,
                sigma=cfg.sat_freq_jitter_std, size=n_sats)
        if cfg.isl_markov is not None:
            # one uniform per round regardless of chain state: the draw
            # count (hence every downstream draw) is state-independent
            self._isl_bad = bool(self._ge_step(self._isl_bad, rng.random(),
                                               *cfg.isl_markov))
            if self._isl_bad:
                ev.isl_scale = cfg.isl_outage_scale
        elif cfg.isl_outage_prob > 0 and rng.random() < cfg.isl_outage_prob:
            ev.isl_scale = cfg.isl_outage_scale
        if cfg.weather_std > 0:
            ev.rate_scale = float(rng.lognormal(
                mean=-0.5 * cfg.weather_std ** 2, sigma=cfg.weather_std))
        if cfg.uplink_markov is not None:
            if self._uplink_bad is None or len(self._uplink_bad) != n_clusters:
                self._uplink_bad = np.zeros(n_clusters, dtype=bool)
            self._uplink_bad = self._ge_step(
                self._uplink_bad, rng.random(n_clusters), *cfg.uplink_markov)
            ev.uplink_delays = {int(n): cfg.uplink_outage_delay
                                for n in np.flatnonzero(self._uplink_bad)}
        elif cfg.uplink_outage_prob > 0:
            hit = rng.random(n_clusters) < cfg.uplink_outage_prob
            ev.uplink_delays = {int(n): cfg.uplink_outage_delay
                                for n in np.flatnonzero(hit)}
        if cfg.churn_prob > 0:
            off = rng.random(n_devices) < cfg.churn_prob
            ev.offline_devices = tuple(int(k) for k in np.flatnonzero(off))
        tr = self.tracer
        if tr.enabled:
            m = tr.metrics
            if ev.isl_scale != 1.0:
                tr.event("outage", "isl_fade", event="isl",
                         scale=ev.isl_scale,
                         bursty=cfg.isl_markov is not None)
                m.counter("outage.isl").inc()
            for n, d in sorted(ev.uplink_delays.items()):
                tr.event("outage", f"uplink_c{n}", event="uplink",
                         cluster=n, delay=d,
                         bursty=cfg.uplink_markov is not None)
                m.counter("outage.uplink").inc()
            if ev.offline_devices:
                tr.event("outage", "device_churn", event="churn",
                         devices=list(ev.offline_devices))
                m.counter("outage.churned_devices").inc(
                    len(ev.offline_devices))
        return ev
