"""Event-stepped multi-region SAGIN simulator and hierarchical FL driver.

Drives one :class:`~repro.core.scheduler.SAGINOrchestrator` (or, in FL
mode, one :class:`~repro.fl.rounds.RegionTrainer`) per region over a
*shared* constellation: coverage windows for every region come from a
single batched propagation pass
(:func:`repro.sim.propagation.access_intervals_multi`), and regions
advance through an event queue ordered by their wall clocks — the
region whose next round starts earliest steps first, exactly as a
gateway scheduler multiplexing one constellation across independent FL
jobs would interleave them.

**FL mode** (pass ``fl=FLConfig(...)``) replaces the bare orchestrators
with full per-region trainers, so the engine event-steps *actual
federated training*.  Cross-region merging is delegated to a pluggable
federation policy (:mod:`repro.fl.federation`), resolved from
``FLConfig.federation`` or ``Scenario.federation`` (the deprecated
``Scenario.merge_*`` fields map to the ``synchronous`` policy): at each
merge boundary the engine EMITS a
:class:`~repro.fl.federation.FederationState` (per-region clock/model
age, data mass, live ISL state from ``sim.dynamics``) and executes
whatever :class:`~repro.fl.federation.MergePlan` the policy returns —
who participates with what staleness-discounted weight, who receives
the merged model, and what each recipient's ISL toll is.  Barrier
policies (``synchronous``, ``partial``, ``elected_hub``) park arriving
regions until all have arrived; asynchronous policies (``soft_async``)
plan at each region's own boundary with no parking.  The engine knows
no merge semantics beyond that.

Randomness is fully threaded and *region-addressable*: region ``i``'s
orchestrator/dynamics streams are rooted at
``region_seed(seed, i) = seed + 1000 * i`` (see :func:`region_streams`),
the exact derivation :func:`repro.fl.rounds.run_fl` applies for
``FLConfig(scenario=..., region_index=i)`` — a single-region FL job and
engine region ``i`` draw identical outage/churn/satellite-CPU streams
at equal seeds, and identical seeds give identical multi-region
trajectories regardless of interleaving.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple, TYPE_CHECKING

import numpy as np

from repro.core.network import build_default_sagin
from repro.core.scheduler import RoundRecord, SAGINOrchestrator
from repro.sim.dynamics import DynamicsConfig, NetworkDynamics
from repro.sim.propagation import Region

if TYPE_CHECKING:  # pragma: no cover - circular-import guard
    from repro.fl.rounds import FLConfig, FLResult, RegionTrainer
    from repro.scenarios.registry import Scenario


def region_seed(seed: int, region_index: int) -> int:
    """Root seed of region ``region_index``'s RNG streams.

    The fold is by construction independent of how many regions a
    scenario declares, so a single-region ``run_fl`` job can reproduce
    any engine region's draws without replaying the regions before it.
    """
    return seed + 1000 * region_index


def region_streams(seed: int, region_index: int,
                   dynamics_cfg: Optional[DynamicsConfig] = None
                   ) -> Tuple[np.random.Generator,
                              Optional[NetworkDynamics]]:
    """Canonical per-region ``(orchestrator_rng, dynamics)`` derivation.

    This is the ONE place the engine and :func:`repro.fl.rounds.run_fl`
    agree on how region ``i``'s streams descend from a root seed: the
    orchestrator draws (satellite CPU frequencies) come from the root
    stream of ``region_seed(seed, i)`` and the dynamics events
    (outages/weather/churn) from its first spawned child — the same
    parent/child split the seed orchestrator used for a single region.
    """
    rseed = region_seed(seed, region_index)
    rng = np.random.default_rng(rseed)
    dynamics = None
    if dynamics_cfg is not None:
        dynamics = NetworkDynamics(
            dynamics_cfg, rng=np.random.default_rng(rseed).spawn(1)[0])
    return rng, dynamics


@dataclasses.dataclass
class RegionTrace:
    """Per-region outcome of an engine run."""
    region: Region
    records: List[RoundRecord] = dataclasses.field(default_factory=list)

    @property
    def wall_clock(self) -> float:
        return (self.records[-1].wall_clock_start
                + self.records[-1].realized_latency) if self.records else 0.0

    @property
    def latencies(self) -> List[float]:
        return [r.latency for r in self.records]

    @property
    def realized_latencies(self) -> List[float]:
        return [r.realized_latency for r in self.records]


@dataclasses.dataclass(frozen=True)
class MergeEvent:
    """One policy-planned merge across regions over the ISLs.

    The per-region tuples span ALL regions: a region that sat the merge
    out carries weight/staleness/cost 0 and accuracy NaN (accuracies are
    evaluated on recipients only).  ``participants``/``recipients``/
    ``hub`` record the realized :class:`~repro.fl.federation.MergePlan`.
    """
    barrier_round: int            # regions had completed this many rounds
    time: float                   # merge wall-clock instant
    staleness: Tuple[float, ...]  # per-region model age at merge (s)
    weights: Tuple[float, ...]    # realized merge weights (sum to 1)
    isl_costs: Tuple[float, ...]  # per-region ISL price (s)
    accuracies: Tuple[float, ...]  # merged model on recipients' eval sets
    policy: str = "synchronous"   # federation policy that planned it
    hub: int = 0                  # aggregating region (its satellite)
    participants: Tuple[int, ...] = ()
    recipients: Tuple[int, ...] = ()


class SAGINEngine:
    """Multi-region simulator over one shared constellation.

    Without ``fl`` the engine steps bare orchestrators (network-only
    simulation, as in PR 2).  With ``fl=FLConfig(...)`` it builds one
    :class:`~repro.fl.rounds.RegionTrainer` per region (``fl.seed``
    governs all streams; the ``seed``/``n_devices``/``n_air`` arguments
    are ignored in favor of the FLConfig) and :meth:`run` performs
    event-stepped federated training with optional global merges.
    """

    def __init__(self, scenario: "Scenario | str", seed: int = 0,
                 n_devices: Optional[int] = None,
                 n_air: Optional[int] = None,
                 backend: str = "numpy",
                 fl: Optional["FLConfig"] = None):
        if isinstance(scenario, str):
            from repro.scenarios.registry import get_scenario
            scenario = get_scenario(scenario)
        self.scenario = scenario
        self.constellation = scenario.build_constellation()
        self.intervals = scenario.build_intervals(backend=backend)
        self.fl_config = fl
        # ONE tracer for the whole run, shared by every region trainer
        # (FLConfig.obs wins over Scenario.obs); repro.obs.NULL_TRACER
        # when neither is set — every hook below is then a single branch
        from repro.obs import resolve_obs
        self.tracer = resolve_obs(
            fl.obs if fl is not None and fl.obs is not None
            else scenario.obs)
        self.trainers: List["RegionTrainer"] = []
        self.merges: List[MergeEvent] = []
        self.global_params = None
        self.federation = None
        self.fault_injector = None
        self.step_order: List[Tuple[int, int]] = []  # (region, round) pops
        self.traces: List[RegionTrace] = [RegionTrace(region=r)
                                          for r in scenario.regions]
        self.orchestrators: List[SAGINOrchestrator] = []
        if fl is not None:
            from repro.fl.federation import resolve_federation
            from repro.fl.rounds import RegionTrainer
            self.federation = resolve_federation(fl.federation, scenario)
            for i, region in enumerate(scenario.regions):
                cfg_i = dataclasses.replace(fl, scenario=scenario.name,
                                            region_index=i)
                self.trainers.append(RegionTrainer(
                    cfg_i, scenario=scenario,
                    intervals=self.intervals[region.name],
                    tracer=self.tracer))
            if scenario.faults is not None:
                # ONE injector shared by the merge path and every
                # trainer: counts aggregate run-wide (repro.resilience)
                from repro.resilience import FaultInjector
                self.fault_injector = FaultInjector(scenario.faults,
                                                    tracer=self.tracer)
                for t in self.trainers:
                    t.faults = self.fault_injector
            return
        nd = n_devices if n_devices is not None else scenario.n_devices
        na = n_air if n_air is not None else scenario.n_air
        for i, region in enumerate(scenario.regions):
            rng, dynamics = region_streams(seed, i, scenario.dynamics)
            sagin = build_default_sagin(
                n_devices=nd, n_air=na,
                samples_per_device=scenario.samples_per_device,
                alpha=scenario.alpha, seed=region_seed(seed, i))
            if dynamics is not None:
                dynamics.tracer = self.tracer
            self.orchestrators.append(SAGINOrchestrator(
                sagin, intervals=self.intervals[region.name], rng=rng,
                dynamics=dynamics, strategy=scenario.strategy))

    # -- event loop ---------------------------------------------------------
    def run(self, n_rounds: int,
            final_merge: bool = True) -> List[RegionTrace]:
        """Advance every region by ``n_rounds`` MORE, event-stepped: at
        each step the region with the earliest wall clock executes its
        next round (ties broken by region index for determinism; the pop
        sequence is recorded in ``self.step_order``).  In FL mode with a
        merge cadence, the federation policy additionally plans merges
        at round boundaries (see :meth:`_policy_merge`).

        ``run`` CONTINUES from wherever the engine stands (fresh
        engines stand at round 0), so ``run(5); run(5)`` and the
        checkpoint/resume path (``repro.checkpoint.engine``) replay
        ``run(10)`` exactly — provided the first segment passes
        ``final_merge=False`` to suppress the forced off-cadence merge
        at its own last round (an artifact of treating the segment end
        as the end of training).  Cadence-aligned merges key on the
        GLOBAL round index either way.
        """
        if self.trainers:
            return self._run_fl(n_rounds, final_merge)
        self.step_order = []
        if n_rounds <= 0:
            return self.traces
        heap, ends = [], []
        for i, orch in enumerate(self.orchestrators):
            start = len(self.traces[i].records)
            ends.append(start + n_rounds)
            heap.append((orch.wall_clock, i, start))
        heapq.heapify(heap)
        tr = self.tracer
        while heap:
            _, i, r = heapq.heappop(heap)
            self.step_order.append((i, r))
            orch = self.orchestrators[i]
            name = self.scenario.regions[i].name
            if tr.enabled:
                tr.set_context(region=name, round=r, t_sim=orch.wall_clock)
            rec = orch.step(r)
            self.traces[i].records.append(rec)
            if tr.enabled:
                tr.span("round", f"{name}/r{r}", t_sim=rec.wall_clock_start,
                        dur_sim=rec.realized_latency, case=rec.plan.case,
                        latency_analytic=rec.latency,
                        n_handovers=rec.schedule.n_handovers)
            if r + 1 < ends[i]:
                heapq.heappush(heap, (orch.wall_clock, i, r + 1))
        tr.flush()
        return self.traces

    def _run_fl(self, n_rounds: int,
                final_merge: bool = True) -> List[RegionTrace]:
        """FL mode: event-step the region trainers; at merge boundaries
        consult the federation policy — barrier policies park regions
        until all arrive, asynchronous policies plan per trigger."""
        fed = self.federation
        policy = None
        if fed is not None and fed.every is not None:
            from repro.fl.federation import get_policy
            policy = get_policy(fed)
        self.step_order = []
        if n_rounds <= 0:
            return self.traces
        starts = {len(t.result.times) for t in self.trainers}
        if len(starts) != 1:
            raise ValueError(f"cannot continue an FL run whose regions "
                             f"stand at unequal round counts: "
                             f"{sorted(starts)}")
        start = starts.pop()
        end = start + n_rounds
        heap = [(t.wall_clock, i, start)
                for i, t in enumerate(self.trainers)]
        heapq.heapify(heap)
        waiting: List[Tuple[int, int]] = []  # (region, next_round) parked
        while heap:
            _, i, r = heapq.heappop(heap)
            self.step_order.append((i, r))
            trainer = self.trainers[i]
            self.traces[i].records.append(trainer.step(r))
            nxt = r + 1
            at_boundary = (policy is not None
                           and (nxt % fed.every == 0
                                or (final_merge and nxt == end)))
            if at_boundary and policy.requires_barrier:
                waiting.append((i, nxt))
                if len(waiting) == len(self.trainers):
                    self._policy_merge(policy, nxt)
                    for j, nr in waiting:
                        if nr < end:
                            heapq.heappush(
                                heap, (self.trainers[j].wall_clock, j, nr))
                    waiting = []
            else:
                if at_boundary:  # asynchronous boundary: no parking
                    self._policy_merge(policy, nxt, trigger=i)
                if nxt < end:
                    heapq.heappush(heap, (trainer.wall_clock, i, nxt))
        if policy is None and self.trainers:
            # no merging: the "global" model is undefined; expose None so
            # callers can tell one-global-model runs from independent ones
            self.global_params = None
        self.tracer.flush()
        return self.traces

    def federation_state(self, barrier_round: int,
                         trigger: Optional[int] = None):
        """Emit the :class:`~repro.fl.federation.FederationState` a
        policy plans from: one snapshot per region (clock, data mass,
        model payload, realized ISL state) plus the boundary context."""
        from repro.fl.federation import FederationState
        return FederationState(
            config=self.federation,
            regions=tuple(t.federation_snapshot(i)
                          for i, t in enumerate(self.trainers)),
            barrier_round=barrier_round, trigger=trigger)

    def _policy_merge(self, policy, barrier_round: int,
                      trigger: Optional[int] = None):
        """Plan one merge with the federation policy and execute it:
        aggregate the participants' models, evaluate on and install to
        the plan's recipients (clock := merge time + ISL toll), and
        record the realized :class:`MergeEvent`.  A ``None`` plan skips
        the merge — no models move, no clocks change."""
        from repro.fl.client import evaluate

        trainers = self.trainers
        tr = self.tracer
        state = self.federation_state(barrier_round, trigger)
        if tr.enabled:
            for rs in state.regions:
                tr.metrics.gauge(
                    f"federation.isl_scale.{rs.name}").set(rs.isl_scale)
        inj = self.fault_injector
        partitioned = (inj.partition_at(barrier_round)
                       if inj is not None else ())
        if partitioned:
            # injected merge-time ISL partition: retry with capped
            # backoff, then degrade to the partial-quorum plan
            from repro.fl.federation import plan_under_partition
            inj.record_injected("isl_partition",
                                regions=list(partitioned),
                                barrier_round=barrier_round)
            plan, delay = plan_under_partition(policy, state, partitioned)
            if plan is not None:
                inj.record_recovered("isl_partition", policy=plan.policy,
                                     delay_s=delay)
        else:
            plan = policy.plan(state)
        if plan is None:
            # a skipped boundary (quorum miss, nothing to do) is itself
            # an observable event — the report CLI surfaces these
            if tr.enabled:
                from repro.obs import FEDERATION_TRACK
                tr.span("merge", f"{self.federation.policy}@r{barrier_round}"
                        f" skipped", region=FEDERATION_TRACK,
                        round=barrier_round,
                        t_sim=max(t.wall_clock for t in trainers),
                        skipped=True, policy=self.federation.policy,
                        trigger=trigger)
                tr.metrics.counter("merge.skipped").inc()
            return
        merged = policy.apply([trainers[j].params
                               for j in plan.participants], plan)
        n = len(trainers)
        weights = [0.0] * n
        staleness = [0.0] * n
        costs = [0.0] * n
        accs = [float("nan")] * n
        for j, w, s in zip(plan.participants, plan.weights, plan.staleness):
            weights[j] = float(w)
            staleness[j] = float(s)
        for j, cost in zip(plan.recipients, plan.isl_costs):
            t = trainers[j]
            costs[j] = float(cost)
            _, acc = evaluate(t.apply_fn, merged, t.x_eval, t.y_eval)
            accs[j] = float(acc)
            # every recipient receives the SAME merged pytree; a trainer
            # whose cohort engine donates buffers copies it privately
            # inside install_global before its next round can consume it
            t.install_global(merged, plan.time + cost)
        self.global_params = merged
        if tr.enabled:
            from repro.obs import FEDERATION_TRACK
            quorum_miss = len(plan.participants) < len(trainers)
            tr.span("merge", f"{plan.policy}@r{barrier_round}",
                    region=FEDERATION_TRACK, round=barrier_round,
                    t_sim=plan.time, dur_sim=max(costs, default=0.0),
                    policy=plan.policy, hub=plan.hub,
                    participants=list(plan.participants),
                    recipients=list(plan.recipients),
                    recipient_names=[trainers[j]._region_name
                                     for j in plan.recipients],
                    weights=weights, staleness=staleness,
                    # recipient-aligned (skips the NaN accuracy sentinel
                    # of non-recipients: NaN is not valid strict JSON)
                    isl_costs=[costs[j] for j in plan.recipients],
                    accuracies=[accs[j] for j in plan.recipients],
                    quorum_miss=quorum_miss, trigger=trigger)
            m = tr.metrics
            m.counter("merge.count").inc()
            if quorum_miss:
                m.counter("merge.quorum_miss").inc()
            for j, s in zip(plan.participants, plan.staleness):
                m.histogram("merge.staleness_s").observe(float(s))
            for cost in plan.isl_costs:
                m.histogram("merge.isl_cost_s").observe(float(cost))
        self.merges.append(MergeEvent(
            barrier_round=barrier_round, time=plan.time,
            staleness=tuple(staleness), weights=tuple(weights),
            isl_costs=tuple(costs), accuracies=tuple(accs),
            policy=plan.policy, hub=plan.hub,
            participants=tuple(plan.participants),
            recipients=tuple(plan.recipients)))

    # -- results ------------------------------------------------------------
    @property
    def fl_results(self) -> Dict[str, "FLResult"]:
        """FL mode: per-region training curves, keyed by region name."""
        return {t.region.name: tr.result
                for t, tr in zip(self.traces, self.trainers)}

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-region headline numbers for reports and benchmarks."""
        out = {}
        for trace in self.traces:
            lats = trace.realized_latencies
            out[trace.region.name] = {
                "rounds": float(len(trace.records)),
                "wall_clock": trace.wall_clock,
                "mean_latency": float(np.mean(lats)) if lats else 0.0,
                "mean_overhead": (float(np.mean(
                    [r.realized_latency - r.latency
                     for r in trace.records])) if lats else 0.0),
            }
        return out


def run_fl_all_regions(cfg, scenario: "Scenario | str"):
    """Train one INDEPENDENT FL model per scenario region via ``run_fl``.

    Returns ``{region_name: FLResult}``; each region's result carries the
    realized (dynamics-priced) latencies in its time axis.  Region ``i``
    runs with ``region_index=i`` under the shared root ``cfg.seed``, so
    its data draw and orchestrator/dynamics streams are exactly the ones
    ``SAGINEngine`` region ``i`` sees (``region_seed`` fold) — use
    ``SAGINEngine(scenario, fl=cfg)`` instead when the scenario merges
    regions into one global model.
    """
    import dataclasses as _dc

    from repro.fl.rounds import run_fl
    from repro.scenarios.registry import SCENARIOS, get_scenario, register
    transient = None
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    elif SCENARIOS.get(scenario.name) is not scenario:
        # run_fl resolves by name, so an ad-hoc Scenario must be
        # reachable through the registry for the duration of this call;
        # uniquify on collision (e.g. a replace()d preset keeping its
        # name) and always unregister on the way out
        if scenario.name in SCENARIOS:
            scenario = _dc.replace(scenario,
                                   name=f"{scenario.name}@{id(scenario):x}")
        register(scenario)
        transient = scenario.name
    # one shared tracer across the per-region jobs (each run_fl building
    # its own from cfg.obs would overwrite the same trace file N times)
    from repro.obs import resolve_obs
    tracer = resolve_obs(cfg.obs if cfg.obs is not None else scenario.obs)
    out = {}
    try:
        for i, region in enumerate(scenario.regions):
            region_cfg = _dc.replace(cfg, scenario=scenario.name,
                                     region_index=i)
            out[region.name] = run_fl(region_cfg, tracer=tracer)
    finally:
        if transient is not None:
            SCENARIOS.pop(transient, None)
    tracer.flush()
    return out
