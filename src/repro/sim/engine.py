"""Event-stepped multi-region SAGIN simulator.

Drives one :class:`~repro.core.scheduler.SAGINOrchestrator` per region
over a *shared* constellation: coverage windows for every region come
from a single batched propagation pass
(:func:`repro.sim.propagation.access_intervals_multi`), and regions
advance through an event queue ordered by their wall clocks — the
region whose next round starts earliest steps first, exactly as a
gateway scheduler multiplexing one constellation across independent FL
jobs would interleave them.

Randomness is fully threaded: one root ``numpy.random.Generator`` is
spawned into independent per-region streams (satellite CPU draws) and
per-region dynamics streams (outages/weather/churn), so identical seeds
give identical multi-region trajectories regardless of interleaving.

The realized (not just analytic) per-round latencies recorded here are
the same ones :func:`repro.fl.rounds.run_fl` consumes when an FLConfig
selects a scenario — see ``run_fl_all_regions`` for the convenience
wrapper that trains one FL model per region.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, TYPE_CHECKING

import numpy as np

from repro.core.network import build_default_sagin
from repro.core.scheduler import RoundRecord, SAGINOrchestrator
from repro.sim.dynamics import NetworkDynamics
from repro.sim.propagation import Region

if TYPE_CHECKING:  # pragma: no cover - scenarios imports sim.dynamics
    from repro.scenarios.registry import Scenario


@dataclasses.dataclass
class RegionTrace:
    """Per-region outcome of an engine run."""
    region: Region
    records: List[RoundRecord] = dataclasses.field(default_factory=list)

    @property
    def wall_clock(self) -> float:
        return (self.records[-1].wall_clock_start
                + self.records[-1].realized_latency) if self.records else 0.0

    @property
    def latencies(self) -> List[float]:
        return [r.latency for r in self.records]

    @property
    def realized_latencies(self) -> List[float]:
        return [r.realized_latency for r in self.records]


class SAGINEngine:
    """Multi-region simulator over one shared constellation."""

    def __init__(self, scenario: "Scenario | str", seed: int = 0,
                 n_devices: Optional[int] = None,
                 n_air: Optional[int] = None,
                 backend: str = "numpy"):
        if isinstance(scenario, str):
            from repro.scenarios.registry import get_scenario
            scenario = get_scenario(scenario)
        self.scenario = scenario
        self.constellation = scenario.build_constellation()
        self.intervals = scenario.build_intervals(backend=backend)
        nd = n_devices if n_devices is not None else scenario.n_devices
        na = n_air if n_air is not None else scenario.n_air
        root = np.random.default_rng(seed)
        root_dynamics = (NetworkDynamics(scenario.dynamics,
                                         rng=root.spawn(1)[0])
                         if scenario.dynamics is not None else None)
        self.orchestrators: List[SAGINOrchestrator] = []
        self.traces: List[RegionTrace] = []
        for i, region in enumerate(scenario.regions):
            rng = root.spawn(1)[0]
            sagin = build_default_sagin(
                n_devices=nd, n_air=na,
                samples_per_device=scenario.samples_per_device,
                alpha=scenario.alpha, seed=seed + 1000 * i)
            dynamics = (root_dynamics.spawn()
                        if root_dynamics is not None else None)
            self.orchestrators.append(SAGINOrchestrator(
                sagin, intervals=self.intervals[region.name], rng=rng,
                dynamics=dynamics, strategy=scenario.strategy))
            self.traces.append(RegionTrace(region=region))

    def run(self, n_rounds: int) -> List[RegionTrace]:
        """Advance every region by ``n_rounds``, event-stepped: at each
        step the region with the earliest wall clock executes its next
        round (ties broken by region index for determinism)."""
        heap = [(orch.wall_clock, i, 0)
                for i, orch in enumerate(self.orchestrators)]
        heapq.heapify(heap)
        while heap:
            _, i, r = heapq.heappop(heap)
            orch = self.orchestrators[i]
            self.traces[i].records.append(orch.step(r))
            if r + 1 < n_rounds:
                heapq.heappush(heap, (orch.wall_clock, i, r + 1))
        return self.traces

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-region headline numbers for reports and benchmarks."""
        out = {}
        for trace in self.traces:
            lats = trace.realized_latencies
            out[trace.region.name] = {
                "rounds": float(len(trace.records)),
                "wall_clock": trace.wall_clock,
                "mean_latency": float(np.mean(lats)) if lats else 0.0,
                "mean_overhead": (float(np.mean(
                    [r.realized_latency - r.latency
                     for r in trace.records])) if lats else 0.0),
            }
        return out


def run_fl_all_regions(cfg, scenario: "Scenario | str"):
    """Train one FL model per scenario region via ``repro.fl.run_fl``.

    Returns ``{region_name: FLResult}``; each region's result carries the
    realized (dynamics-priced) latencies in its time axis.  Each region
    gets its own seed (folded from ``cfg.seed`` and the region index) so
    data partitions, satellite draws, and dynamics streams differ across
    regions, mirroring the engine's spawned per-region streams.
    """
    import dataclasses as _dc

    from repro.fl.rounds import run_fl
    from repro.scenarios.registry import SCENARIOS, get_scenario, register
    transient = None
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    elif SCENARIOS.get(scenario.name) is not scenario:
        # run_fl resolves by name, so an ad-hoc Scenario must be
        # reachable through the registry for the duration of this call;
        # uniquify on collision (e.g. a replace()d preset keeping its
        # name) and always unregister on the way out
        if scenario.name in SCENARIOS:
            scenario = _dc.replace(scenario,
                                   name=f"{scenario.name}@{id(scenario):x}")
        register(scenario)
        transient = scenario.name
    out = {}
    try:
        for i, region in enumerate(scenario.regions):
            region_cfg = _dc.replace(cfg, scenario=scenario.name,
                                     region_index=i,
                                     seed=cfg.seed + 7919 * i)
            out[region.name] = run_fl(region_cfg)
    finally:
        if transient is not None:
            SCENARIOS.pop(transient, None)
    return out
