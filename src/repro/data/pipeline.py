"""Host-side batching pipeline for FL training and the big-model trainer."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


class BatchIterator:
    """Infinite shuffled mini-batch iterator over an index pool."""

    def __init__(self, x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.indices = np.asarray(indices)
        self.batch_size = max(1, int(batch_size))
        self._rng = np.random.default_rng(seed)
        self._order = self._rng.permutation(len(self.indices))
        self._pos = 0

    def __iter__(self) -> "BatchIterator":
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        if len(self.indices) == 0:
            raise StopIteration
        if self._pos + self.batch_size > len(self._order):
            self._order = self._rng.permutation(len(self.indices))
            self._pos = 0
        sel = self.indices[self._order[self._pos:self._pos + self.batch_size]]
        self._pos += self.batch_size
        return self.x[sel], self.y[sel]


def batch_for_local_steps(x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                          n_steps: int, rng: np.random.Generator,
                          max_batch: int = 64):
    """Split a node's pool into H mini-batches (paper: |D|/H per batch at the
    satellite; capped for memory on ground devices). Returns stacked arrays
    of shape (H, B, ...) padded by resampling when the pool is small."""
    indices = np.asarray(indices)
    if len(indices) == 0:
        return None
    b = int(np.ceil(len(indices) / n_steps))
    # paper: satellite batch = |D|/H. Cap for CPU memory, but let big pools
    # (air/satellite after offloading) use proportionally bigger batches so
    # their lambda-weighted gradients are not noise-dominated.
    eff_cap = int(np.clip(max(max_batch, len(indices) // (4 * n_steps)),
                          max_batch, 8 * max_batch))
    b = int(np.clip(b, 1, eff_cap))
    order = rng.permutation(indices)
    need = n_steps * b
    reps = int(np.ceil(need / len(order)))
    pool = np.concatenate([rng.permutation(indices) for _ in range(reps)])
    sel = pool[:need].reshape(n_steps, b)
    return x[sel], y[sel]
