"""Host-side batching pipeline for FL training and the big-model trainer.

Two entry points feed the FL round driver:

* ``batch_for_local_steps`` — per-node (H, B) batch stacks, used by the
  sequential execution path (one dispatch per node).
* ``build_cohort`` — the batched path's cohort builder: it gathers every
  data-holding node's (H, B) stack into ONE padded ``(C, H, Bmax, ...)``
  tensor plus a per-client validity mask and per-client pool sizes, so a
  single vmapped+jitted local-update step can train the whole cohort.
  Batches are drawn through ``batch_for_local_steps`` with the same RNG
  stream and call order as the sequential loop, which is what makes the
  two execution modes numerically equivalent at equal seeds.
* ``build_bucketed_cohort`` — the size-bucketed planner on top of the
  same batch draw: clients are partitioned by per-client batch width
  into geometric buckets (powers of two times ``batch_align``), each
  bucket padded only to ITS OWN width, so padded FLOPs are bounded by a
  constant factor of real FLOPs instead of growing with pool skew as
  the global-``Bmax`` layout does.  Bucket client counts are quantized
  geometrically too (powers of two, floored at ``client_align``), which
  keeps the set of compiled-step signatures tiny and drift-stable.  In
  shard-aware mode (``client_multiple`` = the mesh's ``data`` axis
  size) the client grid additionally divides evenly across mesh shards
  so buckets can dispatch through ``shard_map`` without a remainder
  shard, and a final collapse pass folds dispatch-bound small cohorts
  back into a single bucket.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Sequence, Tuple

import numpy as np


class BatchIterator:
    """Infinite shuffled mini-batch iterator over an index pool."""

    def __init__(self, x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.indices = np.asarray(indices)
        self.batch_size = max(1, int(batch_size))
        self._rng = np.random.default_rng(seed)
        self._order = self._rng.permutation(len(self.indices))
        self._pos = 0

    def __iter__(self) -> "BatchIterator":
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        if len(self.indices) == 0:
            raise StopIteration
        if self._pos + self.batch_size > len(self._order):
            self._order = self._rng.permutation(len(self.indices))
            self._pos = 0
        sel = self.indices[self._order[self._pos:self._pos + self.batch_size]]
        self._pos += self.batch_size
        return self.x[sel], self.y[sel]


def batch_width_for_pool(n_samples: int, n_steps: int,
                         max_batch: int = 64) -> int:
    """The per-step batch width B that ``batch_for_local_steps`` draws
    for a pool of ``n_samples`` (paper: |D|/H per batch at the
    satellite, capped for memory on ground devices but letting big
    post-offloading pools use proportionally bigger batches so their
    lambda-weighted gradients are not noise-dominated).  Exposed so
    planners and benchmarks can size layouts without materializing any
    batches; 0 for an empty pool."""
    if n_samples <= 0:
        return 0
    b = int(np.ceil(n_samples / n_steps))
    eff_cap = int(np.clip(max(max_batch, n_samples // (4 * n_steps)),
                          max_batch, 8 * max_batch))
    return int(np.clip(b, 1, eff_cap))


def batch_for_local_steps(x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                          n_steps: int, rng: np.random.Generator,
                          max_batch: int = 64):
    """Split a node's pool into H mini-batches (sizing rule in
    ``batch_width_for_pool``). Returns stacked arrays of shape
    (H, B, ...) padded by resampling when the pool is small."""
    indices = np.asarray(indices)
    if len(indices) == 0:
        return None
    b = batch_width_for_pool(len(indices), n_steps, max_batch)
    order = rng.permutation(indices)
    need = n_steps * b
    reps = int(np.ceil(need / len(order)))
    pool = np.concatenate([rng.permutation(indices) for _ in range(reps)])
    sel = pool[:need].reshape(n_steps, b)
    return x[sel], y[sel]


@dataclasses.dataclass
class CohortBatch:
    """A full round's worth of client batches, padded and masked.

    ``xs[c, h, :sizes-derived-B_c]`` are client ``c``'s real samples for
    local step ``h``; slots beyond that (and whole clients beyond
    ``n_clients``, when the cohort is padded to a fixed width) are zero
    and carry ``mask == 0`` so they contribute nothing to loss, gradient,
    or aggregation.
    """
    xs: np.ndarray        # (C, H, Bmax, ...) float
    ys: np.ndarray        # (C, H, Bmax) int
    mask: np.ndarray      # (C, H, Bmax) float32; 1.0 = real sample
    sizes: np.ndarray     # (C,) int pool size per client; 0 = padding client

    @property
    def n_clients(self) -> int:
        """Number of real (data-holding) clients in the cohort."""
        return int(np.sum(self.sizes > 0))

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.xs.shape


def _draw_client_batches(x: np.ndarray, y: np.ndarray,
                         pools: Sequence[np.ndarray], n_steps: int,
                         rng: np.random.Generator, max_batch: int):
    """Draw every non-empty pool's (H, B_c) batch stack in canonical pool
    order — the ONE place both cohort builders consume the round RNG, so
    bucketed, global-Bmax and sequential execution see identical samples
    at equal seeds."""
    per_client: List[Tuple[np.ndarray, np.ndarray]] = []
    sizes: List[int] = []
    for idx in pools:
        idx = np.asarray(idx)
        if len(idx) == 0:
            continue
        out = batch_for_local_steps(x, y, idx, n_steps, rng,
                                    max_batch=max_batch)
        per_client.append(out)
        sizes.append(len(idx))
    return per_client, sizes


def build_cohort(x: np.ndarray, y: np.ndarray,
                 pools: Sequence[np.ndarray], n_steps: int,
                 rng: np.random.Generator, max_batch: int = 64,
                 pad_clients: int = 0,
                 batch_align: int = 32) -> "CohortBatch | None":
    """Gather heterogeneous node pools into one (C, H, Bmax, ...) cohort.

    Each non-empty pool is batched via ``batch_for_local_steps`` (same RNG
    stream and call order as the sequential driver, so both execution
    modes see identical samples), then right-padded along the batch axis
    to a common ``Bmax``. ``Bmax`` is rounded up to a multiple of
    ``batch_align`` and the client axis is optionally padded up to
    ``pad_clients`` zero-weight dummies — both quantize the compiled
    cohort step's shapes so that pool drift only forces a recompile when
    the round's largest per-client batch crosses an alignment bucket.
    Note ``Bmax`` is global: every client is padded to the widest
    client's batch, which is wasteful when pool sizes are heavily
    skewed.
    """
    per_client, sizes = _draw_client_batches(x, y, pools, n_steps, rng,
                                             max_batch)
    if not per_client:
        return None

    b_max = max(bx.shape[1] for bx, _ in per_client)
    align = max(1, int(batch_align))
    b_max = int(np.ceil(b_max / align) * align)
    c = max(len(per_client), int(pad_clients))

    sample_shape = x.shape[1:]
    xs = np.zeros((c, n_steps, b_max) + sample_shape, dtype=x.dtype)
    ys = np.zeros((c, n_steps, b_max), dtype=y.dtype)
    mask = np.zeros((c, n_steps, b_max), dtype=np.float32)
    for ci, (bx, by) in enumerate(per_client):
        b = bx.shape[1]
        xs[ci, :, :b] = bx
        ys[ci, :, :b] = by
        mask[ci, :, :b] = 1.0
    out_sizes = np.zeros(c, dtype=np.int64)
    out_sizes[:len(sizes)] = sizes
    return CohortBatch(xs=xs, ys=ys, mask=mask, sizes=out_sizes)


# ---------------------------------------------------------------------------
# Size-bucketed cohorts ------------------------------------------------------
# ---------------------------------------------------------------------------
def next_geometric(value: int, align: int) -> int:
    """Smallest ``align * 2**k >= value`` (the geometric bucket grid)."""
    b = max(1, int(align))
    value = int(value)
    while b < value:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """One width bucket of the partition produced by :func:`plan_buckets`.

    ``members`` are positions into the canonical real-client order
    (ground 0..K-1, air, satellite — the order both execution modes
    share); the bucket's cohort tensor is padded to ``(c_bucket, H,
    b_bucket, ...)``.
    """
    b_bucket: int               # padded batch width (align * 2^k)
    c_bucket: int               # padded client count (>= len(members))
    members: Tuple[int, ...]    # canonical-order client positions


def plan_buckets(widths: Sequence[int], batch_align: int = 32,
                 client_align: int = 4,
                 merge_slack: float = 1.25,
                 client_multiple: int = 1,
                 collapse_slack: float = 1.5) -> List[BucketPlan]:
    """Partition clients into geometric batch-width buckets.

    Every client lands in the bucket whose width is the smallest
    ``batch_align * 2**k`` covering its batch; within a bucket the batch
    padding is therefore < 2x for any client wider than ``batch_align``
    (and bounded by ``batch_align`` absolutely for narrower ones).  The
    client axis of each bucket is quantized to the same geometric grid
    (``client_align * 2**k``) so pool-size drift between rounds re-uses
    previously compiled step signatures instead of forcing a recompile
    per distinct client count.

    ``client_multiple`` is the shard-aware planner mode: every
    ``c_bucket`` must also be divisible by it (the mesh's ``data`` axis
    size), so a bucket's client axis splits evenly across shards.  The
    client grid becomes ``lcm(client_align, client_multiple) * 2**k`` —
    still geometric, so drift-stability of compiled signatures is
    preserved.

    A greedy coalescing pass then merges a bucket into the next-wider
    one whenever the joint layout costs at most ``merge_slack`` times
    the separate layouts: near-uniform pools collapse back to a single
    dispatch (bucketing must not tax the regime the global layout
    already handles well), while skewed pools — where merging would
    multiply the padding — stay split.  The constant-factor padding
    bound only weakens by ``merge_slack``.

    Finally, when the whole cohort laid out as ONE bucket (every client
    padded to the widest bucket) costs at most ``collapse_slack`` times
    the multi-bucket layout, the plan collapses to that single bucket:
    small cohorts are dispatch-bound, not padding-bound, and paying a
    bounded padding premium to halve the dispatch count is a win there
    (the uniform C=16 regime regressed to 0.62x of the global layout
    before this pass).  ``collapse_slack <= 0`` disables the pass.
    """
    groups: dict = {}
    for pos, w in enumerate(widths):
        groups.setdefault(next_geometric(w, batch_align), []).append(pos)
    align = math.lcm(max(1, int(client_align)), max(1, int(client_multiple)))

    def cost(members, b):
        return next_geometric(len(members), align) * b

    merged: List[Tuple[int, List[int]]] = []       # (b_bucket, members)
    for b in sorted(groups):
        if merged:
            b_prev, m_prev = merged[-1]
            joint = m_prev + groups[b]
            if cost(joint, b) <= merge_slack * (cost(m_prev, b_prev)
                                                + cost(groups[b], b)):
                merged[-1] = (b, joint)
                continue
        merged.append((b, list(groups[b])))

    if collapse_slack > 0 and len(merged) > 1:
        all_members = [p for _, m in merged for p in m]
        b_top = merged[-1][0]
        if cost(all_members, b_top) <= collapse_slack * sum(
                cost(m, b) for b, m in merged):
            merged = [(b_top, all_members)]

    return [BucketPlan(b_bucket=b,
                       c_bucket=next_geometric(len(m), align),
                       members=tuple(sorted(m)))
            for b, m in merged]


@dataclasses.dataclass
class BucketedCohort:
    """A round's client batches partitioned into width-aligned buckets.

    ``buckets[i]`` is a :class:`CohortBatch` padded to
    ``plans[i].c_bucket`` clients by ``plans[i].b_bucket`` batch slots;
    ``plans[i].members`` maps its leading real clients back to canonical
    cohort order.  ``sizes`` are the real clients' pool sizes in that
    canonical order (what eq.-(13) aggregation weights derive from).
    """
    buckets: List[CohortBatch]
    plans: List[BucketPlan]
    sizes: np.ndarray            # (n_real_clients,) canonical order

    @property
    def n_clients(self) -> int:
        return len(self.sizes)

    @property
    def real_elements(self) -> int:
        """Batch elements actually drawn (sum of H * B_c over clients)."""
        return sum(int(np.sum(cb.mask)) for cb in self.buckets)

    @property
    def layout_elements(self) -> int:
        """Batch elements the padded layout materializes and trains on."""
        return sum(int(np.prod(cb.mask.shape)) for cb in self.buckets)

    @property
    def padding_ratio(self) -> float:
        """layout / real elements — the padded-FLOPs overhead factor."""
        real = self.real_elements
        return float(self.layout_elements) / real if real else 1.0


def build_bucketed_cohort(x: np.ndarray, y: np.ndarray,
                          pools: Sequence[np.ndarray], n_steps: int,
                          rng: np.random.Generator, max_batch: int = 64,
                          batch_align: int = 32,
                          client_align: int = 4,
                          client_multiple: int = 1
                          ) -> "BucketedCohort | None":
    """Gather heterogeneous pools into width-aligned sub-cohorts.

    Batches are drawn exactly as :func:`build_cohort` draws them (same
    RNG stream, same canonical pool order), then grouped by per-client
    batch width via :func:`plan_buckets` — so the union of the buckets
    holds the same samples as the global-``Bmax`` cohort while the
    padded-element count stays within a constant factor of the real
    element count regardless of pool skew.  ``client_multiple`` is
    forwarded to the planner so every bucket's client axis divides
    evenly across that many mesh shards.
    """
    per_client, sizes = _draw_client_batches(x, y, pools, n_steps, rng,
                                             max_batch)
    if not per_client:
        return None
    widths = [bx.shape[1] for bx, _ in per_client]
    plans = plan_buckets(widths, batch_align=batch_align,
                         client_align=client_align,
                         client_multiple=client_multiple)
    sample_shape = x.shape[1:]
    buckets = []
    for plan in plans:
        xs = np.zeros((plan.c_bucket, n_steps, plan.b_bucket) + sample_shape,
                      dtype=x.dtype)
        ys = np.zeros((plan.c_bucket, n_steps, plan.b_bucket), dtype=y.dtype)
        mask = np.zeros((plan.c_bucket, n_steps, plan.b_bucket),
                        dtype=np.float32)
        bucket_sizes = np.zeros(plan.c_bucket, dtype=np.int64)
        for slot, pos in enumerate(plan.members):
            bx, by = per_client[pos]
            b = bx.shape[1]
            xs[slot, :, :b] = bx
            ys[slot, :, :b] = by
            mask[slot, :, :b] = 1.0
            bucket_sizes[slot] = sizes[pos]
        buckets.append(CohortBatch(xs=xs, ys=ys, mask=mask,
                                   sizes=bucket_sizes))
    return BucketedCohort(buckets=buckets, plans=plans,
                          sizes=np.asarray(sizes, dtype=np.int64))
