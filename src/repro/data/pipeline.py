"""Host-side batching pipeline for FL training and the big-model trainer.

Two entry points feed the FL round driver:

* ``batch_for_local_steps`` — per-node (H, B) batch stacks, used by the
  sequential execution path (one dispatch per node).
* ``build_cohort`` — the batched path's cohort builder: it gathers every
  data-holding node's (H, B) stack into ONE padded ``(C, H, Bmax, ...)``
  tensor plus a per-client validity mask and per-client pool sizes, so a
  single vmapped+jitted local-update step can train the whole cohort.
  Batches are drawn through ``batch_for_local_steps`` with the same RNG
  stream and call order as the sequential loop, which is what makes the
  two execution modes numerically equivalent at equal seeds.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple

import numpy as np


class BatchIterator:
    """Infinite shuffled mini-batch iterator over an index pool."""

    def __init__(self, x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.x, self.y = x, y
        self.indices = np.asarray(indices)
        self.batch_size = max(1, int(batch_size))
        self._rng = np.random.default_rng(seed)
        self._order = self._rng.permutation(len(self.indices))
        self._pos = 0

    def __iter__(self) -> "BatchIterator":
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        if len(self.indices) == 0:
            raise StopIteration
        if self._pos + self.batch_size > len(self._order):
            self._order = self._rng.permutation(len(self.indices))
            self._pos = 0
        sel = self.indices[self._order[self._pos:self._pos + self.batch_size]]
        self._pos += self.batch_size
        return self.x[sel], self.y[sel]


def batch_for_local_steps(x: np.ndarray, y: np.ndarray, indices: np.ndarray,
                          n_steps: int, rng: np.random.Generator,
                          max_batch: int = 64):
    """Split a node's pool into H mini-batches (paper: |D|/H per batch at the
    satellite; capped for memory on ground devices). Returns stacked arrays
    of shape (H, B, ...) padded by resampling when the pool is small."""
    indices = np.asarray(indices)
    if len(indices) == 0:
        return None
    b = int(np.ceil(len(indices) / n_steps))
    # paper: satellite batch = |D|/H. Cap for CPU memory, but let big pools
    # (air/satellite after offloading) use proportionally bigger batches so
    # their lambda-weighted gradients are not noise-dominated.
    eff_cap = int(np.clip(max(max_batch, len(indices) // (4 * n_steps)),
                          max_batch, 8 * max_batch))
    b = int(np.clip(b, 1, eff_cap))
    order = rng.permutation(indices)
    need = n_steps * b
    reps = int(np.ceil(need / len(order)))
    pool = np.concatenate([rng.permutation(indices) for _ in range(reps)])
    sel = pool[:need].reshape(n_steps, b)
    return x[sel], y[sel]


@dataclasses.dataclass
class CohortBatch:
    """A full round's worth of client batches, padded and masked.

    ``xs[c, h, :sizes-derived-B_c]`` are client ``c``'s real samples for
    local step ``h``; slots beyond that (and whole clients beyond
    ``n_clients``, when the cohort is padded to a fixed width) are zero
    and carry ``mask == 0`` so they contribute nothing to loss, gradient,
    or aggregation.
    """
    xs: np.ndarray        # (C, H, Bmax, ...) float
    ys: np.ndarray        # (C, H, Bmax) int
    mask: np.ndarray      # (C, H, Bmax) float32; 1.0 = real sample
    sizes: np.ndarray     # (C,) int pool size per client; 0 = padding client

    @property
    def n_clients(self) -> int:
        """Number of real (data-holding) clients in the cohort."""
        return int(np.sum(self.sizes > 0))

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.xs.shape


def build_cohort(x: np.ndarray, y: np.ndarray,
                 pools: Sequence[np.ndarray], n_steps: int,
                 rng: np.random.Generator, max_batch: int = 64,
                 pad_clients: int = 0,
                 batch_align: int = 32) -> "CohortBatch | None":
    """Gather heterogeneous node pools into one (C, H, Bmax, ...) cohort.

    Each non-empty pool is batched via ``batch_for_local_steps`` (same RNG
    stream and call order as the sequential driver, so both execution
    modes see identical samples), then right-padded along the batch axis
    to a common ``Bmax``. ``Bmax`` is rounded up to a multiple of
    ``batch_align`` and the client axis is optionally padded up to
    ``pad_clients`` zero-weight dummies — both quantize the compiled
    cohort step's shapes so that pool drift only forces a recompile when
    the round's largest per-client batch crosses an alignment bucket.
    Note ``Bmax`` is global: every client is padded to the widest
    client's batch, which is wasteful when pool sizes are heavily
    skewed.
    """
    per_client: List[Tuple[np.ndarray, np.ndarray]] = []
    sizes: List[int] = []
    for idx in pools:
        idx = np.asarray(idx)
        if len(idx) == 0:
            continue
        out = batch_for_local_steps(x, y, idx, n_steps, rng,
                                    max_batch=max_batch)
        per_client.append(out)
        sizes.append(len(idx))
    if not per_client:
        return None

    b_max = max(bx.shape[1] for bx, _ in per_client)
    align = max(1, int(batch_align))
    b_max = int(np.ceil(b_max / align) * align)
    c = max(len(per_client), int(pad_clients))

    sample_shape = x.shape[1:]
    xs = np.zeros((c, n_steps, b_max) + sample_shape, dtype=x.dtype)
    ys = np.zeros((c, n_steps, b_max), dtype=y.dtype)
    mask = np.zeros((c, n_steps, b_max), dtype=np.float32)
    for ci, (bx, by) in enumerate(per_client):
        b = bx.shape[1]
        xs[ci, :, :b] = bx
        ys[ci, :, :b] = by
        mask[ci, :, :b] = 1.0
    out_sizes = np.zeros(c, dtype=np.int64)
    out_sizes[:len(sizes)] = sizes
    return CohortBatch(xs=xs, ys=ys, mask=mask, sizes=out_sizes)
