"""Federated data partitioning (Section VI-A).

IID: uniform random allocation to the K ground devices.
Non-IID: sort by class, split into 200 shards, assign 4 shards per device
(the paper's protocol; generalizes to other K via shards = 4*K).
Sensitive/non-sensitive split: a fraction alpha of each device's samples is
non-sensitive (offloadable), the rest must stay on-device (Section II).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .synthetic import Dataset


@dataclasses.dataclass
class DevicePartition:
    device: int
    indices: np.ndarray            # into x_train
    sensitive_mask: np.ndarray     # True -> must stay on the device

    @property
    def n_samples(self) -> int:
        return len(self.indices)

    @property
    def n_sensitive(self) -> int:
        return int(self.sensitive_mask.sum())

    @property
    def offloadable_indices(self) -> np.ndarray:
        return self.indices[~self.sensitive_mask]

    @property
    def sensitive_indices(self) -> np.ndarray:
        return self.indices[self.sensitive_mask]


def partition(ds: Dataset, n_devices: int = 50, iid: bool = True,
              alpha: float = 0.8, shards_per_device: int = 4,
              seed: int = 0) -> List[DevicePartition]:
    rng = np.random.default_rng(seed)
    n = len(ds.x_train)
    if iid:
        perm = rng.permutation(n)
        splits = np.array_split(perm, n_devices)
    else:
        order = np.argsort(ds.y_train, kind="stable")
        n_shards = shards_per_device * n_devices
        shards = np.array_split(order, n_shards)
        shard_ids = rng.permutation(n_shards)
        splits = []
        for d in range(n_devices):
            ids = shard_ids[d * shards_per_device:(d + 1) * shards_per_device]
            splits.append(np.concatenate([shards[i] for i in ids]))
    out = []
    for d, idx in enumerate(splits):
        idx = np.asarray(idx)
        n_sens = int(round((1.0 - alpha) * len(idx)))
        mask = np.zeros(len(idx), dtype=bool)
        if n_sens > 0:
            mask[rng.choice(len(idx), size=n_sens, replace=False)] = True
        out.append(DevicePartition(device=d, indices=idx,
                                   sensitive_mask=mask))
    return out


@dataclasses.dataclass
class FederatedPools:
    """Mutable sample pools per node, updated by offloading each round.

    ``ground[k]``, ``air[n]``, ``sat`` are arrays of indices into x_train.
    Only non-sensitive indices ever move (the optimizer's plans are given in
    sample counts; we move the corresponding index sets).
    """
    ground: List[np.ndarray]
    ground_sensitive: List[np.ndarray]
    air: List[np.ndarray]
    sat: np.ndarray

    @classmethod
    def from_partitions(cls, parts: List[DevicePartition],
                        n_air: int) -> "FederatedPools":
        return cls(
            ground=[p.offloadable_indices.copy() for p in parts],
            ground_sensitive=[p.sensitive_indices.copy() for p in parts],
            air=[np.empty(0, dtype=np.int64) for _ in range(n_air)],
            sat=np.empty(0, dtype=np.int64),
        )

    def ground_all(self, k: int) -> np.ndarray:
        return np.concatenate([self.ground_sensitive[k], self.ground[k]])

    def total(self) -> int:
        return (sum(len(g) for g in self.ground)
                + sum(len(g) for g in self.ground_sensitive)
                + sum(len(a) for a in self.air) + len(self.sat))

    # -- moves (all amounts in #samples; clipped to availability) ------------
    def move_ground_to_air(self, k: int, n: int, amount: int) -> int:
        amount = int(min(amount, len(self.ground[k])))
        if amount <= 0:
            return 0
        moved, self.ground[k] = (self.ground[k][:amount],
                                 self.ground[k][amount:])
        self.air[n] = np.concatenate([self.air[n], moved])
        return amount

    def move_air_to_ground(self, n: int, k: int, amount: int) -> int:
        amount = int(min(amount, len(self.air[n])))
        if amount <= 0:
            return 0
        moved, self.air[n] = self.air[n][:amount], self.air[n][amount:]
        self.ground[k] = np.concatenate([self.ground[k], moved])
        return amount

    def move_air_to_sat(self, n: int, amount: int) -> int:
        amount = int(min(amount, len(self.air[n])))
        if amount <= 0:
            return 0
        moved, self.air[n] = self.air[n][:amount], self.air[n][amount:]
        self.sat = np.concatenate([self.sat, moved])
        return amount

    def move_sat_to_air(self, n: int, amount: int) -> int:
        amount = int(min(amount, len(self.sat)))
        if amount <= 0:
            return 0
        moved, self.sat = self.sat[:amount], self.sat[amount:]
        self.air[n] = np.concatenate([self.air[n], moved])
        return amount
