from .synthetic import SPECS, Dataset, make_dataset
from .partition import DevicePartition, FederatedPools, partition
from .pipeline import BatchIterator, batch_for_local_steps

__all__ = ["SPECS", "Dataset", "make_dataset", "DevicePartition",
           "FederatedPools", "partition", "BatchIterator",
           "batch_for_local_steps"]
