"""Synthetic stand-ins for the paper's FL benchmark datasets.

MNIST / FMNIST / CIFAR-10 are not downloadable in this container, so we
generate class-conditioned Gaussian-mixture image datasets with identical
shapes and cardinalities. Each class c has a random but fixed template
prototype; samples are prototype + noise, making the task learnable by the
same CNNs the paper uses, with a controllable difficulty (noise scale).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

SPECS = {
    # name: (image shape, n_classes, n_train, n_test)
    "mnist": ((28, 28, 1), 10, 60000, 10000),
    "fmnist": ((28, 28, 1), 10, 60000, 10000),
    "cifar10": ((32, 32, 3), 10, 50000, 10000),
}


@dataclasses.dataclass
class Dataset:
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_classes(self) -> int:
        return int(self.y_train.max()) + 1

    @property
    def sample_bits(self) -> float:
        """q: bits per sample (uint8 image + label byte), for the latency
        model."""
        return float(np.prod(self.x_train.shape[1:]) * 8 + 8)


def make_dataset(name: str, noise: float = 0.9, seed: int = 0,
                 train_fraction: float = 1.0,
                 sample_seed: int | None = None) -> Dataset:
    """Generate a synthetic dataset shaped like ``name``.

    ``train_fraction`` can shrink the dataset for fast tests.

    ``seed`` fixes the TASK (the class prototype templates);
    ``sample_seed`` (default: ``seed``) fixes the train/test sample
    draw around those prototypes.  Multi-region FL uses this split to
    give every region a different sample of the SAME task — models
    trained in different regions then solve one problem and can be
    merged into a global model.
    """
    shape, n_classes, n_train, n_test = SPECS[name]
    n_train = int(n_train * train_fraction)
    n_test = max(256, int(n_test * train_fraction))
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, size=(n_classes,) + shape).astype(np.float32)
    # smooth the prototypes a little so convolutions have structure to find
    for _ in range(2):
        protos = 0.5 * protos + 0.25 * (np.roll(protos, 1, axis=1)
                                        + np.roll(protos, -1, axis=1))

    def gen(n: int, seed2: int) -> Tuple[np.ndarray, np.ndarray]:
        r = np.random.default_rng(seed2)
        y = r.integers(0, n_classes, size=n).astype(np.int32)
        x = protos[y] + noise * r.normal(0.0, 1.0,
                                         size=(n,) + shape).astype(np.float32)
        return x.astype(np.float32), y

    s = seed if sample_seed is None else sample_seed
    x_tr, y_tr = gen(n_train, s + 1)
    x_te, y_te = gen(n_test, s + 2)
    return Dataset(name=name, x_train=x_tr, y_train=y_tr,
                   x_test=x_te, y_test=y_te)
