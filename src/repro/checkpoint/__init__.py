from .ckpt import load_pytree, save_pytree, handover_state
from .engine import restore_engine, save_engine

__all__ = ["load_pytree", "save_pytree", "handover_state",
           "restore_engine", "save_engine"]
