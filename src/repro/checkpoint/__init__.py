from .ckpt import load_pytree, save_pytree, handover_state

__all__ = ["load_pytree", "save_pytree", "handover_state"]
