"""Full-engine checkpoint/resume for :class:`~repro.sim.engine.SAGINEngine`.

Snapshots EVERYTHING the event-stepped FL run needs to continue
bit-identically: per-region model params, both RNG stream states (the
trainer's batch-draw generator and the orchestrator's satellite-CPU
generator), the Gilbert-Elliott dynamics chain states, wall clocks,
index pools, accumulated :class:`~repro.fl.rounds.FLResult` curves, the
engine's merge history, the global model, and the fault injector's
counters — such that at equal seeds

    engine.run(10)

and

    engine.run(5, final_merge=False)
    save_engine(engine, dir)
    ...                               # new process, fresh engine
    restore_engine(engine2, dir)
    engine2.run(5)

produce identical result curves, merges, and global params
(test-locked in ``tests/test_resilience.py``).

A checkpoint is a DIRECTORY:

* ``manifest.json``        — versioned run state (everything JSON-
  serializable), written atomically (temp file + ``os.replace``, the
  :mod:`repro.checkpoint.ckpt` discipline) and LAST, so a manifest's
  existence certifies a complete checkpoint.
* ``region<i>_params.npz`` (+ ``.tree`` sidecar) — per-region models.
* ``global_params.npz``    — the merged global model, when one exists.

``restore_engine`` restores INTO a freshly constructed engine built
with the same scenario/config/seed: construction replays the identical
derivation draws (dataset, partition, eval-set choice, model init), and
the checkpoint then overwrites every piece of state that advanced.
What is deliberately NOT checkpointed: cohort-engine compile
signatures/stats (the resumed process re-warms its jit caches) and
per-round :class:`~repro.core.scheduler.RoundRecord` histories (derived
telemetry; the result curves carry the trajectory).  The ``static``
offload strategy caches its round-0 plan outside the snapshot, so
resume it from round 0 only.
"""
from __future__ import annotations

import json
import os
from typing import List

import jax
import numpy as np

from .ckpt import _atomic_write_bytes, load_pytree, save_pytree

MANIFEST_VERSION = 1
MANIFEST_KIND = "sagin-engine"


def _pools_state(pools) -> dict:
    return {
        "ground": [p.tolist() for p in pools.ground],
        "ground_sensitive": [p.tolist() for p in pools.ground_sensitive],
        "air": [p.tolist() for p in pools.air],
        "sat": pools.sat.tolist(),
    }


def _restore_pools(pools, state: dict) -> None:
    pools.ground = [np.asarray(p, dtype=np.int64)
                    for p in state["ground"]]
    pools.ground_sensitive = [np.asarray(p, dtype=np.int64)
                              for p in state["ground_sensitive"]]
    pools.air = [np.asarray(p, dtype=np.int64) for p in state["air"]]
    pools.sat = np.asarray(state["sat"], dtype=np.int64)


def _result_state(res) -> dict:
    return {
        "times": list(res.times),
        "accuracies": list(res.accuracies),
        "losses": list(res.losses),
        "latencies": list(res.latencies),
        "cases": list(res.cases),
        "layer_portions": list(res.layer_portions),
        "participated": list(res.participated),
    }


def _restore_result(res, state: dict) -> None:
    res.times[:] = [float(x) for x in state["times"]]
    res.accuracies[:] = [float(x) for x in state["accuracies"]]
    res.losses[:] = [float(x) for x in state["losses"]]
    res.latencies[:] = [float(x) for x in state["latencies"]]
    res.cases[:] = [int(x) for x in state["cases"]]
    res.layer_portions[:] = [dict(p) for p in state["layer_portions"]]
    res.participated[:] = [bool(x) for x in state["participated"]]


def _trainer_state(trainer) -> dict:
    orch = trainer.orch
    return {
        "rng": trainer.rng.bit_generator.state,
        "orch_rng": orch._rng.bit_generator.state,
        "wall_clock": float(orch.wall_clock),
        "dynamics": (orch.dynamics.state_dict()
                     if orch.dynamics is not None else None),
        "last_isl_scale": float(trainer._last_isl_scale),
        "result": _result_state(trainer.result),
        "pools": _pools_state(trainer.pools),
    }


def _restore_trainer(trainer, state: dict, params_path: str) -> None:
    from repro.fl.rounds import _sync_sizes

    trainer.params = jax.device_put(
        load_pytree(trainer.params, params_path))
    trainer.rng.bit_generator.state = state["rng"]
    orch = trainer.orch
    orch._rng.bit_generator.state = state["orch_rng"]
    orch.wall_clock = float(state["wall_clock"])
    if state["dynamics"] is not None:
        if orch.dynamics is None:
            raise ValueError(
                f"checkpoint carries dynamics state but the rebuilt "
                f"trainer for region {trainer._region_name!r} has none "
                f"— scenario mismatch?")
        orch.dynamics.load_state_dict(state["dynamics"])
    trainer._last_isl_scale = float(state["last_isl_scale"])
    _restore_result(trainer.result, state["result"])
    _restore_pools(trainer.pools, state["pools"])
    _sync_sizes(trainer.pools, trainer.sagin)


def _merge_state(m) -> dict:
    return {
        "barrier_round": m.barrier_round, "time": m.time,
        "staleness": list(m.staleness), "weights": list(m.weights),
        "isl_costs": list(m.isl_costs), "accuracies": list(m.accuracies),
        "policy": m.policy, "hub": m.hub,
        "participants": list(m.participants),
        "recipients": list(m.recipients),
    }


def _restore_merges(states: List[dict]):
    from repro.sim.engine import MergeEvent
    return [MergeEvent(
        barrier_round=int(s["barrier_round"]), time=float(s["time"]),
        staleness=tuple(s["staleness"]), weights=tuple(s["weights"]),
        isl_costs=tuple(s["isl_costs"]),
        accuracies=tuple(s["accuracies"]), policy=s["policy"],
        hub=int(s["hub"]), participants=tuple(s["participants"]),
        recipients=tuple(s["recipients"])) for s in states]


def save_engine(engine, path: str) -> str:
    """Snapshot a (FL-mode) engine's full run state into directory
    ``path``.  Returns the manifest path.

    Safe against crashes mid-save: params land via the atomic npz
    writer, and the manifest — written last, atomically — is what
    :func:`restore_engine` keys on, so an interrupted save can never
    masquerade as a complete checkpoint (a previous manifest at the
    same path keeps describing the previous, still-intact snapshot
    only if its params files were not yet overwritten — use a fresh
    directory per snapshot when that matters).
    """
    if not engine.trainers:
        raise ValueError("save_engine snapshots FL-mode engines; this "
                         "engine has no region trainers")
    os.makedirs(path, exist_ok=True)
    regions = []
    for i, t in enumerate(engine.trainers):
        save_pytree(t.params, os.path.join(path, f"region{i}_params.npz"))
        regions.append(_trainer_state(t))
    has_global = engine.global_params is not None
    if has_global:
        save_pytree(engine.global_params,
                    os.path.join(path, "global_params.npz"))
    manifest = {
        "version": MANIFEST_VERSION,
        "kind": MANIFEST_KIND,
        "scenario": engine.scenario.name,
        "n_regions": len(engine.trainers),
        "rounds_done": len(engine.trainers[0].result.times),
        "has_global": has_global,
        "merges": [_merge_state(m) for m in engine.merges],
        "faults": (engine.fault_injector.state_dict()
                   if engine.fault_injector is not None else None),
        "regions": regions,
    }
    manifest_path = os.path.join(path, "manifest.json")
    _atomic_write_bytes(manifest_path,
                        json.dumps(manifest, indent=1).encode("utf-8"))
    return manifest_path


def restore_engine(engine, path: str):
    """Restore the snapshot in directory ``path`` into ``engine`` — a
    freshly constructed engine with the same scenario/FLConfig/seed —
    and return it.  Raises :class:`ValueError` on a missing/foreign/
    mismatched checkpoint.  Emits one ``resume`` span on the engine's
    tracer (purely observational, like all obs)."""
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        raise ValueError(f"no engine checkpoint at {path!r} "
                         f"(manifest.json missing)")
    with open(manifest_path, "r", encoding="utf-8") as f:
        manifest = json.load(f)
    if manifest.get("kind") != MANIFEST_KIND:
        raise ValueError(f"{manifest_path} is not a sagin-engine "
                         f"checkpoint (kind={manifest.get('kind')!r})")
    if manifest.get("version") != MANIFEST_VERSION:
        raise ValueError(f"unsupported engine-checkpoint version "
                         f"{manifest.get('version')!r}; this build reads "
                         f"version {MANIFEST_VERSION}")
    if manifest["scenario"] != engine.scenario.name:
        raise ValueError(f"checkpoint is for scenario "
                         f"{manifest['scenario']!r}, engine runs "
                         f"{engine.scenario.name!r}")
    if manifest["n_regions"] != len(engine.trainers):
        raise ValueError(f"checkpoint has {manifest['n_regions']} "
                         f"regions, engine has {len(engine.trainers)}")
    for i, (t, state) in enumerate(zip(engine.trainers,
                                       manifest["regions"])):
        _restore_trainer(t, state,
                         os.path.join(path, f"region{i}_params.npz"))
    engine.merges = _restore_merges(manifest["merges"])
    if manifest["has_global"]:
        engine.global_params = jax.device_put(load_pytree(
            engine.trainers[0].params,
            os.path.join(path, "global_params.npz")))
    else:
        engine.global_params = None
    if manifest["faults"] is not None:
        if engine.fault_injector is None:
            raise ValueError("checkpoint carries fault-injector state "
                             "but the engine has no fault plan — "
                             "scenario mismatch?")
        engine.fault_injector.load_state_dict(manifest["faults"])
    tr = engine.tracer
    if tr.enabled:
        from repro.obs import FEDERATION_TRACK
        tr.event("resume", f"resume@r{manifest['rounds_done']}",
                 region=FEDERATION_TRACK,
                 round=int(manifest["rounds_done"]),
                 t_sim=max((t.wall_clock for t in engine.trainers),
                           default=0.0),
                 rounds_done=int(manifest["rounds_done"]),
                 scenario=manifest["scenario"])
        tr.metrics.counter("engine.resumes").inc()
    return engine
