"""Pytree checkpointing (npz-based; no external deps).

Also provides ``handover_state``: the serialized blob a satellite transmits
to its successor (model + optimizer state + remaining-data manifest), whose
byte size feeds the handover-delay model (eq. 7).
"""
from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, path: str) -> int:
    """Save a pytree to ``path`` (npz + structure json). Returns bytes."""
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)
    with open(path + ".tree", "w") as f:
        f.write(str(treedef))
    return os.path.getsize(path if path.endswith(".npz") else path + ".npz")


def load_pytree(template, path: str):
    """Load into the structure of ``template`` (keys must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_t = _flatten_with_paths(template)
    assert set(flat_t) == set(data.files), "checkpoint structure mismatch"
    leaves, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    new_leaves = []
    for (path_elems, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        arr = data[key]
        new_leaves.append(np.asarray(arr, dtype=np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def handover_state(params, opt_state, data_manifest: Dict[str, Any]
                   ) -> Tuple[bytes, float]:
    """Serialize the satellite handover blob; returns (blob, bits).

    The bit count is what enters eq. (7) as Q(w) (+ manifest overhead);
    the data samples themselves are counted separately via q|D_S|.
    """
    buf = io.BytesIO()
    flat = _flatten_with_paths({"params": params, "opt": opt_state})
    np.savez(buf, **flat)
    manifest = json.dumps(data_manifest).encode()
    blob = manifest + b"\x00" + buf.getvalue()
    return blob, 8.0 * len(blob)
