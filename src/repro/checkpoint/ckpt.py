"""Pytree checkpointing (npz-based; no external deps).

Also provides ``handover_state``: the serialized blob a satellite transmits
to its successor (model + optimizer state + remaining-data manifest), whose
byte size feeds the handover-delay model (eq. 7).

Write discipline: both the ``.npz`` payload and its ``.tree`` structure
sidecar land via temp file + ``os.replace`` — a crash mid-save leaves
the previous checkpoint intact, never a torn file (the engine-level
snapshots in :mod:`repro.checkpoint.engine` build on this).
"""
from __future__ import annotations

import io
import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _npz_path(path: str) -> str:
    """Normalized on-disk npz destination for ``path``."""
    return path if path.endswith(".npz") else path + ".npz"


def _atomic_write_bytes(path: str, payload: bytes) -> None:
    """Write ``payload`` to ``path`` via temp file + ``os.replace``."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def save_pytree(tree, path: str) -> int:
    """Save a pytree to ``path`` (npz + structure sidecar). Returns bytes.

    Both files are written atomically (temp file + ``os.replace``); the
    byte count is that of the npz payload regardless of whether ``path``
    already carries the ``.npz`` suffix.
    """
    flat = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    npz = _npz_path(path)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    _atomic_write_bytes(npz, buf.getvalue())
    _atomic_write_bytes(npz + ".tree", str(treedef).encode("utf-8"))
    return os.path.getsize(npz)


def load_pytree(template, path: str):
    """Load into the structure of ``template`` (keys must match).

    Raises :class:`ValueError` on a leaf-key mismatch with the template
    and on a ``.tree`` structure-sidecar mismatch (when the sidecar
    exists — pre-hardening checkpoints may lack one).
    """
    path = _npz_path(path)
    data = np.load(path)
    flat_t = _flatten_with_paths(template)
    if set(flat_t) != set(data.files):
        missing = sorted(set(flat_t) - set(data.files))
        extra = sorted(set(data.files) - set(flat_t))
        raise ValueError(
            f"checkpoint structure mismatch for {path}: "
            f"missing keys {missing[:5]}{'...' if len(missing) > 5 else ''}, "
            f"unexpected keys {extra[:5]}{'...' if len(extra) > 5 else ''}")
    tree_path = path + ".tree"
    if os.path.exists(tree_path):
        with open(tree_path, "r", encoding="utf-8") as f:
            saved_def = f.read().strip()
        want_def = str(jax.tree_util.tree_structure(template)).strip()
        if saved_def != want_def:
            raise ValueError(
                f"checkpoint treedef mismatch for {path}: saved structure "
                f"{saved_def!r} != template structure {want_def!r}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    new_leaves = []
    for (path_elems, leaf) in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        arr = data[key]
        new_leaves.append(np.asarray(arr, dtype=np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def handover_state(params, opt_state, data_manifest: Dict[str, Any]
                   ) -> Tuple[bytes, float]:
    """Serialize the satellite handover blob; returns (blob, bits).

    The bit count is what enters eq. (7) as Q(w) (+ manifest overhead);
    the data samples themselves are counted separately via q|D_S|.
    """
    buf = io.BytesIO()
    flat = _flatten_with_paths({"params": params, "opt": opt_state})
    np.savez(buf, **flat)
    manifest = json.dumps(data_manifest).encode()
    blob = manifest + b"\x00" + buf.getvalue()
    return blob, 8.0 * len(blob)
