from .registry import (SCENARIOS, Scenario, get_scenario, list_scenarios,
                       register)

__all__ = ["SCENARIOS", "Scenario", "get_scenario", "list_scenarios",
           "register"]
