"""Named SAGIN scenario presets: constellation + regions + dynamics.

A :class:`Scenario` is the single declarative object from which
examples, tests, and benchmarks construct a full simulation — the
"as many scenarios as you can imagine" axis of the roadmap.  Presets
ship for the paper's exact setup, a mega-constellation, a multi-region
deployment, degraded links, and device churn; new scenarios register
with :func:`register` (or :func:`scenario`, its decorator form for
lazily-built variants).

    from repro.scenarios import get_scenario
    scn = get_scenario("multi_region")
    engine = SAGINEngine(scn, seed=0)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

from repro.core.constellation import AccessInterval, WalkerStar
from repro.fl.federation import FederationConfig
from repro.obs import ObsConfig
from repro.resilience import FaultPlan, FaultSpec
from repro.serve.workload import ServeConfig
from repro.sim.dynamics import DynamicsConfig
from repro.sim.propagation import Region, access_intervals_multi


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Declarative description of one SAGIN FL deployment."""
    name: str
    description: str
    # constellation ---------------------------------------------------------
    n_sats: int = 80
    n_planes: int = 5
    altitude: float = 800e3
    inclination_deg: float = 85.0
    phasing: int = 1
    # regions ---------------------------------------------------------------
    regions: Tuple[Region, ...] = (Region("indiana", 40.0, -86.0),)
    # per-region network population (engine defaults; FLConfig may override)
    n_devices: int = 50
    n_air: int = 5
    samples_per_device: int = 1200
    alpha: float = 0.8
    strategy: str = "adaptive"
    # dynamics --------------------------------------------------------------
    dynamics: Optional[DynamicsConfig] = None
    # observability (repro.obs): an ObsConfig or a bare JSONL trace
    # path; disabled when None.  FLConfig.obs wins when both are set.
    obs: Optional[ObsConfig | str] = None
    # fault injection (repro.resilience): a deterministic schedule of
    # typed faults the engine injects in FL mode — satellite loss,
    # merge-time ISL partitions, stragglers, NaN updates, trainer
    # crashes.  None (default) runs clean with zero overhead.
    faults: Optional[FaultPlan] = None
    # serving workload (repro.serve): arrival process / router / batching
    # a ServeGateway attached to this scenario's engine uses.
    # FLConfig.serve wins when both are set; None means the gateway's
    # defaults.  Training never reads this field.
    serve: Optional[ServeConfig] = None
    # cross-region federation (engine FL mode) ------------------------------
    # The federation policy decides WHO merges WHAT, WHEN, at WHAT ISL
    # price (repro.fl.federation): cadence, topology, staleness
    # half-life, quorum, hub election.  None keeps regions fully
    # independent (one model per region, the pre-merge behavior).
    federation: Optional[FederationConfig] = None
    # DEPRECATED: legacy spelling of federation=FederationConfig(
    # policy="synchronous", every=..., topology=..., half_life=...).
    # Kept as a shim — passing merge_every synthesizes the equivalent
    # synchronous federation config and emits one DeprecationWarning.
    merge_every: Optional[int] = None
    merge_topology: str = "ring"            # "ring" | "star" ISL route
    merge_half_life: Optional[float] = None
    # propagation window ----------------------------------------------------
    horizon: float = 48 * 3600.0
    dt: float = 10.0

    def __post_init__(self):
        from repro.core.latency import MERGE_TOPOLOGIES
        if self.merge_every is not None and self.merge_every < 1:
            raise ValueError(f"{self.name}: merge_every must be a positive "
                             f"round count or None, got {self.merge_every}")
        if self.merge_topology not in MERGE_TOPOLOGIES:
            raise ValueError(f"{self.name}: merge_topology must be one of "
                             f"{MERGE_TOPOLOGIES}, got "
                             f"{self.merge_topology!r}")
        # federation= wins outright over the legacy fields: replace()d
        # copies of a legacy scenario keep merge_every around, so a
        # both-set error would break dataclasses.replace(scn,
        # federation=...) — the migration path itself
        if self.merge_every is not None and self.federation is None:
            warnings.warn(
                f"Scenario merge_every/merge_topology/merge_half_life are "
                f"deprecated; pass federation=FederationConfig("
                f"policy='synchronous', every={self.merge_every}, "
                f"topology={self.merge_topology!r}, "
                f"half_life={self.merge_half_life}) instead",
                DeprecationWarning, stacklevel=3)

    def resolved_federation(self) -> Optional[FederationConfig]:
        """The scenario's federation config, with the deprecated
        ``merge_*`` fields mapped to the equivalent ``synchronous``
        policy (trajectory-identical at equal seeds).  ``None`` means no
        cross-region merging."""
        if self.federation is not None:
            return self.federation
        if self.merge_every is None:
            return None
        return FederationConfig(policy="synchronous",
                                every=self.merge_every,
                                topology=self.merge_topology,
                                half_life=self.merge_half_life)

    def build_constellation(self) -> WalkerStar:
        if self.n_sats % self.n_planes:
            raise ValueError(f"{self.name}: n_sats={self.n_sats} not "
                             f"divisible by n_planes={self.n_planes}")
        return WalkerStar(n_sats=self.n_sats, n_planes=self.n_planes,
                          altitude=self.altitude,
                          inclination_deg=self.inclination_deg,
                          phasing=self.phasing)

    def build_intervals(self, backend: str = "numpy"
                        ) -> Dict[str, List[AccessInterval]]:
        """Coverage windows for every region from one shared propagation.

        NumPy (float64) by default so window boundaries are host-
        independent; see ``access_intervals_multi`` for the jax opt-in.
        """
        return access_intervals_multi(self.build_constellation(),
                                      self.regions, t_end=self.horizon,
                                      dt=self.dt, backend=backend)


SCENARIOS: Dict[str, Scenario] = {}


def register(scn: Scenario) -> Scenario:
    if scn.name in SCENARIOS:
        raise ValueError(f"scenario {scn.name!r} already registered")
    SCENARIOS[scn.name] = scn
    return scn


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; available: "
                         f"{sorted(SCENARIOS)}") from None


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# Presets --------------------------------------------------------------------
# ---------------------------------------------------------------------------
register(Scenario(
    name="paper",
    description="The paper's Section VI-A setup: 80-sat Walker-Star over "
                "one Indiana target region, deterministic network.",
))

register(Scenario(
    name="mega_constellation",
    description="Starlink-class shell: 1080 satellites in 27 planes at "
                "550 km / 53 deg serving two mid-latitude regions.",
    n_sats=1080, n_planes=27, altitude=550e3, inclination_deg=53.0,
    regions=(Region("indiana", 40.0, -86.0),
             Region("catalonia", 41.4, 2.2)),
    horizon=6 * 3600.0, dt=10.0,
))

register(Scenario(
    name="multi_region",
    description="One shared 80-sat constellation training ONE global FL "
                "model across four continents: regions merge over the "
                "ISL ring every 2 rounds with staleness-discounted "
                "weights (set federation=None for independent models; "
                "swap federation.policy for soft_async/partial/"
                "elected_hub merges).",
    regions=(Region("indiana", 40.0, -86.0),
             Region("nairobi", -1.3, 36.8),
             Region("reykjavik", 64.1, -21.9),
             Region("sydney", -33.9, 151.2)),
    n_devices=20, n_air=2,
    federation=FederationConfig(policy="synchronous", every=2,
                                topology="ring", half_life=3600.0),
    horizon=24 * 3600.0,
))

register(Scenario(
    name="degraded_links",
    description="Paper topology under hostile links: frequent ISL fades, "
                "per-cluster uplink outages, heavy weather on rates.",
    dynamics=DynamicsConfig(isl_outage_prob=0.3, isl_outage_scale=0.25,
                            uplink_outage_prob=0.2,
                            uplink_outage_delay=30.0,
                            weather_std=0.3),
))

register(Scenario(
    name="device_churn",
    description="Paper topology with unreliable ground devices (20% "
                "offline per round) and satellite compute jitter.",
    dynamics=DynamicsConfig(churn_prob=0.2, sat_freq_jitter_std=0.2),
))

register(Scenario(
    name="flash_crowd",
    description="Burst-dominated serving traffic over hostile links: "
                "three regions under the degraded_links outage profile "
                "while Gilbert-Elliott burst episodes drive 12x request "
                "spikes against a quiet baseline — the stress case for "
                "the min-response-time serving router (queues pile onto "
                "the own satellite exactly when its uplink dead-airs).",
    regions=(Region("indiana", 40.0, -86.0),
             Region("nairobi", -1.3, 36.8),
             Region("sydney", -33.9, 151.2)),
    n_devices=12, n_air=2,
    dynamics=DynamicsConfig(isl_outage_prob=0.3, isl_outage_scale=0.25,
                            uplink_outage_prob=0.2,
                            uplink_outage_delay=30.0,
                            weather_std=0.3),
    serve=ServeConfig(base_rate=1.0, diurnal_amplitude=0.2,
                      burst_markov=(0.05, 0.2), burst_multiplier=12.0,
                      router="min_rt"),
    federation=FederationConfig(policy="synchronous", every=2,
                                topology="ring", half_life=3600.0),
    horizon=24 * 3600.0,
))

register(Scenario(
    name="chaos",
    description="Resilience gauntlet: three regions under bursty "
                "Gilbert-Elliott ISL/uplink outages, heavy weather, and "
                "device churn, with a handcrafted fault schedule that "
                "exercises every repro.resilience fault kind — "
                "mid-coverage satellite loss, merge-time ISL partitions, "
                "stragglers, NaN client updates, and a trainer crash — "
                "against the recovery paths (unplanned handover re-plan, "
                "partial-quorum fallback, quarantine, warm restart).",
    regions=(Region("indiana", 40.0, -86.0),
             Region("nairobi", -1.3, 36.8),
             Region("sydney", -33.9, 151.2)),
    n_devices=12, n_air=2,
    dynamics=DynamicsConfig(isl_markov=(0.3, 0.5), isl_outage_scale=0.25,
                            uplink_markov=(0.2, 0.6),
                            uplink_outage_delay=30.0,
                            weather_std=0.2, sat_freq_jitter_std=0.2,
                            churn_prob=0.15),
    federation=FederationConfig(policy="synchronous", every=2,
                                topology="ring", half_life=3600.0),
    faults=FaultPlan(faults=(
        FaultSpec("sat_loss", round=1, region=0, severity=0.5),
        FaultSpec("straggler", round=1, region=1, severity=3.0),
        FaultSpec("isl_partition", round=2, region=2),
        FaultSpec("nan_update", round=3, region=2, severity=2.0),
        FaultSpec("trainer_crash", round=4, region=1, severity=0.5),
        FaultSpec("isl_partition", round=4, region=1),
        FaultSpec("nan_update", round=5, region=0, severity=1.0),
    )),
    horizon=24 * 3600.0,
))
