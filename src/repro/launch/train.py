"""pjit train/prefill step factories for the production mesh.

``make_sharded_train_step`` builds the standard distributed trainer
(data+tensor parallel with FSDP weights). ``make_fl_train_step`` builds the
paper's hierarchical-FL variant: each pod holds an independent model
replica (satellite), runs local SGD, and replicas are aggregated with the
lambda-weighted psum of eq. (13) across the ``pod`` axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape, input_specs
from repro.models import transformer as T
from repro.sharding.specs import batch_axes, data_pspec, param_pspecs


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def make_sharded_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                            lr: float = 1e-3, fsdp: bool = True,
                            pod_shard_params: bool = False,
                            donate: bool = True):
    """Returns (step_fn, in_shardings, out_shardings) ready to lower.

    step(params, batch) -> (params, metrics); plain SGD (paper eqs. 3-6).
    """
    multi_pod = "pod" in mesh.axis_names
    pspecs = param_pspecs(cfg, abstract_params(cfg), fsdp=fsdp,
                          pod_shard_params=pod_shard_params)
    param_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs)
    bspec = data_pspec(cfg, shape, multi_pod)
    batch_sh = {
        "inputs": NamedSharding(mesh, bspec),
        "labels": NamedSharding(mesh, bspec),
    }
    step = T.make_train_step(cfg, lr=lr)
    metrics_sh = {k: NamedSharding(mesh, P())
                  for k in ("loss", "ce", "aux")}
    jitted = jax.jit(
        step,
        in_shardings=(param_sh, batch_sh),
        out_shardings=(param_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, (param_sh, batch_sh), (param_sh, metrics_sh)


def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape):
    """Forward-only step (inference prefill): logits of the last position."""
    multi_pod = "pod" in mesh.axis_names
    pspecs = param_pspecs(cfg, abstract_params(cfg))
    param_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs)
    bspec = data_pspec(cfg, shape, multi_pod)

    def prefill(params, batch):
        h, _ = T.forward(params, cfg, batch["inputs"])
        # last-token logits only (decode bootstrap)
        logits = T.unembed(params, cfg, h[:, -1:, :])
        return logits[:, 0].astype(jnp.float32)

    jitted = jax.jit(
        prefill,
        in_shardings=(param_sh, {"inputs": NamedSharding(mesh, bspec)}),
        out_shardings=NamedSharding(mesh, P(bspec[0] if bspec else None)),
    )
    return jitted, param_sh


def make_replica_agg_step(mesh, axis_names, spec):
    """Standalone eq.-(13) aggregation across mesh axes, shard_map-native.

    Wraps ``hierarchical_weighted_psum`` in ``repro.compat.shard_map`` (so
    it works across the jax versions that moved the API). Returns a jitted
    ``agg(tree, lam)`` where every leaf of ``tree`` and ``lam`` is sharded
    by ``spec``; ``lam`` holds each shard's aggregation weight (one scalar
    per shard, weights summing to 1 across ``axis_names``).
    """
    from repro.fl.aggregation import hierarchical_weighted_psum

    def agg_block(tree, lam):
        return hierarchical_weighted_psum(tree, jnp.reshape(lam, ()),
                                          axis_names)

    sm = shard_map(agg_block, mesh=mesh, in_specs=(spec, spec),
                   out_specs=spec)
    return jax.jit(sm)


# ---------------------------------------------------------------------------
# Hierarchical FL across pods (the paper's technique, mesh-native) ------------
# ---------------------------------------------------------------------------
def make_fl_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                       lr: float = 1e-3, h_local: int = 1,
                       agg_dtype: str = "float32"):
    """Per-pod local SGD + eq.-(13) aggregation across the ``pod`` axis.

    Params carry a leading replica axis of size n_pod sharded over
    ``pod`` (each pod = one satellite-era model replica); the inner train
    step is vmapped over that axis, so within a pod it runs data+tensor
    parallel as usual, and the round ends with the lambda-weighted
    aggregation of eq. (13) — a weighted mean over the replica axis that
    GSPMD lowers to collectives across pods. (A partial-manual shard_map
    formulation trips an XLA SPMD partitioner check at 512 devices; the
    vmap formulation is semantically identical.)
    """
    assert "pod" in mesh.axis_names, "FL step needs the multi-pod mesh"
    n_pod = mesh.devices.shape[0]
    base_shapes = abstract_params(cfg)
    pspecs = param_pspecs(cfg, base_shapes, fsdp=True)
    rep_pspecs = jax.tree_util.tree_map(
        lambda s: P(*(("pod",) + tuple(s))), pspecs)
    rep_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), rep_pspecs)
    # batch carries the same leading replica axis: (n_pod, B/n_pod, ...)
    if cfg.input_mode == "tokens":
        in_spec = P("pod", "data", None)
    else:
        in_spec = P("pod", "data", None, None)
    batch_sh = {"inputs": NamedSharding(mesh, in_spec),
                "labels": NamedSharding(mesh, P("pod", "data", None))}

    inner_step = T.make_train_step(cfg, lr=lr)

    def pod_round(params_rep, batch):
        def local(params, b):
            for _ in range(h_local):
                params, metrics = inner_step(params, b)
            return params, metrics

        new_rep, metrics = jax.vmap(local)(params_rep, batch)
        # eq. (13): lambda-weighted aggregation across pod replicas
        # (uniform data portions across pods in this lowering).
        # agg_dtype="bfloat16" aggregates in the param dtype — a
        # beyond-paper option halving the cross-pod collective bytes.
        adt = jnp.dtype(agg_dtype)
        lam = jnp.asarray(1.0 / n_pod, adt)
        agg = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                jnp.sum(lam * x.astype(adt), axis=0,
                        keepdims=True).astype(x.dtype), x.shape),
            new_rep)
        metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x), metrics)
        return agg, metrics

    metrics_sh = {k: NamedSharding(mesh, P())
                  for k in ("loss", "ce", "aux")}
    jitted = jax.jit(pod_round, in_shardings=(rep_sh, batch_sh),
                     out_shardings=(rep_sh, metrics_sh),
                     donate_argnums=(0,))
    return jitted, rep_sh, batch_sh
