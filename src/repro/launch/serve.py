"""pjit serve_step factory: one-token decode with a sharded KV/state cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer as T
from repro.sharding.specs import cache_pspecs, data_pspec, param_pspecs
from .train import abstract_params


def abstract_cache(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    cache_len = shape.seq_len
    return jax.eval_shape(lambda: T.init_cache(cfg, b, cache_len))


def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                    donate: bool = True):
    """Returns (step_fn, (param_sh, cache_sh, input_sh)).

    step(params, cache, inputs, pos) -> (logits (B, V), new_cache).
    """
    multi_pod = "pod" in mesh.axis_names
    pspecs = param_pspecs(cfg, abstract_params(cfg))
    param_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs)
    cache_shape = abstract_cache(cfg, shape)
    cspecs = cache_pspecs(cfg, cache_shape, shape, multi_pod)
    cache_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), cspecs)
    bspec = data_pspec(cfg, shape, multi_pod)
    input_sh = NamedSharding(mesh, bspec)

    def step(params, cache, inputs, pos):
        return T.serve_step(params, cfg, cache, inputs, pos)

    logits_sh = NamedSharding(mesh, P(bspec[0] if len(bspec) else None,
                                      "model"))
    jitted = jax.jit(
        step,
        in_shardings=(param_sh, cache_sh, input_sh, None),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, (param_sh, cache_sh, input_sh)
