"""Production mesh construction (TPU v5e target).

Single pod: 256 chips as (16, 16) with axes ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16), axes ("pod","data","model").

Defined as functions (never module-level constants) so importing this
module does not touch jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE any jax import.
"""
from __future__ import annotations

import jax

from repro.compat import shard_map  # noqa: F401  (version-stable re-export
#                                    for mesh programs; see repro.compat)

__all__ = ["make_production_mesh", "make_host_mesh", "make_cohort_mesh",
           "shard_map", "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_cohort_mesh(n_devices=None):
    """1-D ``("data",)`` mesh over the visible devices — the client-axis
    sharding domain of the mesh-sharded :class:`~repro.fl.cohort_engine.
    CohortEngine`.  ``n_devices`` caps the mesh to a leading subset of
    ``jax.devices()`` (forced-host-device CI sweeps use 1/2/4/8)."""
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devices):
        raise ValueError(f"n_devices={n} not in [1, {len(devices)}]")
    return jax.make_mesh((n,), ("data",), devices=devices[:n])


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
