"""Loop-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 94 layers contributes its body a single time, making the
numbers useless for rooflines of scanned models. This module re-derives
per-device FLOPs / bytes-accessed / collective bytes by walking the HLO
computation graph from ENTRY and multiplying ``while`` bodies by their
``known_trip_count`` backend annotation (exact for lax.scan).

Counting rules
  * flops: ``dot`` ops only (2 * prod(result) * prod(contracting dims));
    elementwise flops are ignored (they are never roofline-dominant here).
  * bytes: result + operand bytes of every materializing op; ``fusion``
    ops are counted at the call site (post-fusion traffic), their bodies
    are not descended into. parameter/constant/tuple plumbing is free.
  * collectives: per-device result bytes by kind (all-reduce counted 2x:
    ring reduce-scatter + all-gather traffic).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
         "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
         "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n["\s:]+\"?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "call", "conditional", "iota",
                   "after-all", "partition-id", "replica-id"}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * BYTES.get(dtype, 4)
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]          # op name -> result type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(name=m.group(2), ops=[], symbols={})
                # signature parameters also define symbols, but HLO emits
                # explicit "parameter(i)" ops inside, so nothing to do.
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(name=m.group(1), type_str=m.group(2), opcode=m.group(3),
                    line=line)
            cur.ops.append(op)
            cur.symbols[op.name] = op.type_str
    return comps


_OPERAND_RE = re.compile(r"\(([^)]*)\)")
_NAME_IN_OPERANDS = re.compile(r"%([\w.\-]+)")


def _operand_names(op: Op) -> List[str]:
    # operands are inside the first (...) after the opcode
    idx = op.line.find(op.opcode + "(")
    if idx < 0:
        return []
    rest = op.line[idx + len(op.opcode):]
    m = _OPERAND_RE.search(rest)
    if not m:
        return []
    return _NAME_IN_OPERANDS.findall(m.group(1))


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    _, rdims = _shape_dims(op.type_str)
    result = 1.0
    for d in rdims:
        result *= d
    k = 1.0
    m = _CONTRACT_RE.search(op.line)
    ops = _operand_names(op)
    if m and ops:
        lhs_type = symbols.get(ops[0], "")
        _, ldims = _shape_dims(lhs_type)
        for i in [int(x) for x in m.group(1).split(",") if x]:
            if i < len(ldims):
                k *= ldims[i]
    return 2.0 * result * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k,
                     {n: v * k for n, v in self.collectives.items()})

    def add(self, other: "Costs"):
        self.flops += other.flops
        self.bytes += other.bytes
        for n, v in other.collectives.items():
            self.collectives[n] = self.collectives.get(n, 0.0) + v

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def _comp_costs(comp: Computation, comps: Dict[str, Computation],
                memo: Dict[str, Costs]) -> Costs:
    if comp.name in memo:
        return memo[comp.name]
    total = Costs()
    memo[comp.name] = total  # guards (benign) cycles
    for op in comp.ops:
        code = op.opcode
        if code == "while":
            trip = 1
            m = _TRIP_RE.search(op.line)
            if m:
                trip = int(m.group(1))
            mb = _BODY_RE.search(op.line)
            if mb and mb.group(1) in comps:
                total.add(_comp_costs(comps[mb.group(1)], comps,
                                      memo).scaled(trip))
            mc = _COND_RE.search(op.line)
            if mc and mc.group(1) in comps:
                total.add(_comp_costs(comps[mc.group(1)], comps,
                                      memo).scaled(trip))
            continue
        if code == "call":
            m = _CALL_RE.search(op.line)
            if m and m.group(1) in comps:
                total.add(_comp_costs(comps[m.group(1)], comps, memo))
            continue
        if code == "conditional":
            m = _BRANCH_RE.search(op.line)
            if m:
                names = _NAME_IN_OPERANDS.findall(m.group(1))
                for n in names:
                    if n in comps:
                        total.add(_comp_costs(comps[n], comps, memo))
            continue
        base = code.replace("-start", "")
        if base in _COLLECTIVES and not code.endswith("-done"):
            b = _shape_bytes(op.type_str)
            if base == "all-reduce":
                b *= 2  # ring: reduce-scatter + all-gather passes
            total.collectives[base] = total.collectives.get(base, 0.0) + b
        if code == "dot":
            total.flops += _dot_flops(op, comp.symbols)
        if code not in _SKIP_BYTES_OPS and not code.endswith("-done"):
            result_b = _shape_bytes(op.type_str)
            name_l = op.name.lower()
            operand_bs = [_shape_bytes(comp.symbols.get(n, ""))
                          for n in _operand_names(op)]
            if ("dynamic_update_slice" in name_l
                    or "dynamic-update-slice" in name_l):
                # in-place window write: traffic ~ 2x the update (read +
                # write); the big buffer is aliased, not re-streamed
                small = [x for x in operand_bs if 0 < x < result_b]
                b = 2 * (min(small) if small else result_b)
            elif ("dynamic_slice" in name_l or "dynamic-slice" in name_l
                  or "gather" in name_l):
                # window/element read from a big (often loop-invariant)
                # operand: traffic ~ result + index operands, NOT the
                # whole operand (fixes ~100x overcount on scanned SSMs)
                b = result_b + sum(x for x in operand_bs if x < result_b)
            else:
                b = result_b + sum(operand_bs)
            total.bytes += b
    memo[comp.name] = total
    return total


def xla_cost(compiled) -> Dict[str, float]:
    """XLA's own ``cost_analysis`` as a flat dict (version-normalized).

    Reference numbers only — while bodies are counted ONCE by XLA; use
    ``analyze`` for loop-aware costs.
    """
    from repro.compat import cost_analysis
    return cost_analysis(compiled)


def analyze(hlo: str) -> Costs:
    """Loop-aware per-device costs of a compiled HLO module."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line)
            if m:
                entry = m.group(2)
            break
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].ops))
    # fusion bodies are included in `comps` but never descended into;
    # while/call/conditional targets are reached from ENTRY.
    return _comp_costs(comps[entry], comps, {})
