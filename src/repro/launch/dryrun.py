import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh using ShapeDtypeStruct stand-ins
(no allocation), then extract the roofline terms from the compiled module.

MUST be run as __main__ (or imported before any other jax-touching module)
so the XLA_FLAGS above take effect before jax initializes its backends.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every combo, subprocesses
  python -m repro.launch.dryrun --all --mesh multi
Outputs JSON records under experiments/dryrun/.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import numpy as np

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
         "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
         "f8e5m2": 1, "s16": 2, "u16": 2}

COLLECTIVE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\n]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def collective_bytes(hlo_text: str):
    """Sum per-device result bytes of every collective op, by type."""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * BYTES.get(dtype, 4)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def roofline(cost, coll_bytes_per_dev, n_chips, cfg, shape, kind):
    """The three roofline terms (seconds) + useful-FLOPs ratio."""
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    flops_per_dev = float(cost.get("flops", 0.0) or 0.0)
    bytes_per_dev = float(cost.get("bytes accessed", 0.0) or 0.0)
    t_compute = flops_per_dev / PEAK_FLOPS_BF16
    t_memory = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    # model flops: 6 N_active D for training, 2 N_active per generated token
    n_act = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_act * tokens
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_act * tokens
    else:
        model_flops = 2.0 * n_act * shape.global_batch
    hlo_total = flops_per_dev * n_chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": max(
            [("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)], key=lambda kv: kv[1])[0],
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
    }


def run_one(arch: str, shape_name: str, mesh_kind: str,
            fsdp: bool = True, remat: bool = None,
            fl_step: bool = False, fl_local: int = 1,
            fl_agg_dtype: str = "float32",
            pod_shard_params: bool = False) -> dict:
    import dataclasses

    from repro.configs import SHAPES, get_config, input_specs, supports
    from repro.launch.mesh import make_production_mesh
    from repro.launch.serve import abstract_cache, make_serve_step
    from repro.launch.train import (abstract_params, make_fl_train_step,
                                    make_prefill_step,
                                    make_sharded_train_step)
    from repro.sharding.activations import activation_sharding
    from repro.sharding.specs import batch_axes as mesh_batch_axes

    cfg = get_config(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "fsdp": fsdp, "fl_step": fl_step, "fl_local": fl_local,
           "fl_agg_dtype": fl_agg_dtype, "status": "skipped"}
    if not supports(cfg, shape):
        rec["reason"] = "full-attention arch without sub-quadratic variant"
        return rec
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.perf_counter()

    # batch axes usable for activation constraints (respect divisibility)
    baxes = mesh_batch_axes(multi_pod)
    n_batch = int(np.prod([16 if a == "data" else 2 for a in baxes]))
    if shape.global_batch % n_batch != 0:
        baxes = ("data",) if shape.global_batch % 16 == 0 else ()
    if fl_step:
        # inside the manual-"pod" shard_map region constraints may only
        # name auto axes
        baxes = ("data",)

    with mesh, activation_sharding(mesh, baxes):
        if shape.kind == "train":
            if fl_step:
                step, rep_sh, batch_sh = make_fl_train_step(
                    cfg, mesh, shape, h_local=fl_local,
                    agg_dtype=fl_agg_dtype)
                n_pod = mesh.devices.shape[0]
                params_abs = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct((n_pod,) + x.shape,
                                                   x.dtype),
                    abstract_params(cfg))
                batch_abs = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(
                        (n_pod, x.shape[0] // n_pod) + x.shape[1:], x.dtype),
                    input_specs(cfg, shape))
                lowered = step.lower(params_abs, batch_abs)
            elif False:
                pass
            else:
                step, (param_sh, batch_sh), _ = make_sharded_train_step(
                    cfg, mesh, shape, fsdp=fsdp,
                    pod_shard_params=pod_shard_params)
                params_abs = abstract_params(cfg)
                batch_abs = input_specs(cfg, shape)
                lowered = step.lower(params_abs, batch_abs)
        elif shape.kind == "prefill":
            step, param_sh = make_prefill_step(cfg, mesh, shape)
            params_abs = abstract_params(cfg)
            batch_abs = input_specs(cfg, shape)
            lowered = step.lower(params_abs, batch_abs)
        else:  # decode
            step, _ = make_serve_step(cfg, mesh, shape)
            params_abs = abstract_params(cfg)
            cache_abs = abstract_cache(cfg, shape)
            inp = input_specs(cfg, shape)["inputs"]
            lowered = step.lower(params_abs, cache_abs, inp,
                                 jax.ShapeDtypeStruct((), np.int32))
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    from repro.compat import cost_analysis
    cost = cost_analysis(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_info = {}
    hlo = compiled.as_text()
    from repro.launch import hlo_analysis
    costs = hlo_analysis.analyze(hlo)
    loop_cost = {"flops": costs.flops, "bytes accessed": costs.bytes}
    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware per-device numbers (see hlo_analysis docstring)
        "flops_per_dev": costs.flops,
        "bytes_per_dev": costs.bytes,
        "collective_bytes_per_dev": dict(costs.collectives,
                                         total=costs.collective_total),
        # XLA cost_analysis for reference (while bodies counted ONCE)
        "xla_cost_flops_per_dev": float(cost.get("flops", 0.0) or 0.0),
        "xla_cost_bytes_per_dev": float(cost.get("bytes accessed", 0.0)
                                        or 0.0),
        "memory": mem_info,
        "roofline": roofline(loop_cost, costs.collective_total, n_chips,
                             cfg, shape, shape.kind),
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--pod-shard-params", action="store_true",
                    help="FSDP over (data,pod): halves per-device weight "
                         "memory, trades per-pod FL replica semantics")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fl-step", action="store_true",
                    help="lower the hierarchical-FL train step (paper eq.13)")
    ap.add_argument("--fl-local", type=int, default=1,
                    help="H local steps between aggregations (paper's H)")
    ap.add_argument("--fl-agg-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro.configs import ARCH_IDS, SHAPES
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                tag = f"{arch}_{shape}_{args.mesh}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", args.mesh, "--out", args.out]
                print(f"[run] {tag}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append(tag)
                    print(r.stdout[-2000:])
                    print(r.stderr[-4000:])
        print("failures:", failures)
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    try:
        rec = run_one(args.arch, args.shape, args.mesh,
                      fsdp=not args.no_fsdp,
                      remat=(False if args.no_remat else None),
                      fl_step=args.fl_step, fl_local=args.fl_local,
                      fl_agg_dtype=args.fl_agg_dtype,
                      pod_shard_params=args.pod_shard_params)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": traceback.format_exc()}
    suffix = ("_" + args.tag) if args.tag else ""
    if rec.get("fl_step"):
        suffix += "_flstep"
    tag = f"{args.arch}_{args.shape}_{args.mesh}{suffix}"
    path = os.path.join(args.out, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("error",)}, indent=2))
    if rec["status"] == "error":
        print(rec["error"])
        sys.exit(1)


if __name__ == "__main__":
    main()
