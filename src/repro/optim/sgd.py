"""Plain/momentum SGD on pytrees (optax is unavailable in this container)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return ()
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd_update(params, grads, state, lr, momentum: float = 0.0,
               weight_decay: float = 0.0):
    """Returns (new_params, new_state)."""
    if weight_decay:
        grads = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p, grads, params)
    if momentum == 0.0:
        new_params = jax.tree_util.tree_map(
            lambda p, g: p - lr * g, params, grads)
        return new_params, state
    new_state = jax.tree_util.tree_map(
        lambda m, g: momentum * m + g, state, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m: p - lr * m, params, new_state)
    return new_params, new_state
