"""Uniform optimizer interface used by the trainers."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .adam import adam_init, adam_update
from .sgd import sgd_init, sgd_update


@dataclasses.dataclass
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (params, grads, state, lr) -> (params, state)
    name: str = "sgd"


def make_optimizer(name: str = "sgd", momentum: float = 0.0,
                   weight_decay: float = 0.0, **kw) -> Optimizer:
    if name == "sgd":
        return Optimizer(
            init=lambda p: sgd_init(p, momentum),
            update=lambda p, g, s, lr: sgd_update(
                p, g, s, lr, momentum=momentum, weight_decay=weight_decay),
            name="sgd")
    if name in ("adam", "adamw"):
        wd = weight_decay if name == "adamw" else 0.0
        return Optimizer(
            init=adam_init,
            update=lambda p, g, s, lr: adam_update(
                p, g, s, lr, weight_decay=wd, **kw),
            name=name)
    raise ValueError(f"unknown optimizer {name!r}")
