from .sgd import sgd_init, sgd_update
from .adam import adam_init, adam_update
from .api import Optimizer, make_optimizer

__all__ = ["sgd_init", "sgd_update", "adam_init", "adam_update",
           "Optimizer", "make_optimizer"]
