"""Adam/AdamW on pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8, weight_decay: float = 0.0):
    count = state["count"] + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state["mu"], grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state["nu"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, m, v):
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p
        return p - step

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
