"""Codebase-tuned JAX/NumPy lint rules over Python ASTs.

Rule catalog (ids are stable; severities feed the CLI exit code):

========  ========  ==================================================
id        severity  checks
========  ========  ==================================================
RNG001    error     legacy module-level ``np.random.*`` draws in
                    library/benchmark code (untracked global stream)
RNG002    error     ``jax.random`` key reuse: one key value flowing to
                    two consumers without an intervening ``split`` /
                    ``fold_in``, or consumed inside a loop/
                    comprehension without per-iteration derivation
RNG003    warning   hard-coded ``PRNGKey(<literal>)`` in library code
JIT001    error     ``jax.jit`` / ``jax.pmap`` invoked inside a loop
                    body (fresh wrapper + retrace risk per iteration)
JIT002    error     immediately-invoked ``jax.jit(f)(...)`` (wrapper
                    rebuilt per call; defeats the C++ dispatch path)
JIT003    error     ``static_argnums``/``static_argnames`` binding a
                    parameter with an unhashable (list/dict/set)
                    default, or passing a list/dict/set literal at a
                    static position of a module-local jitted function
DON001    error     read of a buffer after it was passed in a
                    ``donate_argnums`` position (use-after-donate)
HOST001   warning   ``.item()`` / ``float()`` / ``np.asarray()`` on a
                    non-trivial value inside a round/step loop (hidden
                    device->host sync every iteration)
OBS001    error     ``repro.obs`` Tracer/Metrics call inside a
                    jit-decorated (or module-level-jitted) function —
                    runs at trace time, not per execution
SHARD001  error     ``jax.lax`` collective (``psum``/``pmean``/...)
                    with a literal axis name in a function never wired
                    into a ``shard_map``/``pmap`` mesh context in its
                    module (unbound axis at trace time)
RES001    warning   bare ``assert`` in library code (stripped under
                    ``python -O``; resilience paths must fail loudly —
                    raise ``ValueError`` or use
                    ``repro.analysis.contracts``)
TIME001   warning   ``time.time()`` in library/benchmark/example code:
                    wall-clock is NTP-adjustable and coarse — use
                    ``time.perf_counter()`` for durations or the
                    engine's simulated clock for simulated time
========  ========  ==================================================

All rules resolve import aliases (``import numpy as np``, ``from jax
import random as jr``, ...) rather than matching bare attribute text.
Path-sensitivity is deliberately simple: statements are walked in
order, ``if``/``else`` branches analyzed on copies and merged, and
nested function bodies get fresh scopes — tuned to this repository's
idioms, preferring missed corner cases over false positives.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .findings import ERROR, WARNING

LIBRARY, BENCH, TEST, EXAMPLE = "library", "bench", "test", "example"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    severity: str
    kinds: Tuple[str, ...]     # file kinds the rule applies to
    summary: str
    check: Callable            # (FileContext) -> Iterator[(node, message)]


RULES: Dict[str, Rule] = {}


def register(id: str, name: str, severity: str, kinds: Sequence[str],
             summary: str):
    def deco(fn):
        RULES[id] = Rule(id=id, name=name, severity=severity,
                         kinds=tuple(kinds), summary=summary, check=fn)
        return fn
    return deco


@dataclasses.dataclass
class FileContext:
    """Everything a rule needs to know about one source file."""
    path: str                  # display path (posix, relative)
    kind: str                  # library | bench | test | example
    tree: ast.Module
    imports: Dict[str, str]    # local alias -> dotted origin
    donors: Dict[str, Tuple[int, ...]]   # project-wide donating callables


# ---------------------------------------------------------------------------
# Alias resolution
# ---------------------------------------------------------------------------
def build_import_table(tree: ast.Module) -> Dict[str, str]:
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def resolve(node: ast.AST, imports: Dict[str, str]) -> Optional[str]:
    """Dotted origin of a Name/Attribute chain, or None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    base = imports.get(node.id)
    if base is None:
        return None
    return ".".join([base] + list(reversed(parts)))


def _resolve_call(node: ast.Call, imports) -> Optional[str]:
    return resolve(node.func, imports)


def _is_jit_name(origin: Optional[str]) -> bool:
    return origin in ("jax.jit", "jax.pmap")


def _jit_callable_of(node: ast.Call, imports) -> Optional[ast.Call]:
    """Return ``node`` if it is a (possibly partial-wrapped) jit call."""
    origin = _resolve_call(node, imports)
    if _is_jit_name(origin):
        return node
    if origin == "functools.partial" and node.args:
        inner = node.args[0]
        if _is_jit_name(resolve(inner, imports)):
            return node
    return None


def _kwarg(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _const_ints(node: Optional[ast.expr]) -> Optional[Tuple[int, ...]]:
    """Literal int tuple/list value of an argnums expression."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            vals.append(e.value)
        return tuple(vals)
    return None


def _const_strs(node: Optional[ast.expr]) -> Optional[Tuple[str, ...]]:
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            vals.append(e.value)
        return tuple(vals)
    return None


def iter_loops(tree: ast.AST):
    """(loop_node, body_statements) for every for/while loop, at any depth."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            yield node, list(node.body) + list(node.orelse)


def _walk_skip_defs(stmts: Sequence[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function bodies
    (their execution time is unrelated to the enclosing loop's)."""
    stack: List[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# RNG001 — legacy global numpy RNG
# ---------------------------------------------------------------------------
_NPR_ALLOWED = {
    "default_rng", "Generator", "BitGenerator", "SeedSequence",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
}


@register("RNG001", "numpy-global-rng", ERROR, (LIBRARY, BENCH, EXAMPLE),
          "legacy np.random.* draw from the untracked global stream")
def check_rng001(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        origin = _resolve_call(node, ctx.imports)
        if origin is None or not origin.startswith("numpy.random."):
            continue
        fn = origin.split(".")[2] if origin.count(".") >= 2 else ""
        if origin.count(".") == 2 and fn not in _NPR_ALLOWED:
            yield (node,
                   f"legacy global-stream call np.random.{fn}(...): thread "
                   f"an explicit np.random.Generator (default_rng) so seeds "
                   f"stay reproducible across call-order changes")


# ---------------------------------------------------------------------------
# RNG002 — jax PRNG key reuse
# ---------------------------------------------------------------------------
_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "clone"}
_KEY_NONCONSUMING = {"fold_in", "clone", "key_data", "PRNGKey", "key"}
_SAFE_CALLS = {"len", "print", "repr", "str", "id", "type", "isinstance",
               "list", "tuple", "hash"}


@dataclasses.dataclass
class _KeyInfo:
    uses: int = 0
    first_use: Optional[ast.AST] = None


def _param_key_kind(arg: ast.arg, imports) -> Optional[str]:
    """Is this parameter a PRNG key ("n"), a key stack ("a"), or neither?

    Named on the repo's conventions: anything containing "key" is a key;
    bare "rng" is ambiguous (numpy Generators share the name) and is only
    treated as a key when the annotation says so.
    """
    ann = resolve(arg.annotation, imports) if arg.annotation else None
    if ann and ("PRNGKey" in ann or "KeyArray" in ann):
        return "n"
    low = arg.arg.lower()
    if low in ("key", "subkey", "prngkey") or low.endswith("_key"):
        return "n"
    if low in ("keys", "subkeys") or low.endswith("_keys"):
        return "a"
    return None


class _KeyReuseScope:
    """Statement-order key tracking for one function (or module) body."""

    def __init__(self, ctx: FileContext, report):
        self.ctx = ctx
        self.report = report

    # -- key-expression identity -------------------------------------------
    def _key_id(self, node: ast.expr, state) -> Optional[Tuple]:
        if isinstance(node, ast.Name):
            for kind in ("n", "a"):
                if (kind, node.id) in state:
                    return (kind, node.id)
            return None
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, int)
                and ("a", node.value.id) in state):
            # per-index view into a split() stack; tracked lazily
            return ("s", node.value.id, node.slice.value)
        return None

    def _is_key_maker(self, node: ast.expr) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        origin = _resolve_call(node, self.ctx.imports)
        if origin and origin.startswith("jax.random."):
            fn = origin.rsplit(".", 1)[1]
            if fn in _KEY_MAKERS:
                return fn
        return None

    # -- state: dict key-id -> _KeyInfo ------------------------------------
    def run(self, body: Sequence[ast.stmt],
            fn: Optional[ast.AST] = None):
        state: Dict[Tuple, _KeyInfo] = {}
        if fn is not None:
            params = (list(getattr(fn.args, "posonlyargs", []))
                      + list(fn.args.args) + list(fn.args.kwonlyargs))
            for a in params:
                kind = _param_key_kind(a, self.ctx.imports)
                if kind is not None:
                    state[(kind, a.arg)] = _KeyInfo()
        self._walk(body, state, frozen=frozenset())

    def _walk(self, stmts, state, frozen):
        for stmt in stmts:
            self._stmt(stmt, state, frozen)

    def _clear_name(self, name, state):
        for k in [k for k in state
                  if k[1] == name or (k[0] == "s" and k[1] == name)]:
            del state[k]
        state.pop(("a", name), None)

    def _bind(self, target, value, state):
        maker = self._is_key_maker(value)
        if isinstance(target, ast.Name):
            self._clear_name(target.id, state)
            if maker in ("PRNGKey", "key", "fold_in", "clone"):
                state[("n", target.id)] = _KeyInfo()
            elif maker == "split":
                # one name holding a stack of keys: track per-index
                state[("a", target.id)] = _KeyInfo()
        elif isinstance(target, (ast.Tuple, ast.List)):
            if maker == "split":
                for el in target.elts:
                    if isinstance(el, ast.Name):
                        self._clear_name(el.id, state)
                        state[("n", el.id)] = _KeyInfo()
            else:
                for el in target.elts:
                    if isinstance(el, ast.Name):
                        self._clear_name(el.id, state)

    def _use(self, key_id, node, state, frozen):
        base = key_id[1]
        if base in frozen:
            self.report(node,
                        f"PRNG key '{base}' consumed inside a loop but "
                        f"derived outside it — every iteration reuses the "
                        f"same key value; split/fold_in per iteration")
            return
        info = state.get(key_id)
        if info is None:
            if key_id[0] != "s":
                return
            info = state.setdefault(key_id, _KeyInfo())
        info.uses += 1
        if info.uses == 1:
            info.first_use = node
        elif info.uses == 2:
            first = getattr(info.first_use, "lineno", "?")
            self.report(node,
                        f"PRNG key '{base}' reused (first consumed at line "
                        f"{first}) without an intervening split/fold_in — "
                        f"both consumers draw identical randomness")

    # -- expressions --------------------------------------------------------
    def _expr(self, node, state, frozen):
        if node is None:
            return
        if isinstance(node, (ast.Lambda, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            return
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            # comprehension == loop: outer keys consumed per element
            rebound = set()
            for gen in node.generators:
                for t in ast.walk(gen.target):
                    if isinstance(t, ast.Name):
                        rebound.add(t.id)
                self._expr(gen.iter, state, frozen)
            inner_frozen = (frozenset(k[1] for k in state) - rebound) | frozen
            elts = ([node.key, node.value] if isinstance(node, ast.DictComp)
                    else [node.elt])
            for e in elts:
                self._expr(e, state, inner_frozen)
            return
        if isinstance(node, ast.Call):
            origin = _resolve_call(node, self.ctx.imports)
            consuming = True
            if origin and origin.startswith("jax.random."):
                fn = origin.rsplit(".", 1)[1]
                consuming = fn not in _KEY_NONCONSUMING
            elif origin in _SAFE_CALLS or (
                    isinstance(node.func, ast.Name)
                    and node.func.id in _SAFE_CALLS):
                consuming = False
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                key_id = self._key_id(arg, state)
                if key_id is not None and consuming:
                    self._use(key_id, arg, state, frozen)
                else:
                    self._expr(arg, state, frozen)
            self._expr(node.func, state, frozen)
            return
        for child in ast.iter_child_nodes(node):
            self._expr(child, state, frozen)

    # -- statements ---------------------------------------------------------
    def _branch(self, bodies, state, frozen):
        """Analyze exclusive branches on copies; merge use counts by max."""
        snapshots = []
        for body in bodies:
            branch_state = {k: dataclasses.replace(v)
                            for k, v in state.items()}
            self._walk(body, branch_state, frozen)
            snapshots.append(branch_state)
        merged_keys = set()
        for snap in snapshots:
            merged_keys |= set(snap)
        state.clear()
        for k in merged_keys:
            infos = [snap[k] for snap in snapshots if k in snap]
            best = max(infos, key=lambda i: i.uses)
            state[k] = best

    def _loop_rebound(self, body) -> Set[str]:
        rebound = set()
        for node in _walk_skip_defs(body):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            rebound.add(n.id)
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                for n in ast.walk(node.target):
                    if isinstance(n, ast.Name):
                        rebound.add(n.id)
        return rebound

    def _stmt(self, stmt, state, frozen):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _KeyReuseScope(self.ctx, self.report).run(stmt.body, fn=stmt)
            return
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value, state, frozen)
            for t in stmt.targets:
                self._bind(t, stmt.value, state)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._expr(stmt.value, state, frozen)
            self._bind(stmt.target, stmt.value, state)
            return
        if isinstance(stmt, ast.AugAssign):
            self._expr(stmt.value, state, frozen)
            if isinstance(stmt.target, ast.Name):
                self._clear_name(stmt.target.id, state)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr(stmt.iter, state, frozen)
            rebound = self._loop_rebound(stmt.body)
            for n in ast.walk(stmt.target):
                if isinstance(n, ast.Name):
                    rebound.add(n.id)
            inner_frozen = ((frozenset(k[1] for k in state) - rebound)
                            | frozen)
            self._branch([stmt.body], state, inner_frozen)
            self._walk(stmt.orelse, state, frozen)
            return
        if isinstance(stmt, ast.While):
            self._expr(stmt.test, state, frozen)
            rebound = self._loop_rebound(stmt.body)
            inner_frozen = ((frozenset(k[1] for k in state) - rebound)
                            | frozen)
            self._branch([stmt.body], state, inner_frozen)
            self._walk(stmt.orelse, state, frozen)
            return
        if isinstance(stmt, ast.If):
            self._expr(stmt.test, state, frozen)
            self._branch([stmt.body, stmt.orelse], state, frozen)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._expr(item.context_expr, state, frozen)
            self._walk(stmt.body, state, frozen)
            return
        if isinstance(stmt, ast.Try):
            self._walk(stmt.body, state, frozen)
            for h in stmt.handlers:
                self._walk(h.body, state, frozen)
            self._walk(stmt.orelse, state, frozen)
            self._walk(stmt.finalbody, state, frozen)
            return
        if isinstance(stmt, (ast.Return, ast.Expr)):
            self._expr(stmt.value, state, frozen)
            return
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._expr(child, state, frozen)
            elif isinstance(child, ast.stmt):
                self._stmt(child, state, frozen)


@register("RNG002", "jax-key-reuse", ERROR, (LIBRARY, BENCH),
          "one jax.random key value flowing to two consumers")
def check_rng002(ctx: FileContext):
    found: List[Tuple[ast.AST, str]] = []
    scope = _KeyReuseScope(ctx, lambda node, msg: found.append((node, msg)))
    scope.run(ctx.tree.body)
    yield from found


# ---------------------------------------------------------------------------
# RNG003 — hard-coded PRNGKey literal in library code
# ---------------------------------------------------------------------------
@register("RNG003", "hardcoded-prngkey", WARNING, (LIBRARY,),
          "hard-coded PRNGKey(<literal>) in library code")
def check_rng003(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        origin = _resolve_call(node, ctx.imports)
        if origin not in ("jax.random.PRNGKey", "jax.random.key"):
            continue
        if (node.args and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, int)):
            yield (node,
                   f"hard-coded {origin.rsplit('.', 1)[1]}"
                   f"({node.args[0].value}) in library code — thread the "
                   f"seed from config so callers control reproducibility")


# ---------------------------------------------------------------------------
# JIT001 — jit/pmap invoked inside a loop body
# ---------------------------------------------------------------------------
@register("JIT001", "jit-in-loop", ERROR, (LIBRARY, BENCH, EXAMPLE),
          "jax.jit / jax.pmap constructed inside a loop body")
def check_jit001(ctx: FileContext):
    seen: Set[int] = set()
    for loop, body in iter_loops(ctx.tree):
        for node in _walk_skip_defs(body):
            if (isinstance(node, ast.Call) and id(node) not in seen
                    and _jit_callable_of(node, ctx.imports) is not None):
                seen.add(id(node))
                yield (node,
                       "jax.jit constructed inside a loop: a fresh wrapper "
                       "is built (and its trace cache keyed) every "
                       "iteration — hoist the jit out of the loop")


# ---------------------------------------------------------------------------
# JIT002 — immediately-invoked jit
# ---------------------------------------------------------------------------
@register("JIT002", "jit-immediately-invoked", ERROR,
          (LIBRARY, BENCH, EXAMPLE),
          "jax.jit(f)(...) rebuilt at every call site execution")
def check_jit002(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        inner = node.func
        if (isinstance(inner, ast.Call)
                and _is_jit_name(_resolve_call(inner, ctx.imports))):
            yield (node,
                   "immediately-invoked jax.jit(f)(...): the wrapper is "
                   "rebuilt on every execution of this line, defeating the "
                   "C++ dispatch fast path — bind the jitted function once "
                   "and call the bound name")


# ---------------------------------------------------------------------------
# JIT003 — unhashable static args
# ---------------------------------------------------------------------------
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                     ast.SetComp)


def _jit_static_spec(call: ast.Call, imports):
    """(argnums, argnames) literals of a jit/partial-jit call, else None."""
    if _jit_callable_of(call, imports) is None:
        return None
    return (_const_ints(_kwarg(call, "static_argnums")),
            _const_strs(_kwarg(call, "static_argnames")))


def _module_jitted_statics(tree: ast.Module, imports) -> Dict[str, Tuple]:
    """name -> static argnums for module-level ``F = jax.jit(g, ...)``."""
    out = {}
    for stmt in tree.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and isinstance(stmt.value, ast.Call)):
            spec = _jit_static_spec(stmt.value, imports)
            if spec and spec[0]:
                out[stmt.targets[0].id] = spec[0]
    return out


@register("JIT003", "unhashable-static-arg", ERROR, (LIBRARY, BENCH),
          "static jit argument bound to an unhashable value")
def check_jit003(ctx: FileContext):
    # (a) decorated defs whose static parameter has a mutable default
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            spec = _jit_static_spec(deco, ctx.imports)
            if spec is None:
                continue
            argnums, argnames = spec
            params = node.args.args
            defaults = node.args.defaults
            # defaults align with the TAIL of the positional params
            offset = len(params) - len(defaults)
            static_idx = set(argnums or ())
            for name in argnames or ():
                for i, p in enumerate(params):
                    if p.arg == name:
                        static_idx.add(i)
            for i in static_idx:
                di = i - offset
                if 0 <= di < len(defaults) and isinstance(
                        defaults[di], _MUTABLE_LITERALS):
                    yield (defaults[di],
                           f"static argument '{params[i].arg}' of jitted "
                           f"'{node.name}' defaults to an unhashable "
                           f"literal — static args are hashed into the "
                           f"compilation-cache key; use a tuple or None")
    # (b) list/dict/set literal passed at a static position of a
    #     module-local jitted callable
    statics = _module_jitted_statics(ctx.tree, ctx.imports)
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in statics):
            for i in statics[node.func.id]:
                if i < len(node.args) and isinstance(node.args[i],
                                                     _MUTABLE_LITERALS):
                    yield (node.args[i],
                           f"unhashable literal at static position {i} of "
                           f"jitted '{node.func.id}' — raises TypeError at "
                           f"trace time (or silently recompiles if "
                           f"converted); pass a hashable value")


# ---------------------------------------------------------------------------
# DON001 — use-after-donate
# ---------------------------------------------------------------------------
def collect_donors(tree: ast.Module, imports) -> Dict[str, Tuple[int, ...]]:
    """Donating callables defined in this module.

    * ``F = jax.jit(g, donate_argnums=(k,))`` at module level
    * ``@partial(jax.jit, donate_argnums=(k,))`` / ``@jax.jit(...)`` defs
    """
    donors: Dict[str, Tuple[int, ...]] = {}
    for stmt in tree.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and isinstance(stmt.value, ast.Call)
                and _jit_callable_of(stmt.value, imports) is not None):
            nums = _const_ints(_kwarg(stmt.value, "donate_argnums"))
            if nums:
                donors[stmt.targets[0].id] = nums
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            if (isinstance(deco, ast.Call)
                    and _jit_callable_of(deco, imports) is not None):
                nums = _const_ints(_kwarg(deco, "donate_argnums"))
                if nums:
                    donors[node.name] = nums
    return donors


class _DonationScope:
    """Statement-order use-after-donate tracking for one function body."""

    def __init__(self, ctx: FileContext, report):
        self.ctx = ctx
        self.report = report

    def run(self, body):
        self._walk(body, {})

    def _walk(self, stmts, consumed: Dict[str, ast.AST]):
        for stmt in stmts:
            self._stmt(stmt, consumed)

    def _donated_positions(self, call: ast.Call) -> Tuple[int, ...]:
        if isinstance(call.func, ast.Name):
            return self.ctx.donors.get(call.func.id, ())
        if isinstance(call.func, ast.Attribute):
            # method-style or imported-module access: match on the attr
            return self.ctx.donors.get(call.func.attr, ())
        if isinstance(call.func, ast.Call):
            # inline jax.jit(g, donate_argnums=...)(args)
            if _jit_callable_of(call.func, self.ctx.imports) is not None:
                nums = _const_ints(_kwarg(call.func, "donate_argnums"))
                return nums or ()
        return ()

    def _expr(self, node, consumed, reading=True):
        """Walk an expression: report reads of consumed names, then apply
        any donations the expression performs (post-order, so
        ``params = f(params)`` reads before it consumes)."""
        if node is None or isinstance(node, (ast.Lambda, ast.FunctionDef)):
            return
        for sub in ast.walk(node):
            if (reading and isinstance(sub, ast.Name)
                    and isinstance(sub.ctx, ast.Load)
                    and sub.id in consumed):
                don = consumed[sub.id]
                self.report(sub,
                            f"'{sub.id}' read after being donated at line "
                            f"{getattr(don, 'lineno', '?')} — the buffer "
                            f"was consumed by a donate_argnums position "
                            f"and may alias the output; copy before "
                            f"donating or use the returned value")
                del consumed[sub.id]     # one report per donation
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                for pos in self._donated_positions(sub):
                    if pos < len(sub.args) and isinstance(sub.args[pos],
                                                          ast.Name):
                        consumed[sub.args[pos].id] = sub

    def _stmt(self, stmt, consumed):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _DonationScope(self.ctx, self.report).run(stmt.body)
            return
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value, consumed)
            for t in stmt.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        consumed.pop(n.id, None)
            return
        if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            self._expr(stmt.value, consumed)
            if isinstance(stmt.target, ast.Name):
                consumed.pop(stmt.target.id, None)
            return
        if isinstance(stmt, ast.If):
            self._expr(stmt.test, consumed)
            merged: Dict[str, ast.AST] = {}
            for body in (stmt.body, stmt.orelse):
                branch = dict(consumed)
                self._walk(body, branch)
                merged.update(branch)
            consumed.clear()
            consumed.update(merged)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr(stmt.iter, consumed)
            self._walk(stmt.body, consumed)
            self._walk(stmt.orelse, consumed)
            return
        if isinstance(stmt, ast.While):
            self._expr(stmt.test, consumed)
            self._walk(stmt.body, consumed)
            self._walk(stmt.orelse, consumed)
            return
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._expr(item.context_expr, consumed)
            self._walk(stmt.body, consumed)
            return
        if isinstance(stmt, ast.Try):
            self._walk(stmt.body, consumed)
            for h in stmt.handlers:
                self._walk(h.body, consumed)
            self._walk(stmt.orelse, consumed)
            self._walk(stmt.finalbody, consumed)
            return
        if isinstance(stmt, ast.Return):
            self._expr(stmt.value, consumed)
            return
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._expr(child, consumed)
            elif isinstance(child, ast.stmt):
                self._stmt(child, consumed)


@register("DON001", "use-after-donate", ERROR, (LIBRARY, BENCH),
          "buffer read after being passed in a donate_argnums position")
def check_don001(ctx: FileContext):
    found: List[Tuple[ast.AST, str]] = []
    scope = _DonationScope(ctx, lambda node, msg: found.append((node, msg)))
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope.run(node.body)
    yield from found


# ---------------------------------------------------------------------------
# HOST001 — host sync inside round/step loops
# ---------------------------------------------------------------------------
_ROUND_NAMES = {"r", "rnd", "round", "round_index", "step", "epoch", "t",
                "i_round", "n_round"}
_ROUND_HINTS = ("round", "step", "epoch")


def _is_round_loop(loop) -> bool:
    if isinstance(loop, (ast.For, ast.AsyncFor)):
        names = {n.id for n in ast.walk(loop.target)
                 if isinstance(n, ast.Name)}
        if names & _ROUND_NAMES:
            return True
        src_names = {getattr(n, "attr", getattr(n, "id", ""))
                     for n in ast.walk(loop.iter)}
    else:
        src_names = {getattr(n, "attr", getattr(n, "id", ""))
                     for n in ast.walk(loop.test)}
    return any(h in (name or "").lower()
               for name in src_names for h in _ROUND_HINTS)


_HOST_SYNC_CASTS = {"float", "int", "bool", "complex"}


@register("HOST001", "host-sync-in-loop", WARNING, (LIBRARY,),
          "device->host sync every iteration of a round/step loop")
def check_host001(ctx: FileContext):
    seen: Set[int] = set()
    for loop, body in iter_loops(ctx.tree):
        if not _is_round_loop(loop):
            continue
        for node in _walk_skip_defs(body):
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            msg = None
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                msg = ".item() inside a round/step loop"
            elif (isinstance(node.func, ast.Name)
                    and node.func.id in _HOST_SYNC_CASTS
                    and len(node.args) == 1
                    and isinstance(node.args[0],
                                   (ast.Name, ast.Attribute, ast.Subscript))):
                msg = (f"{node.func.id}(...) on a computed value inside a "
                       f"round/step loop")
            else:
                origin = _resolve_call(node, ctx.imports)
                if origin in ("numpy.asarray", "numpy.array",
                              "jax.device_get") and node.args:
                    msg = (f"{origin.replace('numpy', 'np')}(...) inside a "
                           f"round/step loop")
            if msg:
                seen.add(id(node))
                yield (node,
                       f"{msg}: forces a device->host transfer and blocks "
                       f"dispatch every iteration — accumulate on device "
                       f"and read out after the loop")


# ---------------------------------------------------------------------------
# OBS001 — tracer/metrics call inside a jitted function
# ---------------------------------------------------------------------------
_OBS_METHODS = {"span", "event", "set_context", "flush", "counter", "gauge",
                "histogram", "inc", "set", "observe", "wall_now"}
_OBS_RECEIVERS = ("tracer", "metrics")


def _is_obs_call(node: ast.Call, imports) -> bool:
    """A call into ``repro.obs`` (resolved import) or a method call whose
    receiver chain names a tracer/metrics object (``tracer.span(...)``,
    ``self.tracer.event(...)``, ``m.counter("x").inc()``)."""
    origin = _resolve_call(node, imports)
    if origin is not None and (origin.startswith("repro.obs.")
                               or origin == "repro.obs"):
        return True
    f = node.func
    if not (isinstance(f, ast.Attribute) and f.attr in _OBS_METHODS):
        return False
    for sub in ast.walk(f.value):
        ident = None
        if isinstance(sub, ast.Name):
            ident = sub.id
        elif isinstance(sub, ast.Attribute):
            ident = sub.attr
        if ident is not None:
            low = ident.lower()
            if any(low == r or low.endswith("_" + r) or low == "_" + r
                   for r in _OBS_RECEIVERS):
                return True
    return False


def _jitted_function_defs(tree: ast.Module, imports):
    """Function defs whose body runs under tracing: jit-decorated defs,
    plus defs bound by module-level ``F = jax.jit(g)``."""
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    seen: Set[int] = set()
    for node in defs.values():
        for deco in node.decorator_list:
            jitted = (_is_jit_name(resolve(deco, imports))
                      or (isinstance(deco, ast.Call)
                          and _jit_callable_of(deco, imports) is not None))
            if jitted and id(node) not in seen:
                seen.add(id(node))
                yield node
    for stmt in tree.body:
        if (isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call)
                and _jit_callable_of(stmt.value, imports) is not None):
            args = stmt.value.args
            # jax.jit(g, ...) and partial(jax.jit, ...)(g) both put the
            # traced callable in the first positional argument
            target = args[0] if args else None
            if (_resolve_call(stmt.value, imports) == "functools.partial"
                    and len(args) >= 2):
                target = args[1]
            if (isinstance(target, ast.Name) and target.id in defs
                    and id(defs[target.id]) not in seen):
                seen.add(id(defs[target.id]))
                yield defs[target.id]


@register("OBS001", "obs-call-in-jit", ERROR, (LIBRARY, BENCH),
          "repro.obs Tracer/Metrics call inside a jitted function")
def check_obs001(ctx: FileContext):
    for fn in _jitted_function_defs(ctx.tree, ctx.imports):
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and _is_obs_call(node, ctx.imports)):
                continue
            # chained instrument calls (metrics.counter("x").inc()) match
            # twice; report only the innermost of the chain
            if (isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Call)
                    and _is_obs_call(node.func.value, ctx.imports)):
                continue
            yield (node,
                       f"tracer/metrics call inside jitted '{fn.name}': "
                       f"the Python call runs once at TRACE time (and "
                       f"again per retrace), not per execution — spans/"
                       f"metrics recorded here are wrong and a host "
                       f"callback would break async dispatch; hoist the "
                       f"instrumentation outside the compiled function")


# ---------------------------------------------------------------------------
# SHARD001: collective with a literal axis name outside shard_map context
# ---------------------------------------------------------------------------
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather",
                "psum_scatter"}


def _is_shard_map_origin(origin: Optional[str]) -> bool:
    return origin is not None and (origin == "shard_map"
                                   or origin.endswith(".shard_map"))


def _collective_axis_arg(node: ast.Call) -> Optional[ast.expr]:
    """The axis-name argument of a ``jax.lax`` collective call (second
    positional, or the ``axis_name`` keyword)."""
    for kw in node.keywords:
        if kw.arg == "axis_name":
            return kw.value
    if len(node.args) >= 2:
        return node.args[1]
    return None


def _literal_axis_names(node: Optional[ast.expr]) -> Optional[List[str]]:
    """String-literal axis names of a collective call, or None when the
    axis flows in through a variable (helpers like
    ``hierarchical_weighted_psum`` take the axes as a parameter and are
    exercised under a caller's mesh — out of static reach, skipped)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        names = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            names.append(elt.value)
        return names or None
    return None


# ---------------------------------------------------------------------------
# RES001 — bare assert in library code
# ---------------------------------------------------------------------------
@register("RES001", "assert-in-library", WARNING, (LIBRARY,),
          "bare assert in library code vanishes under python -O")
def check_res001(ctx: FileContext):
    """``assert`` compiles to nothing under ``python -O``, so a guard
    written as one silently stops guarding in optimized runs — the
    opposite of what the resilience subsystem needs (faults must fail
    LOUDLY so recovery paths can engage).  Library code should raise
    ``ValueError``/``TypeError`` or route through
    ``repro.analysis.contracts``; ``assert`` stays fine in tests (where
    pytest rewrites it) and scratch/bench code."""
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert):
            yield (node,
                   "bare assert in library code — stripped under "
                   "python -O, so the guard silently disappears; raise "
                   "ValueError (or use repro.analysis.contracts) so "
                   "invalid state fails loudly in every mode")


@register("SHARD001", "collective-outside-shard-map", ERROR,
          (LIBRARY, BENCH, EXAMPLE),
          "jax.lax collective with a literal axis name outside any "
          "shard_map context")
def check_shard001(ctx: FileContext):
    """``jax.lax.psum``/``pmean``/... with a LITERAL axis name is only
    meaningful inside a manual-mesh program: the axis must be bound by a
    ``shard_map`` (or ``pmap``) enclosing the traced function.  A
    collective whose enclosing function is never wired into one fails at
    runtime with an unbound-axis error — or worse, gets copy-pasted into
    a single-device path where it silently never reduces.

    A function counts as shard_map context when (in this module) it is
    passed to ``shard_map``/``pmap`` by name, or it lexically contains a
    ``shard_map``/``pmap`` call (the closure-factory idiom of
    ``CohortEngine._make_sharded_step``/``make_replica_agg_step``).
    Axis names arriving through parameters are skipped — preferring
    missed corner cases over false positives, per the module docstring.
    """
    imports = ctx.imports

    def _is_binder(origin: Optional[str]) -> bool:
        return _is_shard_map_origin(origin) or origin in (
            "jax.pmap", "jax.experimental.maps.xmap")

    # functions passed to shard_map/pmap by name anywhere in the module
    wired: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _is_binder(
                _resolve_call(node, imports)):
            if node.args and isinstance(node.args[0], ast.Name):
                wired.add(node.args[0].id)

    def _contains_binder(fn: ast.AST) -> bool:
        return any(isinstance(n, ast.Call)
                   and _is_binder(_resolve_call(n, imports))
                   for n in ast.walk(fn))

    fn_types = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

    def visit(node: ast.AST, covered: bool):
        if isinstance(node, fn_types):
            name = getattr(node, "name", None)
            covered = (covered or name in wired
                       or _contains_binder(node))
        for child in ast.iter_child_nodes(node):
            yield from visit(child, covered)
        if not (isinstance(node, ast.Call) and not covered):
            return
        origin = _resolve_call(node, imports)
        if origin is None or not origin.startswith("jax.lax."):
            return
        op = origin.rsplit(".", 1)[1]
        if op not in _COLLECTIVES:
            return
        names = _literal_axis_names(_collective_axis_arg(node))
        if not names:
            return
        yield (node,
               f"jax.lax.{op} over axis {names!r} outside any shard_map/"
               f"pmap context: no enclosing function is wired into a "
               f"mesh here, so the axis name is unbound at trace time; "
               f"dispatch through shard_map (repro.compat.shard_map) or "
               f"take the axis names as a parameter like "
               f"repro.fl.aggregation.hierarchical_weighted_psum")

    yield from visit(ctx.tree, False)


# ---------------------------------------------------------------------------
# TIME001 — wall-clock used where a measurement is implied
# ---------------------------------------------------------------------------
@register("TIME001", "wall-clock-for-durations", WARNING,
          (LIBRARY, BENCH, EXAMPLE),
          "time.time() in measurement code (non-monotonic, coarse)")
def check_time001(ctx: FileContext):
    """Every ``time.time()`` call in library/bench/example code.

    ``time.time()`` is adjustable wall-clock (NTP slew, DST, manual
    resets) with platform-dependent resolution — a duration measured
    with it can come out negative.  This stack measures two kinds of
    time and has a right answer for both: ``time.perf_counter()`` for
    wall durations (the ``benchmarks.common.timeit_min`` / gateway
    ``wall_infer`` discipline) and the simulated clock
    (``trainer.wall_clock`` / span ``t_sim``) for simulated time.
    Genuine epoch timestamps are rare enough to baseline explicitly.
    """
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Call)
                and _resolve_call(node, ctx.imports) == "time.time"):
            yield (node,
                   "time.time() is non-monotonic wall-clock (NTP slew "
                   "can run it backwards) with coarse resolution: use "
                   "time.perf_counter() for durations, or the simulated "
                   "clock (trainer.wall_clock / span t_sim) for "
                   "simulated time; baseline the rare real timestamp")
