"""JAX-aware static analysis + runtime contracts for the repro codebase.

Two halves, one invariant set:

* :mod:`repro.analysis.rules` / :mod:`repro.analysis.engine` — an
  AST-based linter (``python -m repro.analysis``) catching RNG
  indiscipline, recompile hazards, donation bugs, and host-sync smells
  *before* they run.
* :mod:`repro.analysis.contracts` — runtime context managers
  (``no_recompile``, ``assert_donated``, ``nan_tripwire``) asserting
  the same invariants *while* they run, used by ``CohortEngine``, the
  benchmark runners, and the test suite.
"""
from .engine import classify, discover, scan
from .findings import (DEFAULT_BASELINE, ERROR, WARNING, Finding,
                       apply_baseline, load_baseline, render_json,
                       render_text, sort_findings, write_baseline)
from .rules import RULES, Rule

__all__ = [
    "classify", "discover", "scan",
    "DEFAULT_BASELINE", "ERROR", "WARNING", "Finding",
    "apply_baseline", "load_baseline", "render_json", "render_text",
    "sort_findings", "write_baseline",
    "RULES", "Rule",
]
