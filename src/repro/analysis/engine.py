"""File discovery, file-kind classification, and the two-pass scan.

Pass 1 parses every file and collects project-wide *donating callables*
(``jax.jit(..., donate_argnums=...)`` bindings), so DON001 can flag a
use-after-donate even when the donating function is imported from a
sibling module (the repo's real layout: ``cohort_round_step_donated``
lives in ``fl/client.py`` and is consumed by ``fl/cohort_engine.py``).
Pass 2 runs every registered rule whose ``kinds`` include the file's
kind.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .findings import ERROR, Finding, sort_findings
from .rules import (BENCH, EXAMPLE, LIBRARY, RULES, TEST, FileContext,
                    build_import_table, collect_donors)

_SKIP_DIRS = {".git", "__pycache__", ".venv", "venv", "build", "dist",
              ".eggs", "node_modules"}


def classify(path: Path) -> str:
    """File kind from its path: test / example / bench / library."""
    parts = [p.lower() for p in path.parts]
    name = path.name.lower()
    if ("tests" in parts or name.startswith("test_")
            or name.startswith("conftest")):
        return TEST
    if "examples" in parts or "docs" in parts:
        return EXAMPLE
    if "benchmarks" in parts or "bench" in parts:
        return BENCH
    return LIBRARY


def discover(paths: Sequence[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    files.append(f)
        elif p.suffix == ".py":
            files.append(p)
    # stable order, no duplicates
    seen, out = set(), []
    for f in files:
        key = f.resolve()
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _display(path: Path, root: Optional[Path]) -> str:
    try:
        rel = path.resolve().relative_to((root or Path.cwd()).resolve())
        return rel.as_posix()
    except ValueError:
        return path.as_posix()


def _parse(path: Path) -> Tuple[Optional[ast.Module], Optional[str]]:
    try:
        return ast.parse(path.read_text(encoding="utf-8")), None
    except SyntaxError as e:
        return None, f"syntax error: {e.msg} (line {e.lineno})"
    except (OSError, UnicodeDecodeError) as e:
        return None, f"unreadable: {e}"


def scan(paths: Sequence[Path], root: Optional[Path] = None,
         rule_ids: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run the lint rules over ``paths`` (files or directories)."""
    files = discover([Path(p) for p in paths])
    parsed: List[Tuple[Path, str, ast.Module, Dict[str, str]]] = []
    findings: List[Finding] = []

    # pass 1: parse + project-wide donor table
    donors: Dict[str, Tuple[int, ...]] = {}
    for f in files:
        tree, err = _parse(f)
        display = _display(f, root)
        if tree is None:
            findings.append(Finding(rule="PARSE", severity=ERROR,
                                    path=display, line=1, col=0,
                                    message=err or "unparseable"))
            continue
        imports = build_import_table(tree)
        donors.update(collect_donors(tree, imports))
        parsed.append((f, display, tree, imports))

    # pass 2: rules
    active = [RULES[r] for r in (rule_ids or sorted(RULES))]
    for f, display, tree, imports in parsed:
        ctx = FileContext(path=display, kind=classify(f), tree=tree,
                          imports=imports, donors=donors)
        for rule in active:
            if ctx.kind not in rule.kinds:
                continue
            for node, message in rule.check(ctx):
                findings.append(Finding(
                    rule=rule.id, severity=rule.severity, path=display,
                    line=getattr(node, "lineno", 1),
                    col=getattr(node, "col_offset", 0), message=message))
    return sort_findings(findings)
