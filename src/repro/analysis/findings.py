"""Finding records, severities, output formats, and the baseline file.

A :class:`Finding` is one rule violation at one source location.  The
baseline file is the suppression mechanism for *accepted* findings
(ruff's ``--add-noqa`` equivalent, kept out-of-band so the source stays
clean): a JSON list of ``path:rule:line`` keys.  ``python -m
repro.analysis --write-baseline`` regenerates it; the CI lane loads the
committed one, so only findings introduced after the baseline was
written can fail the lane.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

ERROR = "error"
WARNING = "warning"
SEVERITIES = (ERROR, WARNING)

BASELINE_VERSION = 1
DEFAULT_BASELINE = ".repro-analysis-baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    severity: str            # "error" | "warning"
    path: str                # posix-style, relative to the invocation root
    line: int                # 1-based
    col: int                 # 0-based (ast convention)
    message: str

    @property
    def key(self) -> str:
        """Baseline identity: location + rule (message text may evolve)."""
        return f"{self.path}:{self.rule}:{self.line}"

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def format_text(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} [{self.severity}] {self.message}")


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def render_text(findings: Sequence[Finding]) -> str:
    lines = [f.format_text() for f in sort_findings(findings)]
    n_err = sum(1 for f in findings if f.severity == ERROR)
    n_warn = len(findings) - n_err
    lines.append(f"{len(findings)} finding(s): {n_err} error(s), "
                 f"{n_warn} warning(s)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding]) -> str:
    return json.dumps(
        {"version": BASELINE_VERSION,
         "findings": [f.to_dict() for f in sort_findings(findings)]},
        indent=2) + "\n"


# ---------------------------------------------------------------------------
# Baseline I/O
# ---------------------------------------------------------------------------
def load_baseline(path: Path) -> Set[str]:
    """Read the accepted-finding keys from a baseline file."""
    data = json.loads(Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version "
                         f"{data.get('version')!r} in {path}")
    return set(data["suppressed"])


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    """Accept every current finding: subsequent runs with this baseline
    report only NEW findings."""
    data = {
        "version": BASELINE_VERSION,
        "tool": "repro.analysis",
        "suppressed": sorted({f.key for f in findings}),
    }
    Path(path).write_text(json.dumps(data, indent=2) + "\n")


def apply_baseline(findings: Sequence[Finding],
                   suppressed: Optional[Set[str]]) -> List[Finding]:
    """Drop findings whose key the baseline accepts."""
    if not suppressed:
        return list(findings)
    return [f for f in findings if f.key not in suppressed]
