"""Runtime contracts: the linter's invariants, enforced while running.

Three reusable context managers, generalizing the PR-4 recompile lock so
``CohortEngine``, ``RegionTrainer``, the benchmark runners, and the test
suite all assert the same invariants through one door:

* :func:`no_recompile` — no (or at most ``allow``) new jit lowerings
  inside the block.  Backed by a passive ``jax.monitoring`` compile-
  event listener (warm dispatches stay on the C++ fast path — the
  contract adds no per-call cost, so engines can arm it on every
  round), falling back to jax's internal test-utility lowering
  counters, then degrading to an inert pass-through (with a warning)
  rather than breaking when jax internals move.
* :func:`assert_donated` — every watched buffer was actually consumed
  by a ``donate_argnums`` position inside the block.  On backends where
  donation is a documented no-op (CPU) the failure downgrades to a
  warning unless ``strict=True``.
* :func:`nan_tripwire` — flips ``jax_debug_nans`` / ``jax_debug_infs``
  for the block so non-finite values raise at the producing op instead
  of corrupting a merge rounds later.

Violations raise :class:`ContractViolation` (an ``AssertionError``
subclass, so pytest reports them natively).
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Iterator, Optional

import jax


class ContractViolation(AssertionError):
    """A runtime invariant asserted by repro.analysis.contracts failed."""


# ---------------------------------------------------------------------------
# no_recompile
# ---------------------------------------------------------------------------
class RecompileCount:
    """Live view of the lowering count inside a ``no_recompile`` block."""

    def __init__(self, get=None):
        self._get = get        # zero-arg count reader, None = unenforced
        self.enforced = get is not None

    @property
    def count(self) -> int:
        return int(self._get()) if self._get is not None else 0


# Monitoring-based counter: one module-level listener bumps a monotone
# count on every jaxpr trace / backend compile; blocks snapshot it on
# entry.  Listeners are passive — jit's warm C++ fast path is untouched
# (the jtu fallback counters below patch the dispatch internals and cost
# a few hundred microseconds per call inside the block).
_COMPILE_EVENTS = ("/jax/core/compile/jaxpr_trace_duration",
                   "/jax/core/compile/backend_compile_duration")
_event_count = 0
_listener_installed = False


def _install_compile_listener() -> bool:
    global _listener_installed
    if _listener_installed:
        return True
    try:
        from jax._src import monitoring
    except Exception:                                    # pragma: no cover
        return False

    def _on_event(event: str, duration_secs: float = 0.0, **kw) -> None:
        global _event_count
        if event in _COMPILE_EVENTS:
            _event_count += 1

    monitoring.register_event_duration_secs_listener(_on_event)
    _listener_installed = True
    return True


def _lowering_counter():
    """Best available jit-lowering counter from jax's test utilities.

    Ordered by fidelity; each is a context manager yielding a one-element
    list holding the event count.
    """
    try:
        from jax._src import test_util as jtu
    except Exception:                                    # pragma: no cover
        return None
    for name in ("count_jit_and_pmap_lowerings",
                 "count_jit_and_pmap_compiles",          # older spelling
                 "count_jit_tracing_cache_miss"):
        counter = getattr(jtu, name, None)
        if counter is not None:
            return counter
    return None                                          # pragma: no cover


@contextlib.contextmanager
def no_recompile(allow: int = 0,
                 label: Optional[str] = None) -> Iterator[RecompileCount]:
    """Assert that at most ``allow`` new jit lowerings happen in here.

    A *lowering* is jax building a new executable: the warm path of a
    round loop must trigger none, so any count above ``allow`` means a
    shape/dtype/static-arg signature silently churned.  One fresh
    compile scores a small bounded number of events (trace + backend
    compile), not exactly 1 — size ``allow`` budgets accordingly.
    Yields a :class:`RecompileCount` whose ``.count`` is readable after
    the block.
    """
    if _install_compile_listener():
        start = _event_count
        view = RecompileCount(lambda: _event_count - start)
        yield view
    else:                                                # pragma: no cover
        counter = _lowering_counter()
        if counter is None:
            warnings.warn(
                "no_recompile(): jax lowering counters unavailable in "
                "this jax version; contract not enforced", RuntimeWarning,
                stacklevel=3)
            yield RecompileCount(None)
            return
        with counter() as box:
            view = RecompileCount(lambda: int(box[0]))
            yield view
    n = view.count
    if n > allow:
        where = f" [{label}]" if label else ""
        raise ContractViolation(
            f"no_recompile{where}: {n} new jit lowering(s) inside a "
            f"block that allows {allow} — a compilation-cache signature "
            f"(shape, dtype, static arg, or callable identity) changed "
            f"on the warm path")


# ---------------------------------------------------------------------------
# assert_donated
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def assert_donated(*trees, label: Optional[str] = None,
                   strict: Optional[bool] = None) -> Iterator[None]:
    """Assert every array in ``trees`` was donated inside the block.

    A donated buffer is deleted by the runtime (``arr.is_deleted()``),
    so any watched leaf still live after the block means the donation
    silently did not happen — the in-place fast path is quietly running
    at double memory.  On CPU, where jax documents donation as a no-op,
    the failure is reported as a :class:`RuntimeWarning` instead unless
    ``strict=True``.
    """
    leaves = [leaf for tree in trees
              for leaf in jax.tree_util.tree_leaves(tree)]
    yield
    live = [leaf for leaf in leaves
            if hasattr(leaf, "is_deleted") and not leaf.is_deleted()]
    if not live:
        return
    if strict is None:
        strict = jax.default_backend() != "cpu"
    where = f" [{label}]" if label else ""
    msg = (f"assert_donated{where}: {len(live)}/{len(leaves)} watched "
           f"buffer(s) still live after the block — donation did not "
           f"happen (backend: {jax.default_backend()})")
    if strict:
        raise ContractViolation(msg)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# nan_tripwire
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def nan_tripwire(infs: bool = True) -> Iterator[None]:
    """Raise at the op that produces a NaN (optionally inf) in here.

    Flips ``jax_debug_nans`` (and ``jax_debug_infs``) for the dynamic
    extent of the block; previous settings are restored on exit.  Note
    jax re-runs offending computations un-jitted to localize the bad op,
    so keep this off hot paths in production runs.
    """
    old_nans = jax.config.jax_debug_nans
    old_infs = jax.config.jax_debug_infs
    jax.config.update("jax_debug_nans", True)
    if infs:
        jax.config.update("jax_debug_infs", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", old_nans)
        jax.config.update("jax_debug_infs", old_infs)


def assert_finite(tree, label: Optional[str] = None) -> None:
    """Eager check that every leaf of ``tree`` is finite.

    The explicit complement to :func:`nan_tripwire` for values computed
    *before* entering a guarded block (e.g. params arriving over an ISL
    merge): one device round-trip, raises :class:`ContractViolation`
    naming the offending leaf count.
    """
    import jax.numpy as jnp
    bad = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = jnp.asarray(leaf)
        if arr.dtype.kind == "f" and not bool(jnp.isfinite(arr).all()):
            bad += 1
    if bad:
        where = f" [{label}]" if label else ""
        raise ContractViolation(
            f"assert_finite{where}: {bad} leaf array(s) contain "
            f"NaN/inf")
