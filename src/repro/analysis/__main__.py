"""CLI: ``python -m repro.analysis [paths] --format text|json ...``

Exit codes: 0 clean (or only baselined / warning-severity findings),
1 unsuppressed error-severity findings (``--strict`` promotes warnings),
2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from .engine import scan
from .findings import (DEFAULT_BASELINE, ERROR, Finding, apply_baseline,
                       load_baseline, render_json, render_text,
                       write_baseline)
from .rules import RULES


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analysis: RNG discipline, recompile "
                    "hazards, donation safety, host-sync smells.")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to scan (default: src)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--baseline", metavar="FILE", default=None,
                   help=f"baseline of accepted findings (default: "
                        f"{DEFAULT_BASELINE} if present)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore any baseline file")
    p.add_argument("--write-baseline", metavar="FILE", nargs="?",
                   const=DEFAULT_BASELINE, default=None,
                   help="accept all current findings into FILE and exit 0")
    p.add_argument("--select", metavar="RULES", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on warnings too, not just errors")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = _parser().parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            r = RULES[rid]
            print(f"{r.id}  {r.severity:7s}  {r.name}: {r.summary} "
                  f"(applies to: {', '.join(r.kinds)})")
        return 0

    if args.select:
        unknown = [r for r in args.select.split(",") if r not in RULES]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2
        rule_ids = args.select.split(",")
    else:
        rule_ids = None

    for path in args.paths:
        if not Path(path).exists():
            print(f"no such path: {path}", file=sys.stderr)
            return 2

    findings = scan(args.paths, rule_ids=rule_ids)

    if args.write_baseline is not None:
        write_baseline(Path(args.write_baseline), findings)
        print(f"wrote {len(findings)} accepted finding(s) to "
              f"{args.write_baseline}")
        return 0

    if not args.no_baseline:
        baseline_path = args.baseline or (
            DEFAULT_BASELINE if Path(DEFAULT_BASELINE).exists() else None)
        if baseline_path is not None:
            try:
                suppressed = load_baseline(Path(baseline_path))
            except (OSError, ValueError, KeyError) as e:
                print(f"bad baseline {baseline_path}: {e}", file=sys.stderr)
                return 2
            findings = apply_baseline(findings, suppressed)

    out = (render_json(findings) if args.format == "json"
           else render_text(findings))
    print(out, end="" if out.endswith("\n") else "\n")

    def fails(f: Finding) -> bool:
        return f.severity == ERROR or args.strict
    return 1 if any(fails(f) for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
