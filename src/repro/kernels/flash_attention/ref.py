"""Pure-jnp oracle: causal (optionally sliding-window) GQA attention."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True,
              window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.

    Returns (B, Hq, S, D). ``window`` limits attention to the last
    ``window`` positions (sliding-window attention).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (cols <= rows)
    if window is not None:
        mask = mask & (cols > rows - window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, window: Optional[int] = None,
                      block: int = 1024) -> jnp.ndarray:
    """Flash-style blocked attention in pure jnp (lax.scan over KV blocks).

    Numerically matches ``attention`` but never materializes the (S, S)
    score matrix — this is the lowering path used on large sequences so the
    compiled HLO has the same memory behaviour as the TPU Pallas kernel.
    """
    import jax

    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    block = min(block, s)
    assert s % block == 0
    n_blocks = s // block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    kb = k.reshape(b, hkv, n_blocks, block, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, n_blocks, block, d).transpose(2, 0, 1, 3, 4)
    rows = jnp.arange(s)[:, None]
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        j, k_j, v_j = inp
        k_j = jnp.repeat(k_j.astype(jnp.float32), group, axis=1)
        v_j = jnp.repeat(v_j.astype(jnp.float32), group, axis=1)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qf, k_j) * scale
        cols = j * block + jnp.arange(block)[None, :]
        mask = jnp.ones((s, block), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, v_j)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hq, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, s), jnp.float32)
    a0 = jnp.zeros((b, hq, s, d), jnp.float32)
    # checkpoint the KV-block step: backward recomputes the (S, block)
    # probability tensors instead of saving one per block
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(n_blocks), kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)
