"""Dispatching wrapper: Pallas flash attention on TPU, jnp oracle elsewhere."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel, ref


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True,
              window: Optional[int] = None) -> jnp.ndarray:
    """Causal / sliding-window GQA attention.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D). On TPU this lowers to the
    VMEM-tiled Pallas kernel; elsewhere (CPU dry-run/tests) to the oracle.
    """
    if jax.default_backend() == "tpu" and q.shape[2] % 128 == 0:
        return kernel.flash_attention(q, k, v, causal=causal, window=window)
    s = q.shape[2]
    if s >= 4096 and s % 1024 == 0:
        # flash-equivalent lowering path: no (S, S) score materialization
        return ref.blocked_attention(q, k, v, causal=causal, window=window)
    return ref.attention(q, k, v, causal=causal, window=window)
