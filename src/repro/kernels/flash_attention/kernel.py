"""Pallas TPU kernel: causal / sliding-window GQA flash attention.

Grid (B, Hq, Sq/BQ, Skv/BK); the KV grid axis is innermost so the f32
online-softmax accumulators (m, l, acc) live in VMEM scratch and persist
across KV steps (TPU grids execute sequentially, last axis fastest). GQA is
expressed in the KV BlockSpec index maps (q-head -> kv-head), so no KV
replication ever reaches memory. Block shapes are MXU-aligned (128x128 by
default); the working set per step is BQ*D + 2*BK*D + BQ*BK f32 ->
~192 KiB at (128,128,128), well inside the ~16 MiB VMEM budget with room
for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (cols <= rows)
    if window is not None:
        mask = mask & (cols > rows - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (BQ, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (BQ, BK)
    # fully-masked rows would otherwise contribute exp(NEG_INF - NEG_INF)=1
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D). Returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, "seq must divide block size"
    n_q, n_kv = s // bq, s // bk
    scale = float(1.0 / (d ** 0.5))

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
