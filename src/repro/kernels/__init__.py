"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three modules:
  kernel.py — the pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit-friendly wrapper: dispatches to the kernel on TPU,
              to the pure-jnp oracle elsewhere (incl. the CPU dry-run)
  ref.py    — the pure-jnp oracle used for interpret-mode validation

Kernels: fedavg_agg (eq. 13 weighted aggregation), flash_attention
(causal/sliding-window GQA attention), wkv6 (RWKV6 recurrence).
"""
