"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head of dim d, with data-dependent per-channel decay w_t in (0,1):

    S_0 = 0                       (d x d state)
    o_t = r_t @ (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
        u: jnp.ndarray) -> jnp.ndarray:
    """r,k,v,w: (B, H, T, D); u: (H, D). Returns (B, H, T, D)."""
    def per_head(r_h, k_h, v_h, w_h, u_h):
        d = r_h.shape[-1]

        def step(s, inp):
            r_t, k_t, v_t, w_t = inp
            kv = jnp.outer(k_t, v_t)
            o = r_t @ (s + u_h[:, None] * kv)
            s = w_t[:, None] * s + kv
            return s, o

        s0 = jnp.zeros((d, d), jnp.float32)
        _, o = jax.lax.scan(step, s0, (r_h.astype(jnp.float32),
                                       k_h.astype(jnp.float32),
                                       v_h.astype(jnp.float32),
                                       w_h.astype(jnp.float32)))
        return o

    out = jax.vmap(jax.vmap(per_head, in_axes=(0, 0, 0, 0, 0)),
                   in_axes=(0, 0, 0, 0, None))(r, k, v, w, u)
    return out.astype(r.dtype)


def wkv_step(s: jnp.ndarray, r_t, k_t, v_t, w_t, u):
    """Single decode step. s: (B,H,D,D); r_t..w_t: (B,H,D); u: (H,D).

    Returns (new_state, out (B,H,D)).
    """
    kv = jnp.einsum("bhi,bhj->bhij", k_t.astype(jnp.float32),
                    v_t.astype(jnp.float32))
    o = jnp.einsum("bhi,bhij->bhj", r_t.astype(jnp.float32),
                   s + u[None, :, :, None] * kv)
    s_new = w_t.astype(jnp.float32)[..., None] * s + kv
    return s_new, o.astype(r_t.dtype)


def wkv_chunked(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                w: jnp.ndarray, u: jnp.ndarray,
                chunk: int = 64) -> jnp.ndarray:
    """Chunked *parallel* WKV: the linear-attention chunk decomposition.

    Within a chunk of length C (exclusive decay products
    P_t = prod_{tau<t} w_tau, inclusive P^i_t = prod_{tau<=t} w_tau):

      intra: o_t += sum_{s<t} ((r_t*P_t) . (k_s/P^i_s)) v_s
             (lower-triangular (C,C) matmul)
      bonus: o_t += (sum_i r_t[i] u[i] k_t[i]) v_t
      cross: o_t += (r_t*P_t) @ S_chunk_start
      state: S' = diag(p_end) S + sum_s ((p_end/P^i_s) * k_s)^T v_s

    Sequential work drops from S steps to S/C chunk steps of MXU matmuls —
    the lowering-path equivalent of the Pallas kernel's chunking.
    Numerics: f32; 1/P^i_s is bounded for the w = exp(-exp(x)) decays of
    RWKV6 with C <= 64; validated against the oracle in tests.
    """
    b, h, t, d = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n = t // chunk
    rf = r.astype(jnp.float32).reshape(b, h, n, chunk, d)
    kf = k.astype(jnp.float32).reshape(b, h, n, chunk, d)
    vf = v.astype(jnp.float32).reshape(b, h, n, chunk, d)
    wf = w.astype(jnp.float32).reshape(b, h, n, chunk, d)
    uf = u.astype(jnp.float32)

    # exclusive / inclusive cumulative decay products within each chunk
    p_excl = jnp.cumprod(
        jnp.concatenate([jnp.ones_like(wf[..., :1, :]), wf[..., :-1, :]],
                        axis=-2), axis=-2)                  # (b,h,n,C,d)
    p_incl = p_excl * wf
    p_end = p_incl[..., -1, :]                              # (b,h,n,d)

    r_p = rf * p_excl
    # source s -> target t decay: prod_{tau=s+1}^{t-1} = P_excl[t]/P_incl[s]
    k_ip = kf / jnp.maximum(p_incl, 1e-30)
    intra_scores = jnp.einsum("bhncd,bhned->bhnce", r_p, k_ip)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    intra = jnp.einsum("bhnce,bhned->bhncd",
                       jnp.where(mask, intra_scores, 0.0), vf)
    # bonus: o_t[j] += (sum_i r_t[i] u[i] k_t[i]) v_t[j]
    dot_ruk = jnp.sum(rf * uf[None, :, None, None, :] * kf, axis=-1,
                      keepdims=True)                        # (b,h,n,C,1)
    bonus = dot_ruk * vf

    # cross-chunk state: source s feeds the next chunk with decay
    # prod_{tau=s+1}^{C-1} = p_end / P_incl[s]
    kw = (p_end[..., None, :] / jnp.maximum(p_incl, 1e-30)) * kf

    def step(s, inp):
        rp_c, kw_c, v_c, pe_c = inp                         # (b,h,C,d), ...
        cross = jnp.einsum("bhcd,bhde->bhce", rp_c, s)
        s_new = pe_c[..., :, None] * s + jnp.einsum(
            "bhcd,bhce->bhde", kw_c, v_c)
        return s_new, cross

    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    _, cross = jax.lax.scan(
        step, s0,
        (r_p.transpose(2, 0, 1, 3, 4), kw.transpose(2, 0, 1, 3, 4),
         vf.transpose(2, 0, 1, 3, 4), p_end.transpose(2, 0, 1, 3)))
    cross = cross.transpose(1, 2, 0, 3, 4)                  # (b,h,n,C,d)
    out = intra + bonus + cross
    return out.reshape(b, h, t, d).astype(r.dtype)
