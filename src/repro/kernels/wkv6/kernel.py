"""Pallas TPU kernel: RWKV6 WKV recurrence, chunked over the sequence.

Grid (B, H, T/CHUNK); the chunk axis is innermost, so the (D, D) f32 state
persists in VMEM scratch across chunks (TPU sequential grid order). Within
a chunk the recurrence is evaluated timestep-by-timestep on VMEM-resident
(CHUNK, D) tiles — each HBM byte of r/k/v/w is read exactly once. D is the
head dim (64 for rwkv6-1.6b), so the state tile is 16 KiB and the per-chunk
working set ~4*CHUNK*D + D*D f32 ~ 150 KiB at CHUNK=128: comfortably VMEM-
resident with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)               # (D,)
    r = r_ref[0, 0].astype(jnp.float32)            # (CHUNK, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)

    def step(t, _):
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)   # (1, D)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        s = s_scr[...]
        kv = k_t.T @ v_t                                  # (D, D)
        o_t = r_t @ (s + u[:, None] * kv)                 # (1, D)
        s_scr[...] = w_t.T * s + kv
        o_ref[0, 0, pl.ds(t, 1), :] = o_t.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
        u: jnp.ndarray, chunk: int = DEFAULT_CHUNK,
        interpret: bool = False) -> jnp.ndarray:
    """r,k,v,w: (B, H, T, D); u: (H, D). Returns (B, H, T, D)."""
    b, h, t, d = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, "seq len must divide chunk"
    n_chunks = t // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, c: (b_, h_, c, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, d), lambda b_, h_, c: (h_, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
