"""Dispatching wrapper for the RWKV6 WKV recurrence.

TPU       -> Pallas chunked-sequential kernel (VMEM-resident state).
elsewhere -> chunked *parallel* form for long sequences (the lowering path
             whose memory behaviour matches the kernel; EXPERIMENTS.md
             §Perf "wkv-chunked-parallel"), per-step scan oracle for short
             ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref

CHUNK_THRESHOLD = 256


def wkv(r, k, v, w, u) -> jnp.ndarray:
    """RWKV6 recurrence; see module docstring for dispatch rules."""
    t = r.shape[2]
    if jax.default_backend() == "tpu" and t % kernel.DEFAULT_CHUNK == 0:
        return kernel.wkv(r, k, v, w, u)
    if t >= CHUNK_THRESHOLD and t % 64 == 0:
        return ref.wkv_chunked(r, k, v, w, u, chunk=64)
    return ref.wkv(r, k, v, w, u)


wkv_step = ref.wkv_step  # decode path: single step, pure jnp everywhere
