"""Pure-jnp oracle for the fused FedAvg aggregation (eq. 13)."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_aggregate(stacked: jnp.ndarray, weights: jnp.ndarray
                       ) -> jnp.ndarray:
    """out = sum_c weights[c] * stacked[c]; stacked: (C, ...), weights: (C,)."""
    out = jnp.tensordot(weights.astype(jnp.float32),
                        stacked.astype(jnp.float32), axes=1)
    return out.astype(stacked.dtype)
