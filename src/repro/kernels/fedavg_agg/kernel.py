"""Pallas TPU kernel: fused lambda-weighted multi-client aggregation.

eq. (13) is a pure HBM-bandwidth operation executed over every parameter
each round: out[p] = sum_c w[c] * x[c, p]. The kernel streams 128x128-
aligned VMEM tiles of the flattened parameter axis and keeps the client
axis resident in VREGs, so each parameter byte is read exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384  # flattened f32 elements per tile (64 KiB VMEM per operand row)


def _agg_kernel(w_ref, x_ref, o_ref):
    # x_ref: (C, BLOCK) VMEM tile; w_ref: (C, 1); o_ref: (1, BLOCK)
    w = w_ref[...].astype(jnp.float32)            # (C, 1)
    x = x_ref[...].astype(jnp.float32)            # (C, BLOCK)
    o_ref[...] = jnp.sum(w * x, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_aggregate(stacked: jnp.ndarray, weights: jnp.ndarray,
                       interpret: bool = False) -> jnp.ndarray:
    """Pallas path: stacked (C, ...) -> (...,) weighted sum over clients."""
    c = stacked.shape[0]
    out_shape = stacked.shape[1:]
    flat = stacked.reshape(c, -1)
    p = flat.shape[1]
    pad = (-p) % BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    n_blocks = flat.shape[1] // BLOCK
    w2 = weights.reshape(c, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _agg_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((c, 1), lambda i: (0, 0)),
            pl.BlockSpec((c, BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, flat.shape[1]), stacked.dtype),
        interpret=interpret,
    )(w2, flat)
    out = out.reshape(-1)
    if pad:
        out = out[:p]
    return out.reshape(out_shape)
