"""Dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def weighted_aggregate(stacked: jnp.ndarray, weights: jnp.ndarray,
                       interpret: bool = False) -> jnp.ndarray:
    """eq. (13): sum_c weights[c] * stacked[c] over the client axis.

    ``interpret=True`` runs the Pallas kernel in interpret mode on any
    backend (used to validate the TPU path on CPU).
    """
    if interpret:
        return kernel.weighted_aggregate(stacked, weights, interpret=True)
    if _on_tpu():
        return kernel.weighted_aggregate(stacked, weights)
    return ref.weighted_aggregate(stacked, weights)
