"""Dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def weighted_aggregate(stacked: jnp.ndarray, weights: jnp.ndarray
                       ) -> jnp.ndarray:
    """eq. (13): sum_c weights[c] * stacked[c] over the client axis."""
    if _on_tpu():
        return kernel.weighted_aggregate(stacked, weights)
    return ref.weighted_aggregate(stacked, weights)
