"""CLI: ``python -m repro.obs report TRACE.jsonl [--top K] [--json]``.

Subcommands
-----------
``report``    per-region round tables, latency breakdown, top-k
              anomalies (see :mod:`repro.obs.report`).
``perfetto``  convert a JSONL trace to Chrome-trace/Perfetto JSON
              (``--out`` overrides the default sibling path).

Exit codes: **0** report produced; **1** trace loaded but empty
(nothing to report — usually an obs-disabled run); **2** usage error
or unreadable/corrupt trace file.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .report import analyze, render
from .tracer import load_jsonl, perfetto_path, to_perfetto


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect repro-trace/1 JSONL traces")
    sub = parser.add_subparsers(dest="cmd")

    p_rep = sub.add_parser("report", help="summarize a trace")
    p_rep.add_argument("trace", help="JSONL trace path")
    p_rep.add_argument("--top", type=int, default=5,
                       help="max anomalies to list (default 5)")
    p_rep.add_argument("--json", action="store_true",
                       help="emit the report as JSON instead of tables")

    p_pf = sub.add_parser("perfetto", help="convert JSONL -> Perfetto JSON")
    p_pf.add_argument("trace", help="JSONL trace path")
    p_pf.add_argument("--out", default=None,
                      help="output path (default: <trace>.perfetto.json)")

    try:
        args = parser.parse_args(argv)
    except SystemExit as e:
        # argparse exits 2 on usage errors and 0 on --help; preserve both
        return int(e.code or 0)
    if args.cmd is None:
        parser.print_usage(sys.stderr)
        return 2

    try:
        spans = load_jsonl(args.trace)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: cannot load trace {args.trace!r}: {e}",
              file=sys.stderr)
        return 2

    if args.cmd == "perfetto":
        out = args.out or perfetto_path(args.trace)
        with open(out, "w", encoding="utf-8") as fh:
            json.dump(to_perfetto(spans), fh)
        print(f"wrote {out} ({len(spans)} spans)")
        return 0

    if not spans:
        print("trace is empty (was the run observability-disabled?)",
              file=sys.stderr)
        return 1
    report = analyze(spans, top=args.top)
    if args.json:
        doc = {
            "n_spans": report.n_spans, "kinds": report.kinds,
            "merges": report.merges,
            "regions": [vars(r) for r in report.regions],
            "anomalies": [vars(a) for a in report.anomalies],
        }
        print(json.dumps(doc, indent=2, sort_keys=True))
    else:
        print(render(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
