"""Counters / gauges / histograms for the observability layer.

A :class:`Metrics` registry rides on every enabled
:class:`~repro.obs.tracer.Tracer` (``tracer.metrics``); instruments are
get-or-create by name, so instrumentation sites never need to
pre-declare them:

    tracer.metrics.counter("offload.bytes").inc(bits / 8)
    tracer.metrics.gauge("cohort.padding_ratio").set(stats.padding_ratio)
    tracer.metrics.histogram("merge.staleness_s").observe(age)

Determinism contract (same as the tracer's): instruments are pure
accumulators — no RNG, no sampling.  The histogram keeps exact
count/sum/min/max plus a bounded window of the most recent
observations for percentile estimates, so memory stays O(1) per
instrument without reservoir sampling (which would need an RNG).

The disabled path is the shared :data:`NULL_METRICS` registry: every
lookup returns one shared no-op instrument.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional


class Counter:
    """Monotonic accumulator (events, bytes, recompiles)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-write-wins sample (padding ratio, realized ISL scale)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact summary stats + bounded recent window for percentiles.

    ``window`` bounds memory; p50/p95 are computed over the most recent
    observations only (deterministic, unlike reservoir sampling), while
    count/sum/min/max/mean are exact over the full stream.
    """

    __slots__ = ("count", "total", "vmin", "vmax", "_recent")

    def __init__(self, window: int = 256):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._recent: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._recent.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], over the recent window (0.0 when empty)."""
        if not self._recent:
            return 0.0
        vals = sorted(self._recent)
        idx = min(len(vals) - 1, int(round(q / 100.0 * (len(vals) - 1))))
        return vals[idx]

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.vmin, "max": self.vmax,
                "p50": self.percentile(50), "p95": self.percentile(95)}


class Metrics:
    """Name → instrument registry (get-or-create on access)."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, window: int = 256) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(window=window)
        return h

    def names(self) -> List[str]:
        return sorted(set(self._counters) | set(self._gauges)
                      | set(self._histograms))

    def snapshot(self, prefix: Optional[str] = None) -> dict:
        """Flat JSON-serializable view: counters/gauges as scalars,
        histograms as summary dicts.  ``prefix`` filters by name prefix
        (e.g. ``"cohort."`` for the bench-row attachment)."""
        out: dict = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._histograms.items():
            out[name] = h.summary()
        if prefix is not None:
            out = {k: v for k, v in out.items() if k.startswith(prefix)}
        return out


class _NullInstrument:
    """Shared no-op stand-in for every instrument type."""

    __slots__ = ()
    value = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class _NullMetrics(Metrics):
    """Registry handed out by the disabled tracer: never accumulates."""

    def __init__(self):
        super().__init__()

    def counter(self, name: str):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def gauge(self, name: str):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def histogram(self, name: str, window: int = 256):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def snapshot(self, prefix: Optional[str] = None) -> dict:
        return {}


NULL_METRICS = _NullMetrics()
