"""Process-local structured tracer for the SAGIN FL stack.

One :class:`Tracer` instance is shared by every layer of a run
(``SAGINEngine`` → ``RegionTrainer`` → ``CohortEngine`` →
``sim.dynamics``): instrumentation sites emit typed :class:`Span`
records carrying BOTH clocks — the simulated wall clock the engine
advances (``t_sim``/``dur_sim``, seconds) and the host's monotonic
clock (``t_wall``/``dur_wall``, ``time.perf_counter`` seconds relative
to tracer construction).  Spans buffer in memory and export as

* JSONL — one ``Span.to_dict()`` object per line (the on-disk trace
  schema, version ``repro-trace/1``), reloadable with
  :func:`load_jsonl`; and
* Chrome-trace / Perfetto JSON — ``{"traceEvents": [...]}`` with one
  thread track per region on the simulated-clock axis, so a
  multi-region run renders as a per-region timeline in
  https://ui.perfetto.dev (load the ``*.perfetto.json`` sibling that
  :meth:`Tracer.flush` writes next to the JSONL).

Span kinds are CLOSED (:data:`SPAN_KINDS`): ``round`` (one FL round),
``offload`` (the round's data-placement transfer), ``handover``
(one satellite-to-satellite switch inside a round), ``merge`` (one
cross-region federation merge, on the synthetic ``federation`` track),
``bucket_dispatch`` (one compiled cohort-bucket dispatch; wall-clock
duration only — fence with ``ObsConfig.device_timing`` for true device
time), ``outage`` (a realized dynamics event: ISL fade, uplink
dead-air, device churn), ``fault`` / ``recovery`` (one injected fault
and its graceful-degradation response, from
``repro.resilience.FaultInjector``), ``resume`` (an engine
checkpoint restore, from ``repro.checkpoint.engine``), ``request``
(one served inference request, end-to-end, from
``repro.serve.ServeGateway``), and ``serve_batch`` (one batched
inference dispatch at a serving node, geometric-padded).

Determinism contract: the tracer only OBSERVES.  It never draws from
any RNG, never touches model parameters, and (``device_timing`` aside,
which merely forces synchronization) never changes what the
instrumented code computes — trajectories are bit-identical with
tracing on or off at equal seeds (test-locked).

The disabled path is a null object: ``resolve_obs(None)`` returns the
shared :data:`NULL_TRACER` whose ``enabled`` flag is ``False``; hot
instrumentation sites guard on ``tracer.enabled`` so a disabled run
pays one attribute load + branch per site (<2% on the cohort
benchmark, gated by ``benchmarks/obs_overhead.py``).

Do NOT call tracer/metrics methods inside ``jax.jit``-compiled
functions — the call runs at trace time, not per execution (lint rule
``OBS001`` flags this).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional

from .metrics import NULL_METRICS, Metrics

TRACE_SCHEMA = "repro-trace/1"

SPAN_KINDS = ("round", "offload", "handover", "merge", "bucket_dispatch",
              "outage", "fault", "recovery", "resume", "request",
              "serve_batch")

#: Perfetto display category per span kind.  EVERY kind must have an
#: entry — :func:`to_perfetto` indexes this mapping directly, so a kind
#: added to :data:`SPAN_KINDS` without one fails loudly on export (the
#: vocabulary-sync test in ``tests/test_obs.py`` locks the two, plus
#: the report renderer's kinds, together).
PERFETTO_KINDS = {
    "round": "training",
    "offload": "training",
    "handover": "network",
    "merge": "federation",
    "bucket_dispatch": "compute",
    "outage": "network",
    "fault": "resilience",
    "recovery": "resilience",
    "resume": "resilience",
    "request": "serving",
    "serve_batch": "serving",
}

#: Synthetic region name for cross-region events (merges) that belong to
#: no single region's timeline.
FEDERATION_TRACK = "federation"


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability wiring for one run (``FLConfig.obs``/``Scenario.obs``).

    ``path`` is the JSONL trace destination (``None`` keeps spans
    in memory only — still inspectable via ``tracer.spans`` and
    exportable by hand).  ``device_timing`` fences every cohort bucket
    dispatch with ``jax.block_until_ready`` so ``bucket_dispatch``
    spans carry true device time instead of async-dispatch time; it
    changes performance, never results.  ``perfetto`` also writes a
    Chrome-trace sibling (``trace.jsonl`` → ``trace.perfetto.json``)
    on flush.
    """
    path: Optional[str] = None
    enabled: bool = True
    device_timing: bool = False
    perfetto: bool = True


@dataclasses.dataclass
class Span:
    """One typed trace record (an instant event when both durations are 0).

    ``t_sim``/``dur_sim`` are simulated seconds (the engine's wall
    clock); ``t_wall``/``dur_wall`` are host monotonic seconds relative
    to the tracer's construction.  ``round`` is the FL round index the
    span belongs to (-1 when not round-scoped) and ``attrs`` carries
    kind-specific payload (JSON-serializable scalars/lists only).
    """
    kind: str
    name: str
    region: str = ""
    round: int = -1
    t_sim: float = 0.0
    dur_sim: float = 0.0
    t_wall: float = 0.0
    dur_wall: float = 0.0
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = TRACE_SCHEMA
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        return cls(kind=d["kind"], name=d["name"],
                   region=d.get("region", ""), round=int(d.get("round", -1)),
                   t_sim=float(d.get("t_sim", 0.0)),
                   dur_sim=float(d.get("dur_sim", 0.0)),
                   t_wall=float(d.get("t_wall", 0.0)),
                   dur_wall=float(d.get("dur_wall", 0.0)),
                   attrs=dict(d.get("attrs", {})))


class Tracer:
    """Buffered span emitter + metrics registry for one run.

    The tracer carries a mutable *context* (current region / round /
    simulated time) that the outermost instrumentation site
    (``RegionTrainer.step``) sets once per round, so inner layers
    (``sim.dynamics``, ``CohortEngine``) can emit spans without
    plumbing region identity through every call signature.  The stack
    is single-threaded per run; no locking.
    """

    def __init__(self, config: Optional[ObsConfig] = None):
        cfg = config if config is not None else ObsConfig()
        self.config = cfg
        self.enabled = bool(cfg.enabled)
        self.device_timing = self.enabled and bool(cfg.device_timing)
        self.spans: List[Span] = []
        self.metrics: Metrics = Metrics() if self.enabled else NULL_METRICS
        self._epoch = time.perf_counter()
        # emission context (set by the round driver, read by inner layers)
        self.ctx_region = ""
        self.ctx_round = -1
        self.ctx_t_sim = 0.0

    # -- clocks / context ---------------------------------------------------
    def wall_now(self) -> float:
        """Host monotonic seconds since tracer construction."""
        return time.perf_counter() - self._epoch

    def set_context(self, region: Optional[str] = None,
                    round: Optional[int] = None,
                    t_sim: Optional[float] = None) -> None:
        if not self.enabled:
            return
        if region is not None:
            self.ctx_region = region
        if round is not None:
            self.ctx_round = round
        if t_sim is not None:
            self.ctx_t_sim = t_sim

    # -- emission -----------------------------------------------------------
    def span(self, kind: str, name: str, *,
             region: Optional[str] = None, round: Optional[int] = None,
             t_sim: Optional[float] = None, dur_sim: float = 0.0,
             t_wall: Optional[float] = None, dur_wall: float = 0.0,
             **attrs) -> Optional[Span]:
        """Record one span; unset fields fall back to the context.

        Returns the span (or ``None`` when disabled).  ``kind`` must be
        one of :data:`SPAN_KINDS` — the closed vocabulary is what makes
        the report CLI's aggregation semantics possible.
        """
        if not self.enabled:
            return None
        if kind not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {kind!r}; "
                             f"expected one of {SPAN_KINDS}")
        s = Span(kind=kind, name=name,
                 region=self.ctx_region if region is None else region,
                 round=self.ctx_round if round is None else round,
                 t_sim=self.ctx_t_sim if t_sim is None else t_sim,
                 dur_sim=dur_sim,
                 t_wall=self.wall_now() if t_wall is None else t_wall,
                 dur_wall=dur_wall, attrs=attrs)
        self.spans.append(s)
        return s

    def event(self, kind: str, name: str, **kw) -> Optional[Span]:
        """Zero-duration span (an instant on the timeline)."""
        return self.span(kind, name, **kw)

    # -- export -------------------------------------------------------------
    def flush(self, path: Optional[str] = None) -> Optional[str]:
        """Write the buffered spans to ``path`` (default: the config's).

        Idempotent full rewrite — calling again after more spans simply
        rewrites the complete trace.  Writes the Perfetto sibling when
        ``config.perfetto``.  Returns the JSONL path written, or
        ``None`` when disabled / no destination configured.
        """
        if not self.enabled:
            return None
        dest = path if path is not None else self.config.path
        if not dest:
            return None
        write_jsonl(dest, self.spans)
        if self.config.perfetto:
            write_perfetto(perfetto_path(dest), self.spans)
        return dest


#: Shared disabled tracer: every recording method early-returns, metrics
#: are the shared null registry.  ``resolve_obs(None)`` hands this out.
NULL_TRACER = Tracer(ObsConfig(enabled=False))


def resolve_obs(obs) -> Tracer:
    """Coerce an ``FLConfig.obs``/``Scenario.obs`` value to a tracer.

    ``None`` → the shared disabled :data:`NULL_TRACER`; a bare string →
    an enabled tracer writing JSONL (+ Perfetto sibling) to that path;
    an :class:`ObsConfig` → a tracer so configured; an existing
    :class:`Tracer` passes through (the engine shares one across its
    region trainers this way).
    """
    if obs is None:
        return NULL_TRACER
    if isinstance(obs, Tracer):
        return obs
    if isinstance(obs, str):
        obs = ObsConfig(path=obs)
    if isinstance(obs, ObsConfig):
        return Tracer(obs) if obs.enabled else NULL_TRACER
    raise TypeError(f"obs must be None, a path string, ObsConfig, or "
                    f"Tracer, got {type(obs).__name__}")


# -- serialization -----------------------------------------------------------
def write_jsonl(path: str, spans: Iterable[Span]) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        for s in spans:
            fh.write(json.dumps(s.to_dict(), sort_keys=True) + "\n")


def load_jsonl(path: str) -> List[Span]:
    """Reload a JSONL trace written by :func:`write_jsonl`/``flush``."""
    out = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(Span.from_dict(json.loads(line)))
    return out


def perfetto_path(jsonl_path: str) -> str:
    """``trace.jsonl`` → ``trace.perfetto.json`` (suffix-aware)."""
    if jsonl_path.endswith(".jsonl"):
        return jsonl_path[:-len(".jsonl")] + ".perfetto.json"
    return jsonl_path + ".perfetto.json"


def to_perfetto(spans: Iterable[Span]) -> dict:
    """Chrome-trace / Perfetto JSON: one thread track per region.

    The timeline axis is the SIMULATED clock (µs since run start);
    wall-clock measurements ride along in each event's ``args``.
    Zero-duration spans become instant events (``ph: "i"``) on their
    region's track.
    """
    spans = list(spans)
    regions = sorted({s.region or "global" for s in spans})
    tid = {r: i + 1 for i, r in enumerate(regions)}
    events: List[dict] = [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": "repro-sagin"}},
    ]
    for r, t in tid.items():
        events.append({"ph": "M", "pid": 1, "tid": t, "name": "thread_name",
                       "args": {"name": r}})
    for s in spans:
        args = dict(s.attrs)
        args["round"] = s.round
        args["t_wall_s"] = round(s.t_wall, 6)
        if s.dur_wall:
            args["dur_wall_s"] = round(s.dur_wall, 6)
        # cat is a comma-separated category list (Chrome-trace format):
        # the span kind plus its display group — the mapping lookup is
        # deliberately unguarded so an unmapped kind fails loudly here
        base = {"name": s.name,
                "cat": f"{s.kind},{PERFETTO_KINDS[s.kind]}", "pid": 1,
                "tid": tid[s.region or "global"],
                "ts": s.t_sim * 1e6, "args": args}
        if s.dur_sim > 0.0:
            events.append({**base, "ph": "X", "dur": s.dur_sim * 1e6})
        else:
            events.append({**base, "ph": "i", "s": "t"})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA}}


def write_perfetto(path: str, spans: Iterable[Span]) -> None:
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(to_perfetto(spans), fh)
