"""Trace analysis: per-region tables, latency breakdown, anomalies.

Consumes a JSONL trace written by :meth:`repro.obs.Tracer.flush` and
renders what the paper's latency story needs to be debuggable:

* a per-region round table (rounds, simulated end time, round-latency
  stats, handover/outage counts, final accuracy);
* a latency breakdown — where each region's simulated time went:
  **compute** (round latency minus in-round stalls), **uplink**
  (dead-air outage delays), **ISL** (handover switches + merge tolls),
  and **idle** (barrier parking / event-loop gaps to the run's end);
* top-k anomalies: straggler rounds (≥ :data:`STRAGGLER_FACTOR` × the
  region's median), repeated-handover rounds (≥2 switches), and
  quorum-miss or skipped merges;
* a sharded-dispatch breakdown when the trace holds
  ``bucket_dispatch`` spans from a mesh-sharded
  :class:`~repro.fl.cohort_engine.CohortEngine` (``mesh_shape`` and
  per-shard ``shard_real`` attrs): each span's host ``dur_wall`` is
  apportioned across shards by their share of the bucket's real
  (unmasked) batch elements, giving per-shard dispatch time, work
  share, and the aggregate imbalance (max over mean share);
* a serving section when the trace holds ``request``/``serve_batch``
  spans from a :class:`~repro.serve.gateway.ServeGateway`: sustained
  QPS over the served window, end-to-end latency p50/p99, queueing
  share, served accuracy, batch fill, and the per-target-kind split
  (own satellite / ISL neighbour / ground fallback).

:data:`HANDLED_KINDS` is this module's copy of the closed span
vocabulary — every kind ``analyze``/``render`` knows how to aggregate.
The vocabulary-sync test locks it against ``tracer.SPAN_KINDS`` and
``tracer.PERFETTO_KINDS`` so a kind added in only one place fails CI.

Everything here is pure span arithmetic — no jax, no simulator
imports — so the CLI (``python -m repro.obs report``) stays fast and
usable on traces copied off another machine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .tracer import FEDERATION_TRACK, Span

STRAGGLER_FACTOR = 1.5

#: Every span kind this report knows how to aggregate/render — must
#: stay in lockstep with ``tracer.SPAN_KINDS`` (test-locked).
HANDLED_KINDS = frozenset({
    "round", "offload", "handover", "merge", "bucket_dispatch", "outage",
    "fault", "recovery", "resume", "request", "serve_batch",
})

#: Serving-plane kinds: reported in their own section, excluded from the
#: per-region TRAINING tables (round stats, latency breakdown, idle).
SERVING_KINDS = frozenset({"request", "serve_batch"})


@dataclasses.dataclass
class Anomaly:
    kind: str        # "straggler" | "repeated_handover" | "quorum_miss"
    severity: float  # sort key, larger = worse
    message: str


@dataclasses.dataclass
class RegionReport:
    region: str
    rounds: int = 0
    end_sim: float = 0.0           # last activity on this region's track
    mean_round: float = 0.0
    max_round: float = 0.0
    handovers: int = 0
    outages: int = 0
    final_acc: Optional[float] = None
    # latency breakdown (simulated seconds)
    compute: float = 0.0
    uplink: float = 0.0
    isl: float = 0.0
    idle: float = 0.0


@dataclasses.dataclass
class ShardRow:
    shard: int
    real_elements: int = 0       # unmasked batch elements this shard ran
    wall_s: float = 0.0          # dispatch dur_wall apportioned by share


@dataclasses.dataclass
class ShardDispatchReport:
    mesh_shape: List[int]
    dispatches: int              # sharded bucket_dispatch spans seen
    wall_s: float                # total sharded dispatch wall time
    shards: List[ShardRow]
    imbalance: float = 1.0       # max shard share / mean shard share


@dataclasses.dataclass
class ServingReport:
    """Aggregated serving-plane spans (``request``/``serve_batch``)."""
    requests: int = 0
    batches: int = 0
    qps: float = 0.0               # requests / served simulated window
    latency_p50: float = 0.0       # end-to-end simulated seconds
    latency_p99: float = 0.0
    latency_mean: float = 0.0
    wait_mean: float = 0.0         # queueing share
    served_accuracy: Optional[float] = None
    mean_batch: float = 0.0        # real elements per dispatch
    fill: float = 1.0              # real / padded elements
    by_region: Dict[str, int] = dataclasses.field(default_factory=dict)
    by_target: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TraceReport:
    regions: List[RegionReport]
    merges: int
    anomalies: List[Anomaly]
    n_spans: int
    kinds: Dict[str, int]
    shard_dispatch: Optional[ShardDispatchReport] = None
    # resilience (repro.resilience): injected/recovered fault counts by
    # kind, quarantined client updates, and engine checkpoint resumes
    faults: Dict[str, int] = dataclasses.field(default_factory=dict)
    recoveries: Dict[str, int] = dataclasses.field(default_factory=dict)
    quarantined: int = 0
    resumes: int = 0
    # serving (repro.serve): present when the trace holds serving spans
    serving: Optional[ServingReport] = None


def _median(vals: Sequence[float]) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def _shard_dispatch(spans: Sequence[Span]) -> Optional[ShardDispatchReport]:
    """Fold sharded ``bucket_dispatch`` spans into per-shard totals.

    A span is sharded when it carries a ``shard_real`` list (emitted
    only by engines with >1 shard).  Each span's ``dur_wall`` is split
    across shards proportionally to the shard's real-element share of
    that bucket — shard_map runs all shards in lockstep, so this is
    the *useful* time attribution, not a measured per-shard clock.
    """
    sharded = [s for s in spans
               if s.kind == "bucket_dispatch" and s.attrs.get("shard_real")]
    if not sharded:
        return None
    n = max(len(s.attrs["shard_real"]) for s in sharded)
    rows = [ShardRow(shard=i) for i in range(n)]
    wall = 0.0
    mesh_shape = [n]
    for s in sharded:
        per = [float(v) for v in s.attrs["shard_real"]]
        tot = sum(per) or 1.0
        ms = s.attrs.get("mesh_shape")
        if isinstance(ms, list) and ms:
            mesh_shape = [int(v) for v in ms]
        wall += s.dur_wall
        for i, v in enumerate(per):
            rows[i].real_elements += int(v)
            rows[i].wall_s += s.dur_wall * v / tot
    total_real = sum(r.real_elements for r in rows)
    imb = (max(r.real_elements for r in rows) * n / total_real
           if total_real else 1.0)
    return ShardDispatchReport(mesh_shape=mesh_shape,
                               dispatches=len(sharded), wall_s=wall,
                               shards=rows, imbalance=imb)


def _percentile(vals: Sequence[float], q: float) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    idx = min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1))))
    return s[idx]


def _serving(spans: Sequence[Span]) -> Optional[ServingReport]:
    """Fold ``request``/``serve_batch`` spans into the serving section."""
    reqs = [s for s in spans if s.kind == "request"]
    batches = [s for s in spans if s.kind == "serve_batch"]
    if not reqs and not batches:
        return None
    sr = ServingReport(requests=len(reqs), batches=len(batches))
    if reqs:
        lats = [s.dur_sim for s in reqs]
        sr.latency_p50 = _percentile(lats, 50)
        sr.latency_p99 = _percentile(lats, 99)
        sr.latency_mean = sum(lats) / len(lats)
        sr.wait_mean = sum(float(s.attrs.get("wait_s", 0.0))
                           for s in reqs) / len(reqs)
        t_lo = min(s.t_sim for s in reqs)
        t_hi = max(s.t_sim + s.dur_sim for s in reqs)
        if t_hi > t_lo:
            sr.qps = len(reqs) / (t_hi - t_lo)
        flags = [s.attrs["correct"] for s in reqs
                 if s.attrs.get("correct") is not None]
        if flags:
            sr.served_accuracy = sum(bool(f) for f in flags) / len(flags)
        for s in reqs:
            sr.by_region[s.region] = sr.by_region.get(s.region, 0) + 1
            route = str(s.attrs.get("route", "?"))
            sr.by_target[route] = sr.by_target.get(route, 0) + 1
    if batches:
        real = sum(int(s.attrs.get("n_real", 0)) for s in batches)
        padded = sum(int(s.attrs.get("n_pad", 0)) for s in batches)
        sr.mean_batch = real / len(batches)
        sr.fill = real / padded if padded else 1.0
    return sr


def analyze(spans: Sequence[Span], top: int = 5) -> TraceReport:
    """Aggregate a span list into the report structure (pure function)."""
    kinds: Dict[str, int] = {}
    for s in spans:
        kinds[s.kind] = kinds.get(s.kind, 0) + 1

    by_region: Dict[str, List[Span]] = {}
    merges = [s for s in spans if s.kind == "merge"]
    for s in spans:
        # serving spans get their own section; the per-region tables
        # (rounds, latency breakdown, idle) describe TRAINING time
        if (s.region and s.region != FEDERATION_TRACK
                and s.kind not in SERVING_KINDS):
            by_region.setdefault(s.region, []).append(s)

    anomalies: List[Anomaly] = []
    regions: List[RegionReport] = []
    run_end = max((s.t_sim + s.dur_sim for s in spans
                   if s.kind not in SERVING_KINDS), default=0.0)

    for name in sorted(by_region):
        rs = by_region[name]
        rounds = sorted((s for s in rs if s.kind == "round"),
                        key=lambda s: s.round)
        hand = [s for s in rs if s.kind == "handover"]
        outs = [s for s in rs if s.kind == "outage"]
        durs = [s.dur_sim for s in rounds]
        rep = RegionReport(region=name, rounds=len(rounds),
                           handovers=len(hand), outages=len(outs))
        rep.end_sim = max((s.t_sim + s.dur_sim for s in rs), default=0.0)
        if durs:
            rep.mean_round = sum(durs) / len(durs)
            rep.max_round = max(durs)
        accs = [s.attrs.get("acc") for s in rounds
                if s.attrs.get("acc") is not None]
        rep.final_acc = accs[-1] if accs else None

        # breakdown: in-round stalls are priced by their own spans;
        # whatever round time they don't explain is compute.  Merge
        # tolls addressed to this region (per-recipient isl_costs in the
        # merge span attrs) are ISL time spent outside any round.
        uplink = sum(float(s.attrs.get("delay", 0.0)) for s in outs
                     if s.attrs.get("event") == "uplink")
        isl_in_round = sum(s.dur_sim for s in hand)
        merge_toll = 0.0
        for m in merges:
            names = m.attrs.get("recipient_names") or []
            costs = m.attrs.get("isl_costs") or []
            merge_toll += sum(c for rn, c in zip(names, costs)
                              if rn == name)
        busy = sum(durs)
        rep.uplink = uplink
        rep.isl = isl_in_round + merge_toll
        rep.compute = max(0.0, busy - uplink - isl_in_round)
        rep.idle = max(0.0, run_end - busy - merge_toll)
        regions.append(rep)

        med = _median(durs)
        if med > 0:
            for s in rounds:
                ratio = s.dur_sim / med
                if ratio >= STRAGGLER_FACTOR:
                    anomalies.append(Anomaly(
                        "straggler", ratio,
                        f"{name} round {s.round}: {s.dur_sim:.1f}s "
                        f"({ratio:.1f}x region median {med:.1f}s)"))
        for s in rounds:
            nh = int(s.attrs.get("n_handovers", 0))
            if nh >= 2:
                anomalies.append(Anomaly(
                    "repeated_handover", nh,
                    f"{name} round {s.round}: {nh} satellite handovers "
                    f"in one round"))

    for m in merges:
        if m.attrs.get("skipped"):
            anomalies.append(Anomaly(
                "quorum_miss", float("inf"),
                f"merge at boundary r{m.round} SKIPPED "
                f"({m.attrs.get('policy', '?')}: no plan)"))
        elif m.attrs.get("quorum_miss"):
            parts = m.attrs.get("participants") or []
            anomalies.append(Anomaly(
                "quorum_miss", float(len(parts)),
                f"merge at boundary r{m.round} with partial quorum: "
                f"{len(parts)} participant(s) {list(parts)}"))

    anomalies.sort(key=lambda a: -a.severity)

    faults: Dict[str, int] = {}
    recoveries: Dict[str, int] = {}
    quarantined = 0
    resumes = 0
    for s in spans:
        if s.kind == "fault":
            k = str(s.attrs.get("fault", s.name))
            faults[k] = faults.get(k, 0) + 1
        elif s.kind == "recovery":
            k = str(s.attrs.get("fault", s.name))
            recoveries[k] = recoveries.get(k, 0) + 1
            quarantined += int(s.attrs.get("quarantined", 0))
        elif s.kind == "resume":
            resumes += 1

    return TraceReport(regions=regions, merges=len(merges),
                       anomalies=anomalies[:top], n_spans=len(spans),
                       kinds=kinds, shard_dispatch=_shard_dispatch(spans),
                       faults=faults, recoveries=recoveries,
                       quarantined=quarantined, resumes=resumes,
                       serving=_serving(spans))


def _table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def fmt(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    lines = [fmt(headers), fmt(["-" * w for w in widths])]
    lines += [fmt(r) for r in rows]
    return "\n".join(lines)


def render(report: TraceReport) -> str:
    """Human-readable report text (what the CLI prints)."""
    out: List[str] = []
    kinds = " ".join(f"{k}={n}" for k, n in sorted(report.kinds.items()))
    out.append(f"trace: {report.n_spans} spans "
               f"({kinds or 'empty'}), {report.merges} merge(s)")
    out.append("")
    out.append("per-region rounds")
    rows = []
    for r in report.regions:
        rows.append([r.region, str(r.rounds), f"{r.end_sim:.1f}",
                     f"{r.mean_round:.1f}", f"{r.max_round:.1f}",
                     str(r.handovers), str(r.outages),
                     "-" if r.final_acc is None else f"{r.final_acc:.3f}"])
    out.append(_table(["region", "rounds", "end_sim_s", "mean_round_s",
                       "max_round_s", "handovers", "outages", "final_acc"],
                      rows))
    out.append("")
    out.append("latency breakdown (simulated seconds)")
    rows = []
    for r in report.regions:
        tot = r.compute + r.uplink + r.isl + r.idle
        def pct(v):
            return f"{100 * v / tot:.0f}%" if tot > 0 else "-"
        rows.append([r.region, f"{r.compute:.1f} ({pct(r.compute)})",
                     f"{r.uplink:.1f} ({pct(r.uplink)})",
                     f"{r.isl:.1f} ({pct(r.isl)})",
                     f"{r.idle:.1f} ({pct(r.idle)})"])
    out.append(_table(["region", "compute", "uplink", "isl", "idle"], rows))
    out.append("")
    sd = report.shard_dispatch
    if sd is not None:
        out.append(f"sharded dispatch (mesh {'x'.join(map(str, sd.mesh_shape))}, "
                   f"{sd.dispatches} dispatch(es), "
                   f"{1e3 * sd.wall_s:.1f} ms total, "
                   f"imbalance {sd.imbalance:.2f}x)")
        total_real = sum(r.real_elements for r in sd.shards) or 1
        rows = [[str(r.shard), str(r.real_elements),
                 f"{100 * r.real_elements / total_real:.0f}%",
                 f"{1e3 * r.wall_s:.1f}"]
                for r in sd.shards]
        out.append(_table(["shard", "real_elems", "share", "wall_ms"], rows))
        out.append("")
    if report.faults or report.recoveries or report.resumes:
        total_inj = sum(report.faults.values())
        total_rec = sum(report.recoveries.values())
        out.append(f"resilience ({total_inj} fault(s) injected, "
                   f"{total_rec} recovered, "
                   f"{report.quarantined} update(s) quarantined, "
                   f"{report.resumes} resume(s))")
        kinds_seen = sorted(set(report.faults) | set(report.recoveries))
        rows = [[k, str(report.faults.get(k, 0)),
                 str(report.recoveries.get(k, 0))] for k in kinds_seen]
        if rows:
            out.append(_table(["fault", "injected", "recovered"], rows))
        out.append("")
    sv = report.serving
    if sv is not None:
        acc = ("-" if sv.served_accuracy is None
               else f"{sv.served_accuracy:.3f}")
        out.append(f"serving ({sv.requests} request(s), {sv.batches} "
                   f"dispatch(es), {sv.qps:.2f} req/s sustained, "
                   f"served_acc {acc})")
        out.append(_table(
            ["p50_s", "p99_s", "mean_s", "wait_s", "batch", "fill"],
            [[f"{sv.latency_p50:.3f}", f"{sv.latency_p99:.3f}",
              f"{sv.latency_mean:.3f}", f"{sv.wait_mean:.3f}",
              f"{sv.mean_batch:.1f}", f"{100 * sv.fill:.0f}%"]]))
        if sv.by_region:
            total = sum(sv.by_region.values()) or 1
            rows = [[name, str(n), f"{100 * n / total:.0f}%"]
                    for name, n in sorted(sv.by_region.items())]
            out.append(_table(["region", "requests", "share"], rows))
        if sv.by_target:
            out.append("routes: " + " ".join(
                f"{k}={n}" for k, n in sorted(sv.by_target.items())))
        out.append("")
    if report.anomalies:
        out.append(f"top anomalies ({len(report.anomalies)})")
        for a in report.anomalies:
            out.append(f"  [{a.kind}] {a.message}")
    else:
        out.append("no anomalies detected")
    return "\n".join(out)
