"""``repro.obs`` — tracing + metrics for the SAGIN FL stack.

Enable by handing any run an :class:`ObsConfig` (or a bare output-path
string) through ``FLConfig.obs`` / ``Scenario.obs``:

    fl = FLConfig(..., obs="trace.jsonl")
    SAGINEngine("multi_region", fl=fl).run(4)
    # -> trace.jsonl (repro-trace/1) + trace.perfetto.json

then ``python -m repro.obs report trace.jsonl`` for round tables /
latency breakdown / anomalies, or load the ``.perfetto.json`` sibling
in https://ui.perfetto.dev for the per-region timeline.

Disabled (the default) costs one branch per instrumentation site —
gated <2% on the cohort benchmark by ``benchmarks/obs_overhead.py`` —
and the tracer never perturbs RNG streams or results either way.
"""
from .metrics import (Counter, Gauge, Histogram, Metrics,  # noqa: F401
                      NULL_METRICS)
from .report import (HANDLED_KINDS, ServingReport, TraceReport,  # noqa: F401
                     analyze, render)
from .tracer import (FEDERATION_TRACK, NULL_TRACER, ObsConfig,  # noqa: F401
                     PERFETTO_KINDS, SPAN_KINDS, Span, TRACE_SCHEMA, Tracer,
                     load_jsonl, perfetto_path, resolve_obs, to_perfetto,
                     write_jsonl, write_perfetto)

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics", "NULL_METRICS",
    "HANDLED_KINDS", "ServingReport", "TraceReport", "analyze", "render",
    "FEDERATION_TRACK", "NULL_TRACER", "ObsConfig", "PERFETTO_KINDS",
    "SPAN_KINDS", "Span", "TRACE_SCHEMA", "Tracer", "load_jsonl",
    "perfetto_path", "resolve_obs", "to_perfetto", "write_jsonl",
    "write_perfetto",
]
