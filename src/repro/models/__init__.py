from . import cnn, layers, transformer

__all__ = ["cnn", "layers", "transformer"]
