"""The paper's FL payload models (Section VI-A), in raw JAX.

- MNIST:  CNN with two conv layers and two fully connected layers.
- FMNIST: CNN with two conv layers and one fully connected layer.
- CIFAR-10: VGG-11.

Params are plain dicts of jnp arrays; ``apply(params, x)`` returns logits.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = math.sqrt(2.0 / fan_in)
    return {"w": jax.random.normal(key, (kh, kw, cin, cout),
                                   jnp.float32) * std,
            "b": jnp.zeros((cout,), jnp.float32)}


def _dense_init(key, din, dout):
    std = math.sqrt(2.0 / din)
    return {"w": jax.random.normal(key, (din, dout), jnp.float32) * std,
            "b": jnp.zeros((dout,), jnp.float32)}


def _conv(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# MNIST CNN: conv(32) -> pool -> conv(64) -> pool -> fc(128) -> fc(10)
# ---------------------------------------------------------------------------
def init_mnist_cnn(key, image_shape=(28, 28, 1), n_classes=10) -> Dict:
    ks = jax.random.split(key, 4)
    h, w, c = image_shape
    flat = (h // 4) * (w // 4) * 64
    return {"c1": _conv_init(ks[0], 3, 3, c, 32),
            "c2": _conv_init(ks[1], 3, 3, 32, 64),
            "f1": _dense_init(ks[2], flat, 128),
            "f2": _dense_init(ks[3], 128, n_classes)}


def apply_mnist_cnn(params, x):
    x = _maxpool(jax.nn.relu(_conv(x, params["c1"])))
    x = _maxpool(jax.nn.relu(_conv(x, params["c2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"]["w"] + params["f1"]["b"])
    return x @ params["f2"]["w"] + params["f2"]["b"]


# ---------------------------------------------------------------------------
# FMNIST CNN: conv(16) -> pool -> conv(32) -> pool -> fc(10)
# ---------------------------------------------------------------------------
def init_fmnist_cnn(key, image_shape=(28, 28, 1), n_classes=10) -> Dict:
    ks = jax.random.split(key, 3)
    h, w, c = image_shape
    flat = (h // 4) * (w // 4) * 32
    return {"c1": _conv_init(ks[0], 3, 3, c, 16),
            "c2": _conv_init(ks[1], 3, 3, 16, 32),
            "f1": _dense_init(ks[2], flat, n_classes)}


def apply_fmnist_cnn(params, x):
    x = _maxpool(jax.nn.relu(_conv(x, params["c1"])))
    x = _maxpool(jax.nn.relu(_conv(x, params["c2"])))
    x = x.reshape(x.shape[0], -1)
    return x @ params["f1"]["w"] + params["f1"]["b"]


# ---------------------------------------------------------------------------
# VGG-11 for CIFAR-10
# ---------------------------------------------------------------------------
_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_vgg11(key, image_shape=(32, 32, 3), n_classes=10) -> Dict:
    params = {"convs": [], "fc": None}
    cin = image_shape[2]
    keys = jax.random.split(key, len([v for v in _VGG11 if v != "M"]) + 1)
    ki = 0
    for v in _VGG11:
        if v == "M":
            continue
        params["convs"].append(_conv_init(keys[ki], 3, 3, cin, v))
        cin = v
        ki += 1
    params["fc"] = _dense_init(keys[ki], 512, n_classes)
    return params


def apply_vgg11(params, x):
    ci = 0
    for v in _VGG11:
        if v == "M":
            x = _maxpool(x)
        else:
            x = jax.nn.relu(_conv(x, params["convs"][ci]))
            ci += 1
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"]["w"] + params["fc"]["b"]


# ---------------------------------------------------------------------------
MODELS: Dict[str, Tuple[Callable, Callable]] = {
    "mnist": (init_mnist_cnn, apply_mnist_cnn),
    "fmnist": (init_fmnist_cnn, apply_fmnist_cnn),
    "cifar10": (init_vgg11, apply_vgg11),
}


def build_model(name: str, key, image_shape=None, n_classes=10):
    init, apply = MODELS[name]
    kw = {}
    if image_shape is not None:
        kw["image_shape"] = image_shape
    params = init(key, n_classes=n_classes, **kw)
    return params, apply


def param_count(params) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params))


def model_bits(params, dtype_bits: int = 32) -> float:
    """Q(w) for the latency model."""
    return float(param_count(params) * dtype_bits)
