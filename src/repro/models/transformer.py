"""Decoder-only transformer family composed from ``ModelConfig``.

Supports the whole assigned-architecture pool: dense GQA (llama/qwen/olmo/
deepseek-coder/musicgen/internvl2 backbones), MLA+MoE (deepseek-v2), routed
MoE (qwen3-moe), RWKV6, and the Jamba hybrid (1 attention layer per
``attn_every`` layers of Mamba, MoE every ``moe_every``-th FFN).

The layer stack is organized as a ``lax.scan`` over homogeneous *blocks*
(1 layer normally; ``attn_every`` layers for hybrids) so the compiled HLO
stays compact for 90+-layer configs. Training uses plain SGD (eqs. 3-6 of
the paper are vanilla local SGD); Adam is available via ``optimizer=``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.activations import shard
from . import layers as L

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# Block templates -------------------------------------------------------------
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sublayer:
    mixer: str       # gqa|mla|mamba|rwkv6
    ffn: str         # swiglu|moe|rwkv_channel


def block_template(cfg: ModelConfig) -> List[Sublayer]:
    """The repeating unit scanned over. Length = block size."""
    size = cfg.attn_every if cfg.attn_every else 1
    subs = []
    for j in range(size):
        if cfg.arch_type == "ssm" and cfg.ssm_type == "rwkv6":
            mixer = "rwkv6"
        elif cfg.attn_every:
            mixer = "gqa" if j == 0 else "mamba"
        elif cfg.attention == "mla":
            mixer = "mla"
        else:
            mixer = "gqa"
        if mixer == "rwkv6":
            ffn = "rwkv_channel"
        elif cfg.n_experts and (j % cfg.moe_every) == cfg.moe_every - 1:
            ffn = "moe"
        else:
            ffn = "swiglu"
        subs.append(Sublayer(mixer, ffn))
    return subs


def n_blocks(cfg: ModelConfig) -> int:
    size = cfg.attn_every if cfg.attn_every else 1
    assert cfg.n_layers % size == 0, (cfg.n_layers, size)
    return cfg.n_layers // size


# ---------------------------------------------------------------------------
# Init ------------------------------------------------------------------------
# ---------------------------------------------------------------------------
def _init_sublayer(cfg: ModelConfig, key, sub: Sublayer) -> Dict:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": L.rmsnorm_init(cfg), "norm2": {}}
    if sub.mixer == "gqa":
        p["mixer"] = L.gqa_init(cfg, k1)
    elif sub.mixer == "mla":
        p["mixer"] = L.mla_init(cfg, k1)
    elif sub.mixer == "mamba":
        p["mixer"] = L.mamba_init(cfg, k1)
    elif sub.mixer == "rwkv6":
        p["mixer"] = L.rwkv6_init(cfg, k1)
    if sub.ffn == "swiglu":
        p["norm2"] = L.rmsnorm_init(cfg)
        p["ffn"] = L.swiglu_init(cfg, k2)
    elif sub.ffn == "moe":
        p["norm2"] = L.rmsnorm_init(cfg)
        p["ffn"] = L.moe_init(cfg, k2)
    elif sub.ffn == "rwkv_channel":
        p["norm2"] = L.rmsnorm_init(cfg)
        # channel-mix params live inside rwkv6_init's "channel" entry
    return p


def init_block(cfg: ModelConfig, key) -> Dict:
    subs = block_template(cfg)
    keys = jax.random.split(key, len(subs))
    return {f"sub{j}": _init_sublayer(cfg, keys[j], sub)
            for j, sub in enumerate(subs)}


def init_params(cfg: ModelConfig, key) -> Dict:
    kb, ke, kh = jax.random.split(key, 3)
    nb = n_blocks(cfg)
    block_keys = jax.random.split(kb, nb)
    stacked = jax.vmap(lambda k: init_block(cfg, k))(block_keys)
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {"blocks": stacked,
                              "final_norm": L.rmsnorm_init(cfg)}
    if cfg.input_mode == "tokens":
        params["embed"] = {"w": L._init(ke, (cfg.padded_vocab, cfg.d_model),
                                        cfg.d_model, dt)}
    else:
        # modality-frontend stub: inputs arrive as embeddings; a light
        # input projection stands in for the (stubbed) projector.
        params["in_proj"] = {"w": L._init(ke, (cfg.d_model, cfg.d_model),
                                          cfg.d_model, dt)}
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        pass  # reuse embed
    else:
        params["lm_head"] = {"w": L._init(kh, (cfg.d_model, cfg.padded_vocab),
                                          cfg.d_model, dt)}
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill) -------------------------------------------------
# ---------------------------------------------------------------------------
def _apply_sublayer(sp, x, cfg: ModelConfig, sub: Sublayer, positions):
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_apply(sp["norm1"], x, cfg)
    if sub.mixer == "gqa":
        y = L.gqa_apply(sp["mixer"], h, cfg, positions)
    elif sub.mixer == "mla":
        y = L.mla_apply(sp["mixer"], h, cfg, positions)
    elif sub.mixer == "mamba":
        y = L.mamba_apply(sp["mixer"], h, cfg)
    elif sub.mixer == "rwkv6":
        y, _ = L.rwkv6_time_mix(sp["mixer"]["time"], h, cfg)
    x = x + y
    h = L.norm_apply(sp["norm2"], x, cfg)
    if sub.ffn == "swiglu":
        x = x + L.swiglu_apply(sp["ffn"], h)
    elif sub.ffn == "moe":
        x = x + L.moe_apply(sp["ffn"], h, cfg)
        aux = aux + L.moe_aux_loss(sp["ffn"], h, cfg)
    elif sub.ffn == "rwkv_channel":
        y, _ = L.rwkv6_channel_mix(sp["mixer"]["channel"], h)
        x = x + y
    return x, aux


def apply_blocks(params, x, cfg: ModelConfig, positions):
    subs = block_template(cfg)

    def body(carry, block_params):
        x, aux = carry
        x = shard(x, "batch", None, None)
        for j, sub in enumerate(subs):
            x, a = _apply_sublayer(block_params[f"sub{j}"], x, cfg, sub,
                                   positions)
            aux = aux + a
        x = shard(x, "batch", None, None)
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def embed_inputs(params, cfg: ModelConfig, inputs):
    if cfg.input_mode == "tokens":
        return jnp.take(params["embed"]["w"], inputs, axis=0)
    return inputs.astype(jnp.dtype(cfg.param_dtype)) @ params["in_proj"]["w"]


def unembed(params, cfg: ModelConfig, h):
    if "lm_head" in params:
        return h @ params["lm_head"]["w"]
    return h @ params["embed"]["w"].T


def forward(params, cfg: ModelConfig, inputs,
            positions: Optional[jnp.ndarray] = None):
    """Returns final hidden states (B, S, D) and the MoE aux loss."""
    s = inputs.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    x = shard(embed_inputs(params, cfg, inputs), "batch", None, None)
    x, aux = apply_blocks(params, x, cfg, positions)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, aux


def logits_fn(params, cfg: ModelConfig, inputs, positions=None):
    h, aux = forward(params, cfg, inputs, positions)
    return unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Loss + train step ------------------------------------------------------------
# ---------------------------------------------------------------------------
def chunked_ce_loss(params, cfg: ModelConfig, h, labels):
    """Cross-entropy over (B,S) labels without materializing (B,S,V).

    The sequence is processed in LOSS_CHUNK slices; each slice's logits are
    (B, C, V) — with V sharded on the ``model`` axis this is the memory-
    bounded version of the softmax head.
    """
    b, s, d = h.shape
    chunk = min(LOSS_CHUNK, s)
    assert s % chunk == 0
    n = s // chunk
    h_c = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    y_c = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def one(carry, hy):
        hc, yc = hy
        logits = unembed(params, cfg, hc).astype(jnp.float32)
        logits = shard(logits, "batch", None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (h_c, y_c))
    return total / (b * s)


def loss_fn(params, cfg: ModelConfig, batch):
    h, aux = forward(params, cfg, batch["inputs"])
    ce = chunked_ce_loss(params, cfg, h, batch["labels"])
    return ce + 0.01 * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, lr: float = 1e-3,
                    optimizer: str = "sgd"):
    """Returns train_step(params, batch) -> (params, metrics).

    Plain SGD by default (paper eqs. 3-6). ``batch`` has ``inputs`` (tokens
    (B,S) int32 or embeddings (B,S,D)) and ``labels`` (B,S) int32.
    """
    def train_step(params, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"loss": loss, "ce": ce, "aux": aux}

    return train_step


# ---------------------------------------------------------------------------
# Decode (serve_step) -----------------------------------------------------------
# ---------------------------------------------------------------------------
def init_sublayer_cache(cfg: ModelConfig, sub: Sublayer, batch: int,
                        cache_len: int, dtype):
    if sub.mixer == "gqa":
        return L.gqa_init_cache(cfg, batch, cache_len, dtype)
    if sub.mixer == "mla":
        return L.mla_init_cache(cfg, batch, cache_len, dtype)
    if sub.mixer == "mamba":
        return L.mamba_init_cache(cfg, batch, dtype)
    if sub.mixer == "rwkv6":
        return L.rwkv6_init_cache(cfg, batch, dtype)
    raise ValueError(sub.mixer)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Dict:
    """Stacked decode cache. For sliding-window configs the attention cache
    length is min(cache_len, window) — the point of SWA."""
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    subs = block_template(cfg)
    nb = n_blocks(cfg)

    def one_block(_):
        out = {}
        for j, sub in enumerate(subs):
            clen = cache_len
            if sub.mixer == "gqa" and cfg.sliding_window is not None:
                clen = min(cache_len, cfg.sliding_window)
            out[f"sub{j}"] = init_sublayer_cache(cfg, sub, batch, clen,
                                                 dtype)
        return out

    return jax.vmap(one_block)(jnp.arange(nb))


def _decode_sublayer(sp, cache, x, pos, cfg: ModelConfig, sub: Sublayer):
    h = L.norm_apply(sp["norm1"], x, cfg)
    if sub.mixer == "gqa":
        y, cache = L.gqa_decode(sp["mixer"], h, cache, pos, cfg)
    elif sub.mixer == "mla":
        y, cache = L.mla_decode(sp["mixer"], h, cache, pos, cfg)
    elif sub.mixer == "mamba":
        y, mcache = L.mamba_decode(sp["mixer"], h, cache, cfg)
        cache = mcache
    elif sub.mixer == "rwkv6":
        y, s_new, xt = L.rwkv6_time_mix_decode(
            sp["mixer"]["time"], h, cache["wkv"], cache["shift_t"], cfg)
        cache = dict(cache, wkv=s_new, shift_t=xt)
    x = x + y
    h = L.norm_apply(sp["norm2"], x, cfg)
    if sub.ffn == "swiglu":
        x = x + L.swiglu_apply(sp["ffn"], h)
    elif sub.ffn == "moe":
        x = x + L.moe_apply(sp["ffn"], h, cfg)
    elif sub.ffn == "rwkv_channel":
        y, xc = L.rwkv6_channel_mix_decode(sp["mixer"]["channel"], h,
                                           cache["shift_c"])
        cache = dict(cache, shift_c=xc)
        x = x + y
    return x, cache


def serve_step(params, cfg: ModelConfig, cache, inputs, pos):
    """Decode ONE token for the whole batch.

    inputs: (B, 1) int32 tokens or (B, 1, D) embeddings; ``pos`` scalar
    int32 absolute position. Returns (logits (B, V), new_cache).
    """
    subs = block_template(cfg)
    x = embed_inputs(params, cfg, inputs)

    def body(carry, scanned):
        x = carry
        block_params, block_cache = scanned
        new_cache = {}
        for j, sub in enumerate(subs):
            x, c = _decode_sublayer(block_params[f"sub{j}"],
                                    block_cache[f"sub{j}"], x, pos, cfg, sub)
            new_cache[f"sub{j}"] = c
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = unembed(params, cfg, x)[:, 0]
    return logits.astype(jnp.float32), new_cache
