"""Layer library for the model zoo (raw JAX init/apply pairs).

Components: RMSNorm / non-parametric LN, RoPE, GQA attention (+qk-norm,
sliding window), MLA (DeepSeek-V2 latent attention), SwiGLU FFN, MoE FFN
(shared + routed experts, capacity-based gather dispatch), Mamba block,
RWKV6 block. Every attention/ssm component has a paired decode step that
operates on an explicit cache (one token at a time) for ``serve_step``.

Naming conventions of weight leaves drive the sharding rules in
``repro.sharding.specs`` (e.g. ``wq``/``w1`` shard their output dim on the
``model`` mesh axis and their input dim on ``data`` for FSDP).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.activations import shard


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _init(key, shape, scale_dim, dtype):
    std = 1.0 / math.sqrt(scale_dim)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms ----------------------------------------------------------------------
# ---------------------------------------------------------------------------
def rmsnorm_init(cfg: ModelConfig, dim: Optional[int] = None):
    if cfg.norm_type == "nonparametric_ln":
        return {}
    return {"scale": jnp.ones((dim or cfg.d_model,), jnp.float32)}


def norm_apply(params, x, cfg: ModelConfig):
    """Norms compute their statistics in f32 but apply the (broadcast)
    factor in the compute dtype, so the (B,S,D)-sized multiply never
    materializes an f32 residual-stream tensor (EXPERIMENTS.md §Perf
    "bf16-norm-apply")."""
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "nonparametric_ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + 1e-5)
        return ((x - mu.astype(x.dtype)) * inv.astype(x.dtype))
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    factor = jax.lax.rsqrt(ms + 1e-6)
    return x * factor.astype(x.dtype) * params["scale"].astype(x.dtype)


def head_rmsnorm(x, scale):
    """qk-norm: RMS-normalize the head dim. x: (..., D_head)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    factor = jax.lax.rsqrt(ms + 1e-6)
    return x * factor.astype(x.dtype) * jnp.asarray(scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE -----------------------------------------------------------------------
# ---------------------------------------------------------------------------
def rope_frequencies(dim: int, theta: float):
    return theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, None]                               # (1,1,S,D/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, None]                                  # (B,1,S,D/2)
    # angles in f32, rotation applied in the compute dtype so no f32
    # q/k-sized tensors are materialized (EXPERIMENTS.md §Perf "bf16-rope")
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = x[..., ::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# GQA attention ---------------------------------------------------------------
# ---------------------------------------------------------------------------
def gqa_init(cfg: ModelConfig, key):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "wq": _init(ks[0], (d, hq * hd), d, dt),
        "wk": _init(ks[1], (d, hkv * hd), d, dt),
        "wv": _init(ks[2], (d, hkv * hd), d, dt),
        "wo": _init(ks[3], (hq * hd, d), hq * hd, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def gqa_apply(p, x, cfg: ModelConfig, positions,
              window: Optional[int] = None):
    """Full-sequence causal attention (training / prefill)."""
    from repro.kernels.flash_attention import ops as fa
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(shard(x @ p["wq"], "batch", None, "model"), hq, hd)
    k = _split_heads(shard(x @ p["wk"], "batch", None, "model"), hkv, hd)
    v = _split_heads(shard(x @ p["wv"], "batch", None, "model"), hkv, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"])
        k = head_rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    win = window if window is not None else cfg.sliding_window
    o = fa.attention(q, k, v, causal=True, window=win)
    b, _, s, _ = o.shape
    o = shard(o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd),
              "batch", None, "model")
    return shard(o @ p["wo"], "batch", None, None)


def gqa_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, cache_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, cache_len, hd), dtype),
    }


def gqa_decode(p, x, cache, pos, cfg: ModelConfig):
    """One-token decode. x: (B, 1, D); cache k/v: (B, Hkv, L, hd).

    ``pos`` is the absolute position of the new token; with a sliding-window
    cache of length L the cache slot is pos % L (ring buffer).
    """
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(x @ p["wq"], hq, hd)            # (B,Hq,1,hd)
    k = _split_heads(x @ p["wk"], hkv, hd)
    v = _split_heads(x @ p["wv"], hkv, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"])
        k = head_rmsnorm(k, p["k_norm"])
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    cache_len = cache["k"].shape[2]
    slot = jnp.mod(pos, cache_len)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                  k.astype(cache["k"].dtype),
                                                  slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                  v.astype(cache["v"].dtype),
                                                  slot, axis=2)
    # positions valid: <= pos and (ring) within the window
    idx = jnp.arange(cache_len)
    n_written = jnp.minimum(pos + 1, cache_len)
    valid = idx < n_written
    group = hq // hkv
    kr = jnp.repeat(k_cache, group, axis=1)
    vr = jnp.repeat(v_cache, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) / math.sqrt(hd)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", probs, vr.astype(jnp.float32))
    o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
    return o @ p["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2) ------------------------------
# ---------------------------------------------------------------------------
def mla_init(cfg: ModelConfig, key):
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    dt = _dtype(cfg)
    return {
        "wq": _init(ks[0], (d, h * (dn + dr)), d, dt),
        "wkv_a": _init(ks[1], (d, r + dr), d, dt),       # latent + shared rope key
        "wkv_b": _init(ks[2], (r, h * (dn + dv)), r, dt),
        "wo": _init(ks[3], (h * dv, d), h * dv, dt),
        "kv_norm": jnp.ones((r,), jnp.float32),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = x @ p["wkv_a"]                                   # (B,S,r+dr)
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = head_rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,dr)
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg: ModelConfig,
                causal_mask):
    """Attention over the latent cache.

    q_nope: (B,H,Sq,dn); q_rope: (B,H,Sq,dr); c_kv: (B,Skv,r);
    k_rope: (B,1,Skv,dr). Decompression of keys is folded into the query
    (q_nope @ wkv_b_k), so the cache stays rank-r — the MLA trick.
    """
    h = cfg.n_heads
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    wkv_b = p["wkv_b"].reshape(r, h, dn + dv)
    wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]         # (r,H,dn),(r,H,dv)
    # fold key decompression into the query: (B,H,Sq,r)
    q_lat = jnp.einsum("bhsd,rhd->bhsr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scores = (jnp.einsum("bhsr,btr->bhst", q_lat,
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bhsd,bhtd->bhst",
                           q_rope.astype(jnp.float32),
                           jnp.broadcast_to(
                               k_rope.astype(jnp.float32),
                               (k_rope.shape[0], h) + k_rope.shape[2:])))
    scores = scores / math.sqrt(dn + cfg.qk_rope_head_dim)
    if causal_mask is not None:
        scores = jnp.where(causal_mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bhsr", probs, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhsr,rhd->bhsd", o_lat, wv_b.astype(jnp.float32))
    return o


def mla_apply(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    mask = (jnp.arange(s)[None, :] <= jnp.arange(s)[:, None])[None, None]
    o = _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg, mask)
    o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(
        b, s, cfg.n_heads * cfg.v_head_dim)
    return o @ p["wo"]


def mla_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    b = x.shape[0]
    posv = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, posv)
    cache_len = cache["c_kv"].shape[1]
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1)
    r_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
        pos, axis=1)
    valid = jnp.arange(cache_len) <= pos
    o = _mla_attend(p, q_nope, q_rope, c_cache, r_cache[:, None], cfg,
                    valid[None, None, None, :])
    o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(
        b, 1, cfg.n_heads * cfg.v_head_dim)
    return o @ p["wo"], {"c_kv": c_cache, "k_rope": r_cache}


# ---------------------------------------------------------------------------
# FFNs ------------------------------------------------------------------------
# ---------------------------------------------------------------------------
def swiglu_init(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {"w1": _init(ks[0], (d, f), d, dt),
            "w3": _init(ks[1], (d, f), d, dt),
            "w2": _init(ks[2], (f, d), f, dt)}


def swiglu_apply(p, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    if h.ndim == 3:
        h = shard(h, "batch", None, "model")
    return h @ p["w2"]


def moe_init(cfg: ModelConfig, key):
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    dt = _dtype(cfg)
    p = {
        "router": _init(ks[0], (d, e), d, jnp.float32),
        "we1": _init(ks[1], (e, d, f), d, dt),
        "we3": _init(ks[2], (e, d, f), d, dt),
        "we2": _init(ks[3], (e, f, d), f, dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(cfg, ks[4],
                                  d_ff=f * cfg.n_shared_experts)
    return p


def moe_apply(p, x, cfg: ModelConfig):
    """Top-k routed experts with capacity-based gather dispatch.

    Two dispatch strategies (cfg.moe_grouped; EXPERIMENTS.md §Perf):

    * grouped (default): routing + capacity are evaluated *per sequence*
      (group = batch row), so the dispatched tensor (B, E, C, D) keeps the
      batch axis sharded on ``data`` and the expert axis on ``model`` —
      expert FLOPs scale with the full mesh.
    * naive: tokens flattened globally; each expert gathers its top-C
      tokens across the whole batch. The token axis loses its ``data``
      sharding (all-gather) and expert FLOPs shard only over ``model`` —
      16x waste on a (16,16) mesh. Kept for the perf ablation and for
      single-token decode, where per-sequence capacity degenerates and
      global dispatch is the right strategy.

    Overflow tokens beyond capacity drop to the shared experts/identity
    (the standard token-dropping approximation).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    if cfg.moe_grouped and s > 1:
        # Scatter-free dispatch+combine: both directions are GATHERS, which
        # GSPMD shards cleanly on (batch -> data, expert -> model). A
        # scatter-add combine forces operand replication + a (B,S,D)
        # all-reduce (EXPERIMENTS.md §Perf, iteration "moe-gather-combine").
        logits = (x @ p["router"]).astype(jnp.float32)        # (B,S,E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, k)                # (B,S,k)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        gates = jnp.zeros((b, s, e), jnp.float32).at[
            jnp.arange(b)[:, None, None], jnp.arange(s)[None, :, None],
            top_i].set(top_w)
        cap = max(1, min(s, int(k * s / e * cfg.capacity_factor)))
        g_bet = jax.lax.stop_gradient(gates.transpose(0, 2, 1))  # (B,E,S)
        # rank of every token within each expert's preference order
        # (pure index math -> no gradient; sort-grad also trips a jaxlib
        # bug with batched gathers in this environment)
        order = jnp.argsort(-g_bet, axis=-1)                  # (B,E,S)
        ranks = jnp.argsort(order, axis=-1).astype(jnp.int32)
        sel_i = order[..., :cap]                              # (B,E,C)
        xe = jnp.take_along_axis(x[:, None], sel_i[..., None], axis=2)
        xe = shard(xe, "batch", "model", None, None)          # (B,E,C,D)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["we1"])) \
            * jnp.einsum("becd,edf->becf", xe, p["we3"])
        h = shard(h, "batch", "model", None, None)
        ye = jnp.einsum("becf,efd->becd", h, p["we2"])        # (B,E,C,D)
        ye = shard(ye, "batch", "model", None, None)
        # combine: token (b,s) finds its slot in each chosen expert.
        # Reshard ye from expert-sharded to d_model-sharded first (an
        # all-to-all); the combine gather then never crosses the expert
        # shard, avoiding GSPMD's masked-gather + (B,S*k,D) all-reduce
        # (EXPERIMENTS.md §Perf "moe-alltoall-combine").
        ye = shard(ye.astype(x.dtype), "batch", None, None, "model")
        ranks_bse = ranks.transpose(0, 2, 1)                  # (B,S,E)
        slot = jnp.take_along_axis(ranks_bse, top_i, axis=2)  # (B,S,k)
        valid = slot < cap
        flat = ye.reshape(b, e * cap, d)
        idx = top_i * cap + jnp.minimum(slot, cap - 1)        # (B,S,k)
        yi = jnp.take_along_axis(flat, idx.reshape(b, s * k, 1), axis=1)
        yi = shard(yi.reshape(b, s, k, d), "batch", None, None, "model")
        w = (top_w * valid.astype(jnp.float32))[..., None]
        out = jnp.sum(w.astype(yi.dtype) * yi, axis=2)        # (B,S,D)
        out = shard(out, "batch", None, "model")
        if cfg.n_shared_experts:
            out = out + swiglu_apply(p["shared"], x)
        return shard(out.astype(x.dtype), "batch", None, None)
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt @ p["router"]).astype(jnp.float32)       # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                # (T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    gates = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], top_i].set(top_w)
    cap = max(1, min(t, int(k * t / e * cfg.capacity_factor)))
    g_et = gates.T                                        # (E,T)
    sel_w, sel_i = jax.lax.top_k(g_et, cap)               # (E,C)
    xe = jnp.take(xt, sel_i, axis=0)                      # (E,C,D)
    xe = shard(xe, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["we1"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["we3"])
    h = shard(h, "model", None, None)
    ye = jnp.einsum("ecf,efd->ecd", h, p["we2"])          # (E,C,D)
    ye = shard(ye, "model", None, None)
    ye = ye * sel_w[..., None].astype(ye.dtype)
    out = jnp.zeros((t, d), ye.dtype).at[sel_i.reshape(-1)].add(
        ye.reshape(-1, d))
    if cfg.n_shared_experts:
        out = out + swiglu_apply(p["shared"], xt)
    return out.reshape(b, s, d).astype(x.dtype)


def moe_aux_loss(p, x, cfg: ModelConfig):
    """Switch-style load-balance loss (importance * load)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax((xt @ p["router"]).astype(jnp.float32), axis=-1)
    importance = jnp.mean(probs, axis=0)
    top1 = jnp.argmax(probs, axis=-1)
    load = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts), axis=0)
    return cfg.n_experts * jnp.sum(importance * load)


# ---------------------------------------------------------------------------
# Mamba -----------------------------------------------------------------------
# ---------------------------------------------------------------------------
def mamba_init(cfg: ModelConfig, key):
    d = cfg.d_model
    di = cfg.expand * d
    st, ck = cfg.d_state, cfg.d_conv
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    dt_rank = max(1, d // 16)
    return {
        "in_proj": _init(ks[0], (d, 2 * di), d, dt),
        "conv_w": _init(ks[1], (ck, di), ck, jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * st), di, dt),
        "dt_proj": _init(ks[3], (dt_rank, di), dt_rank, jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, st + 1, dtype=jnp.float32), (di, st))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[4], (di, d), di, dt),
    }


def _mamba_ssm_scan(u, dt, b_t, c_t, a, chunk: int = 0):
    """Selective-state-space scan.

    u: (B,S,di) input; dt: (B,S,di); b_t,c_t: (B,S,st); a: (di,st).
    Returns y: (B,S,di).

    ``chunk`` > 0 enables the chunked+remat form: an outer lax.scan over
    S/chunk chunks whose body is ``jax.checkpoint``ed — the backward pass
    only stores the (B,di,st) state at chunk boundaries and rematerializes
    the per-step states, cutting the dominant training-memory term by
    ~S/chunk (EXPERIMENTS.md §Perf). ``chunk`` = 0 is the naive per-step
    scan, whose backward stores the state at every timestep.
    """
    b, s, di = u.shape
    st = a.shape[1]

    def seq_scan(h0, u_c, dt_c, bt_c, ct_c):
        """Per-step scan over the leading (time) axis of the chunk."""
        da = jnp.exp(jnp.einsum("sbd,dn->sbdn", dt_c, a))
        dbu = jnp.einsum("sbd,sbn->sbdn", dt_c * u_c, bt_c)

        def step(h, inp):
            da_t, dbu_t, c = inp
            h = da_t * h + dbu_t
            y = jnp.einsum("bdn,bn->bd", h, c)
            return h, y

        return jax.lax.scan(step, h0, (da, dbu, ct_c.astype(jnp.float32)))

    def vec_chunk(h0, u_c, dt_c, bt_c, ct_c):
        """Vectorized chunk body (EXPERIMENTS.md §Perf "mamba-cumsum"):
        h_t = P_t * (h_0 + cumsum_s(dbu_s / P_s)), P = cumprod(da) — the
        whole chunk is a handful of (C,B,di,st) vector ops instead of C
        sequential state updates. f32; 1/P is bounded for chunk <= 64."""
        da = jnp.exp(jnp.einsum("sbd,dn->sbdn", dt_c, a))
        dbu = jnp.einsum("sbd,sbn->sbdn", dt_c * u_c, bt_c)
        p_inc = jnp.cumprod(da, axis=0)                       # (C,b,di,st)
        acc = jnp.cumsum(dbu / jnp.maximum(p_inc, 1e-30), axis=0)
        h = p_inc * (h0[None] + acc)                          # (C,b,di,st)
        ys = jnp.einsum("sbdn,sbn->sbd", h, ct_c.astype(jnp.float32))
        return h[-1], ys

    u_t = u.transpose(1, 0, 2)
    dt_t = dt.transpose(1, 0, 2)
    bt_t = b_t.transpose(1, 0, 2)
    ct_t = c_t.transpose(1, 0, 2)
    h0 = jnp.zeros((b, di, st), jnp.float32)
    if not chunk or s <= chunk or s % chunk != 0:
        _, ys = seq_scan(h0, u_t, dt_t, bt_t, ct_t)
        return ys.transpose(1, 0, 2)

    n_chunks = s // chunk

    def chunk_body(h, inp):
        return vec_chunk(h, *inp)

    chunk_body = jax.checkpoint(chunk_body)
    resh = lambda t: t.reshape(n_chunks, chunk, b, t.shape[-1])
    _, ys = jax.lax.scan(chunk_body, h0,
                         (resh(u_t), resh(dt_t), resh(bt_t), resh(ct_t)))
    return ys.reshape(s, b, di).transpose(1, 0, 2)


def mamba_apply(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    di = cfg.expand * d
    st = cfg.d_state
    dt_rank = p["dt_proj"].shape[0]
    xz = shard(x @ p["in_proj"], "batch", None, "model")
    xi, z = xz[..., :di], xz[..., di:]
    # depthwise causal conv over the sequence
    ck = p["conv_w"].shape[0]
    xpad = jnp.pad(xi.astype(jnp.float32), ((0, 0), (ck - 1, 0), (0, 0)))
    conv = sum(xpad[:, i:i + s] * p["conv_w"][i] for i in range(ck))
    xi = jax.nn.silu(conv + p["conv_b"])
    proj = (xi.astype(x.dtype) @ p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    b_t = proj[..., dt_rank:dt_rank + st]
    c_t = proj[..., dt_rank + st:]
    a = -jnp.exp(p["a_log"])
    y = _mamba_ssm_scan(xi, dt, b_t, c_t, a, chunk=cfg.mamba_scan_chunk)
    y = y + xi * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x.dtype)) @ p["out_proj"]


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
    }


def mamba_decode(p, x, cache, cfg: ModelConfig):
    """One-token decode. x: (B,1,D)."""
    b = x.shape[0]
    d = cfg.d_model
    di = cfg.expand * d
    st = cfg.d_state
    dt_rank = p["dt_proj"].shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    ck = p["conv_w"].shape[0]
    hist = jnp.concatenate([cache["conv"].astype(jnp.float32),
                            xi.astype(jnp.float32)[:, None]], axis=1)
    conv = jnp.einsum("bkd,kd->bd", hist, p["conv_w"])
    xi_c = jax.nn.silu(conv + p["conv_b"])
    proj = (xi_c.astype(x.dtype) @ p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    b_t = proj[..., dt_rank:dt_rank + st]
    c_t = proj[..., dt_rank + st:]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(jnp.einsum("bd,dn->bdn", dt, a))
    h = da * cache["h"] + jnp.einsum("bd,bn->bdn", dt * xi_c, b_t)
    y = jnp.einsum("bdn,bn->bd", h, c_t)
    y = y + xi_c * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x.dtype)) @ p["out_proj"]
    new_cache = {"h": h,
                 "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return out[:, None], new_cache


# ---------------------------------------------------------------------------
# RWKV6 -----------------------------------------------------------------------
# ---------------------------------------------------------------------------
def rwkv6_init(cfg: ModelConfig, key):
    d = cfg.d_model
    h = max(1, d // 64)
    hd = d // h
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    return {
        "time": {
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_v": jnp.full((d,), 0.5, jnp.float32),
            "mix_w": jnp.full((d,), 0.5, jnp.float32),
            "mix_g": jnp.full((d,), 0.5, jnp.float32),
            "wr": _init(ks[0], (d, d), d, dt),
            "wk": _init(ks[1], (d, d), d, dt),
            "wv": _init(ks[2], (d, d), d, dt),
            "ww": _init(ks[3], (d, d), d, dt),      # data-dependent decay
            "wg": _init(ks[4], (d, d), d, dt),
            "w_bias": jnp.full((d,), -2.0, jnp.float32),
            "u": _init(ks[5], (h, hd), hd, jnp.float32),
            "wo": _init(ks[6], (d, d), d, dt),
            "ln_scale": jnp.ones((hd,), jnp.float32),
        },
        "channel": {
            "mix_k": jnp.full((d,), 0.5, jnp.float32),
            "mix_r": jnp.full((d,), 0.5, jnp.float32),
            "wck": _init(ks[7], (d, cfg.d_ff), d, dt),
            "wcv": _init(jax.random.fold_in(key, 99), (cfg.d_ff, d),
                         cfg.d_ff, dt),
            "wcr": _init(jax.random.fold_in(key, 98), (d, d), d, dt),
        },
    }


def _token_shift(x, prev=None):
    """Shift sequence right by one; prev: (B,D) last token of prior chunk."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, x, cfg: ModelConfig, shift_prev=None, wkv_state=None):
    """RWKV6 time-mix (attention replacement). Returns (out, (last_x, state)).

    Full-sequence form (training/prefill): uses the chunked WKV kernel.
    """
    from repro.kernels.wkv6 import ops as wkv_ops
    b, s, d = x.shape
    h = max(1, d // 64)
    hd = d // h
    xs = _token_shift(x, shift_prev)
    mix = lambda m: x * m.astype(x.dtype) + xs * (1.0 - m).astype(x.dtype)
    r = shard(mix(p["mix_r"]) @ p["wr"], "batch", None, "model")
    k = shard(mix(p["mix_k"]) @ p["wk"], "batch", None, "model")
    v = shard(mix(p["mix_v"]) @ p["wv"], "batch", None, "model")
    g = shard(mix(p["mix_g"]) @ p["wg"], "batch", None, "model")
    w_raw = mix(p["mix_w"]) @ p["ww"]
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32) + p["w_bias"]))

    def heads(t):
        return shard(t.reshape(b, s, h, hd).transpose(0, 2, 1, 3),
                     "batch", "model", None, None)

    o = wkv_ops.wkv(heads(r), heads(k), heads(v),
                    heads(w.astype(x.dtype)), p["u"].astype(x.dtype))
    # group-norm over each head then gate
    o = head_rmsnorm(o, p["ln_scale"])
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    return o @ p["wo"], x[:, -1]


def rwkv6_channel_mix(p, x, shift_prev=None):
    xs = _token_shift(x, shift_prev)
    xk = x * p["mix_k"] + xs * (1.0 - p["mix_k"])
    xr = x * p["mix_r"] + xs * (1.0 - p["mix_r"])
    k = jnp.square(jax.nn.relu(xk.astype(x.dtype) @ p["wck"]))
    if k.ndim == 3:
        k = shard(k, "batch", None, "model")
    kv = k @ p["wcv"]
    return jax.nn.sigmoid((xr.astype(x.dtype) @ p["wcr"]).astype(
        jnp.float32)).astype(x.dtype) * kv, x[:, -1]


def rwkv6_init_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    h = max(1, d // 64)
    hd = d // h
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
    }


def rwkv6_time_mix_decode(p, x, cache_wkv, shift_prev, cfg: ModelConfig):
    """One-token time-mix. x: (B,1,D)."""
    from repro.kernels.wkv6 import ops as wkv_ops
    b, _, d = x.shape
    h = max(1, d // 64)
    hd = d // h
    xt = x[:, 0]
    xs = shift_prev
    mix = lambda m: xt * m + xs * (1.0 - m)
    r = mix(p["mix_r"]).astype(x.dtype) @ p["wr"]
    k = mix(p["mix_k"]).astype(x.dtype) @ p["wk"]
    v = mix(p["mix_v"]).astype(x.dtype) @ p["wv"]
    g = mix(p["mix_g"]).astype(x.dtype) @ p["wg"]
    w_raw = mix(p["mix_w"]).astype(x.dtype) @ p["ww"]
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32) + p["w_bias"]))
    hsplit = lambda t: t.reshape(b, h, hd)
    s_new, o = wkv_ops.wkv_step(cache_wkv, hsplit(r), hsplit(k), hsplit(v),
                                hsplit(w.astype(x.dtype)),
                                p["u"].astype(x.dtype))
    o = head_rmsnorm(o, p["ln_scale"])
    o = o.reshape(b, d)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    return (o @ p["wo"])[:, None], s_new, xt


def rwkv6_channel_mix_decode(p, x, shift_prev):
    xt = x[:, 0]
    xk = xt * p["mix_k"] + shift_prev * (1.0 - p["mix_k"])
    xr = xt * p["mix_r"] + shift_prev * (1.0 - p["mix_r"])
    k = jnp.square(jax.nn.relu(xk.astype(x.dtype) @ p["wck"]))
    kv = k @ p["wcv"]
    out = jax.nn.sigmoid((xr.astype(x.dtype) @ p["wcr"]).astype(
        jnp.float32)).astype(x.dtype) * kv
    return out[:, None], xt
