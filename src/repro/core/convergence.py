"""Theorem 1 convergence-bound evaluator (Section V).

Evaluates the right-hand side of eq. (38) for a given run configuration so
experiments can compare the analytic bound against empirical gradient norms.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class ConvergenceConfig:
    smoothness: float            # L
    sigma_g: float               # mini-batch gradient noise std bound
    c_r: Sequence[float]         # per-round dissimilarity slope (Assumption 3)
    delta_r: Sequence[float]     # per-round dissimilarity offset
    h_local: int                 # H local iterations
    f0_minus_fstar: float        # F(w^0) - F*


def max_learning_rate(cfg: ConvergenceConfig, r: int) -> float:
    """eq. (37): eta^{(r)} <= 1 / (2 sqrt(1+c_r) H L)."""
    return 1.0 / (2.0 * np.sqrt(1.0 + cfg.c_r[r]) * cfg.h_local
                  * cfg.smoothness)


def decaying_lr(eta0: float, r: int) -> float:
    """eta^{(r)} = eta^{(0)} / (r+1) (Section V discussion)."""
    return eta0 / (r + 1)


def constant_lr(h: int, n_rounds: int) -> float:
    """eta = 1/sqrt(H R)."""
    return 1.0 / np.sqrt(h * n_rounds)


def theorem1_bound(cfg: ConvergenceConfig, etas: Sequence[float],
                   lambdas_sq: Sequence[float]) -> float:
    """RHS of eq. (38).

    ``lambdas_sq[r]`` = sum_i (lambda_i^{(r)})^2 over all nodes i in round r
    (time-varying because offloading changes the data portions).
    Returns the bound on (1/Gamma_R) sum_r eta_r E||grad F(w_r)||^2.
    """
    etas = np.asarray(etas, dtype=np.float64)
    lam2 = np.asarray(lambdas_sq, dtype=np.float64)
    c = np.asarray(cfg.c_r, dtype=np.float64)[: len(etas)]
    d2 = np.asarray(cfg.delta_r, dtype=np.float64)[: len(etas)] ** 2
    gamma = float(np.sum(etas))
    h, big_l, sg2 = cfg.h_local, cfg.smoothness, cfg.sigma_g ** 2
    term1 = 4.0 * cfg.f0_minus_fstar / (h * gamma)
    term2 = 4.0 * big_l / gamma * float(np.sum(etas ** 2 * lam2)) * sg2
    term3 = 2.0 * h ** 2 * big_l ** 2 * sg2 / gamma * float(np.sum(etas ** 3))
    term4 = 4.0 * h ** 2 * big_l ** 2 / gamma * float(np.sum(etas ** 3 * d2))
    return term1 + term2 + term3 + term4


def bound_decays_to_zero(cfg: ConvergenceConfig, n_rounds: int,
                         lambdas_sq: float = 1.0) -> np.ndarray:
    """Bound as a function of R with eta = 1/sqrt(HR); should -> 0."""
    out = []
    for r_tot in range(1, n_rounds + 1):
        eta = constant_lr(cfg.h_local, r_tot)
        etas = [eta] * r_tot
        lam2 = [lambdas_sq] * r_tot
        c = ConvergenceConfig(cfg.smoothness, cfg.sigma_g,
                              [cfg.c_r[0]] * r_tot, [cfg.delta_r[0]] * r_tot,
                              cfg.h_local, cfg.f0_minus_fstar)
        out.append(theorem1_bound(c, etas, lam2))
    return np.asarray(out)
