"""Data-placement strategy hooks for the round orchestrator.

Each strategy is a callable ``(orchestrator, round_index) -> OffloadPlan``
registered under the scheme names of Section VI-A, so the baselines are
executable policies rather than bare strings.  ``SAGINOrchestrator``
accepts either a registered name or any callable with this signature,
which is how experiments plug in custom placement policies.
"""
from __future__ import annotations

from typing import Callable, Dict, TYPE_CHECKING

from . import latency as lat
from .handover import space_latency
from .offloading import (ClusterPlan, OffloadPlan, cluster_case1,
                         evaluate_cluster, evaluate_plan,
                         optimize_offloading)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .scheduler import SAGINOrchestrator

StrategyFn = Callable[["SAGINOrchestrator", int], OffloadPlan]

STRATEGIES: Dict[str, StrategyFn] = {}


def register_strategy(name: str):
    def deco(fn: StrategyFn) -> StrategyFn:
        STRATEGIES[name] = fn
        return fn
    return deco


def resolve_strategy(strategy) -> StrategyFn:
    """Name -> hook lookup; callables pass through unchanged."""
    if callable(strategy):
        return strategy
    try:
        return STRATEGIES[strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}; registered: "
                         f"{sorted(STRATEGIES)}") from None


# ---------------------------------------------------------------------------
# The paper's schemes --------------------------------------------------------
# ---------------------------------------------------------------------------
@register_strategy("adaptive")
def plan_adaptive(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """The proposed method: Algorithms 1 & 2 every round."""
    return optimize_offloading(orch.sagin)


@register_strategy("static")
def plan_static(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """Adaptive optimization at round 0 only, then datasets stay frozen."""
    if orch._static_plan is None:
        orch._static_plan = optimize_offloading(orch.sagin)
    if r == 0:
        return orch._static_plan
    return null_plan(orch.sagin)


@register_strategy("none")
def plan_none(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """No data offloading: every node trains on what it already holds."""
    return null_plan(orch.sagin)


@register_strategy("air_ground")
def plan_air_ground(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """Offloading restricted to the air/ground layers (no space moves)."""
    sagin = orch.sagin
    clusters = [cluster_case1(sagin, n, 0.0) for n in sagin.clusters]
    plan = OffloadPlan(case=1, clusters=clusters,
                       new_sat_samples=sagin.n_sat_samples,
                       space_latency=space_latency(sagin.n_sat_samples,
                                                   sagin),
                       round_latency=0.0, baseline_latency=0.0)
    plan.round_latency = evaluate_plan(sagin, plan)
    return plan


@register_strategy("ground_space")
def plan_ground_space(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """Bypass air compute: full optimizer with air nodes as pure relays."""
    sagin = orch.sagin
    saved = [a.f for a in sagin.air_nodes]
    for a in sagin.air_nodes:
        a.f = 1.0  # effectively no compute at air layer
    try:
        plan = optimize_offloading(sagin)
    finally:
        for a, f in zip(sagin.air_nodes, saved):
            a.f = f
    return plan


@register_strategy("proportional")
def plan_proportional(orch: "SAGINOrchestrator", r: int) -> OffloadPlan:
    """Baseline: allocation proportional to each node's compute power."""
    sagin = orch.sagin
    f_sat = sagin.satellites[0].f
    f_total = (sum(d.f for d in sagin.devices)
               + sum(a.f for a in sagin.air_nodes) + f_sat)
    total = sagin.total_samples
    tgt_sat = total * f_sat / f_total
    clusters = []
    sat_delta = tgt_sat - sagin.n_sat_samples
    # distribute the satellite delta across clusters proportionally to
    # their offloadable mass; within each cluster move between air/ground
    offloadable = {n: sum(sagin.devices[k].n_offloadable
                          for k in sagin.clusters[n])
                   + sagin.air_nodes[n].n_samples
                   for n in sagin.clusters}
    off_total = max(1.0, sum(offloadable.values()))
    for n in sagin.clusters:
        cp = ClusterPlan(n=n)
        air = sagin.air_nodes[n]
        ks = sagin.clusters[n]
        if sat_delta > 0:  # clusters send up
            share = sat_delta * offloadable[n] / off_total
            cp.d_air_space = min(share, offloadable[n])
            # take from devices proportionally to their offloadable data
            need = max(0.0, cp.d_air_space - air.n_samples)
            dev_off = max(1.0, sum(sagin.devices[k].n_offloadable
                                   for k in ks))
            for k in ks:
                cp.d_ground_air[k] = (need * sagin.devices[k].n_offloadable
                                      / dev_off)
        else:  # satellite sends down
            share = -sat_delta / len(sagin.clusters)
            cp.d_space_air = share
        clusters.append(cp)
    plan = OffloadPlan(case=2 if sat_delta > 0 else 1, clusters=clusters,
                       new_sat_samples=sagin.n_sat_samples + sum(
                           c.d_air_space - c.d_space_air for c in clusters),
                       space_latency=0.0, round_latency=0.0,
                       baseline_latency=0.0)
    plan.space_latency = space_latency(plan.new_sat_samples, sagin)
    for cp in plan.clusters:
        cp.latency = evaluate_cluster(sagin, cp) + lat.model_upload_time(
            sagin.model_bits, sagin.a2s_rate(cp.n))
    plan.round_latency = evaluate_plan(sagin, plan)
    return plan


def null_plan(sagin) -> OffloadPlan:
    """The no-transfer plan with the current datasets (eq. 16 latency)."""
    clusters = [ClusterPlan(n=n) for n in sagin.clusters]
    plan = OffloadPlan(case=0, clusters=clusters,
                       new_sat_samples=sagin.n_sat_samples,
                       space_latency=space_latency(sagin.n_sat_samples,
                                                   sagin),
                       round_latency=0.0, baseline_latency=0.0)
    for cp in plan.clusters:
        cp.latency = (lat.air_cluster_latency_no_offload(sagin, cp.n)
                      + lat.model_upload_time(sagin.model_bits,
                                              sagin.a2s_rate(cp.n)))
    plan.round_latency = evaluate_plan(sagin, plan)
    return plan
