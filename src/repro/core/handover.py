"""Intra-layer data/model handover at the space layer (Section III-C).

Implements the seamless-handover schedule of eqs. (8)-(12): the current
satellite trains on D_S until its coverage window over the target region
ends; if unfinished it transmits the model + the dataset to the next
incoming satellite over the ISL (handover delay eq. 7), which resumes.
"""
from __future__ import annotations

import dataclasses
from typing import List

from . import latency as lat
from .network import SAGIN


@dataclasses.dataclass
class HandoverLeg:
    sat_index: int
    start_time: float            # when this satellite starts processing
    handover_delay: float        # ISL delay paid to *reach* this satellite
    samples_processed: float
    end_time: float              # when it stops (done or coverage end)


@dataclasses.dataclass
class SpaceSchedule:
    legs: List[HandoverLeg]
    total_latency: float
    completed: bool

    @property
    def n_handovers(self) -> int:
        return max(0, len(self.legs) - 1)


def space_schedule(n_samples: float, sagin: SAGIN) -> SpaceSchedule:
    """Compute the space-layer schedule for processing ``n_samples``.

    Faithful to eqs. (8)-(12): satellite i becomes active at
    T_{i-1} + tau^hand_{i-1,i}; it can process (f_i/m_i) * available_time
    samples before its own coverage end T_i. Returns the full schedule and
    tau_S^{(r)} (eq. 10).
    """
    legs: List[HandoverLeg] = []
    if n_samples <= 0:
        return SpaceSchedule(legs=[], total_latency=0.0, completed=True)

    remaining = float(n_samples)
    t = 0.0  # current wall-clock within the round
    for i, sat in enumerate(sagin.satellites):
        hand = 0.0
        if i > 0:
            # handover pays for the model + the *entire remaining* dataset
            # (the paper hands over D_S^{(r+1)}; eq. 7 uses |D_S^{(r+1)}|,
            # we use the unprocessed remainder which is what must move).
            hand = lat.handover_delay(sagin.model_bits, sagin.q_bits,
                                      remaining, sagin.z_isl)
            t = t + hand
        start = t
        finish_time = lat.comp_time(sat.m, remaining, sat.f)
        if start + finish_time <= sat.coverage_end:
            legs.append(HandoverLeg(sat.index, start, hand, remaining,
                                    start + finish_time))
            return SpaceSchedule(legs=legs, total_latency=start + finish_time,
                                 completed=True)
        # partial processing until coverage end
        avail = max(0.0, sat.coverage_end - start)
        done = (sat.f / sat.m) * avail
        done = min(done, remaining)
        legs.append(HandoverLeg(sat.index, start, hand, done,
                                sat.coverage_end))
        remaining -= done
        t = sat.coverage_end
    # Ran out of known incoming satellites: extrapolate with the last
    # satellite's parameters (an unbounded-coverage virtual satellite), so
    # the optimizer always sees a finite, monotone latency.
    last = sagin.satellites[-1]
    hand = lat.handover_delay(sagin.model_bits, sagin.q_bits, remaining,
                              sagin.z_isl)
    t += hand
    finish = lat.comp_time(last.m, remaining, last.f)
    legs.append(HandoverLeg(-1, t, hand, remaining, t + finish))
    return SpaceSchedule(legs=legs, total_latency=t + finish, completed=True)


def space_latency(n_samples: float, sagin: SAGIN) -> float:
    """tau_S^{(r)} (eq. 10) as a scalar."""
    return space_schedule(n_samples, sagin).total_latency
