"""Intra-layer data/model handover at the space layer (Section III-C).

Implements the seamless-handover schedule of eqs. (8)-(12): the current
satellite trains on D_S until its coverage window over the target region
ends; if unfinished it transmits the model + the dataset to the next
incoming satellite over the ISL (handover delay eq. 7), which resumes.
"""
from __future__ import annotations

import dataclasses
from typing import List

from . import latency as lat
from .network import SAGIN


@dataclasses.dataclass
class HandoverLeg:
    sat_index: int
    start_time: float            # when this satellite starts processing
    handover_delay: float        # ISL delay paid to *reach* this satellite
    samples_processed: float
    end_time: float              # when it stops (done or coverage end)


@dataclasses.dataclass
class SpaceSchedule:
    legs: List[HandoverLeg]
    total_latency: float
    completed: bool

    @property
    def n_handovers(self) -> int:
        return max(0, len(self.legs) - 1)


def space_schedule(n_samples: float, sagin: SAGIN) -> SpaceSchedule:
    """Compute the space-layer schedule for processing ``n_samples``.

    Faithful to eqs. (8)-(12): satellite i becomes active at
    T_{i-1} + tau^hand_{i-1,i}; it can process (f_i/m_i) * available_time
    samples before its own coverage end T_i. Returns the full schedule and
    tau_S^{(r)} (eq. 10).
    """
    legs: List[HandoverLeg] = []
    if n_samples <= 0:
        return SpaceSchedule(legs=[], total_latency=0.0, completed=True)

    remaining = float(n_samples)
    t = 0.0  # current wall-clock within the round
    for i, sat in enumerate(sagin.satellites):
        hand = 0.0
        if i > 0:
            # handover pays for the model + the *entire remaining* dataset
            # (the paper hands over D_S^{(r+1)}; eq. 7 uses |D_S^{(r+1)}|,
            # we use the unprocessed remainder which is what must move).
            hand = lat.handover_delay(sagin.model_bits, sagin.q_bits,
                                      remaining, sagin.z_isl)
            t = t + hand
        start = t
        finish_time = lat.comp_time(sat.m, remaining, sat.f)
        if start + finish_time <= sat.coverage_end:
            legs.append(HandoverLeg(sat.index, start, hand, remaining,
                                    start + finish_time))
            return SpaceSchedule(legs=legs, total_latency=start + finish_time,
                                 completed=True)
        # partial processing until coverage end
        avail = max(0.0, sat.coverage_end - start)
        done = (sat.f / sat.m) * avail
        done = min(done, remaining)
        legs.append(HandoverLeg(sat.index, start, hand, done,
                                sat.coverage_end))
        remaining -= done
        t = sat.coverage_end
    # Ran out of known incoming satellites: extrapolate with the last
    # satellite's parameters (an unbounded-coverage virtual satellite), so
    # the optimizer always sees a finite, monotone latency.
    last = sagin.satellites[-1]
    hand = lat.handover_delay(sagin.model_bits, sagin.q_bits, remaining,
                              sagin.z_isl)
    t += hand
    finish = lat.comp_time(last.m, remaining, last.f)
    legs.append(HandoverLeg(-1, t, hand, remaining, t + finish))
    return SpaceSchedule(legs=legs, total_latency=t + finish, completed=True)


def _schedule_from(t0: float, n_samples: float, satellites,
                   sagin: SAGIN) -> SpaceSchedule:
    """Schedule ``n_samples`` over ``satellites`` starting at wall time
    ``t0``, paying a leading ISL handover into the first satellite —
    the eq. (8)-(12) walk of :func:`space_schedule` re-rooted mid-round
    (used by unplanned-handover recovery).  Falls back to the virtual
    unbounded-coverage satellite when the chain runs dry, exactly as
    the planner does.
    """
    legs: List[HandoverLeg] = []
    remaining = float(n_samples)
    t = t0
    sats = list(satellites) if satellites else [sagin.satellites[-1]]
    for i, sat in enumerate(sats):
        hand = lat.handover_delay(sagin.model_bits, sagin.q_bits,
                                  remaining, sagin.z_isl)
        t = t + hand
        start = t
        finish_time = lat.comp_time(sat.m, remaining, sat.f)
        if start + finish_time <= sat.coverage_end or i == len(sats) - 1:
            # last known satellite extrapolates unbounded (virtual
            # successor), keeping recovery latency finite and monotone
            legs.append(HandoverLeg(sat.index, start, hand, remaining,
                                    start + finish_time))
            return SpaceSchedule(legs=legs,
                                 total_latency=start + finish_time,
                                 completed=True)
        avail = max(0.0, sat.coverage_end - start)
        done = min((sat.f / sat.m) * avail, remaining)
        legs.append(HandoverLeg(sat.index, start, hand, done,
                                sat.coverage_end))
        remaining -= done
        t = sat.coverage_end
    return SpaceSchedule(legs=legs, total_latency=t, completed=True)


def replan_after_loss(schedule: SpaceSchedule, loss_time: float,
                      sagin: SAGIN):
    """Recover from the serving satellite dying mid-coverage.

    The planned ``schedule`` assumed its legs run to completion; at wall
    time ``loss_time`` (within the round) the active satellite is lost
    without warning.  Recovery truncates the active leg at the loss
    instant, pays an UNPLANNED handover — model + the *unprocessed*
    remainder — to the successor satellite over the ISL (eq. 7), and
    resumes the eq. (8)-(12) walk there.

    Returns ``(recovered, restart_latency)``: the recovered
    :class:`SpaceSchedule` (original legs up to the loss + the re-planned
    tail) and the latency of the naive alternative — restarting the
    whole space computation from scratch on the successor (re-sending
    the model + the FULL dataset and reprocessing everything) — the
    baseline the recovered path must beat
    (gated in ``benchmarks/resilience.py``).
    """
    if not schedule.legs:
        return schedule, schedule.total_latency
    total = sum(leg.samples_processed for leg in schedule.legs)
    loss_time = min(max(0.0, loss_time), schedule.total_latency)
    if loss_time >= schedule.total_latency:
        return schedule, schedule.total_latency  # already finished
    # active leg: the one whose [start, end) window holds the loss
    j = len(schedule.legs) - 1
    for i, leg in enumerate(schedule.legs):
        if loss_time < leg.end_time:
            j = i
            break
    active = schedule.legs[j]
    kept = list(schedule.legs[:j])
    window = max(active.end_time - active.start_time, 0.0)
    frac = ((loss_time - active.start_time) / window) if window > 0 else 0.0
    frac = min(max(frac, 0.0), 1.0)
    partial = frac * active.samples_processed
    if partial > 0:
        kept.append(HandoverLeg(active.sat_index, active.start_time,
                                active.handover_delay, partial, loss_time))
    done_before = sum(leg.samples_processed for leg in kept)
    remaining = max(0.0, total - done_before)
    successors = sagin.satellites[j + 1:]
    tail = _schedule_from(loss_time, remaining, successors, sagin)
    recovered = SpaceSchedule(legs=kept + tail.legs,
                              total_latency=tail.total_latency,
                              completed=True)
    restart = _schedule_from(loss_time, total, successors,
                             sagin).total_latency
    return recovered, restart


def space_latency(n_samples: float, sagin: SAGIN) -> float:
    """tau_S^{(r)} (eq. 10) as a scalar."""
    return space_schedule(n_samples, sagin).total_latency
