"""Walker-Star LEO constellation and coverage-time computation.

Replaces MATLAB's ``walkerStar`` + ``accessIntervals`` (Section VI-A):
80 satellites evenly distributed across 5 circular orbits at 800 km
altitude, 85 deg inclination; target region at 40N, 86W; minimum
elevation angle 15 deg. Pure NumPy orbital geometry (spherical Earth).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

MU_EARTH = 3.986004418e14      # m^3/s^2
R_EARTH = 6371e3               # m
OMEGA_EARTH = 7.2921159e-5     # rad/s


@dataclasses.dataclass(frozen=True)
class WalkerStar:
    """Frozen (hashable) so derived geometry — the propagation engine's
    basis GEMM operands — can be memoized per constellation; derive
    variants with ``dataclasses.replace`` instead of mutating."""
    n_sats: int = 80
    n_planes: int = 5
    altitude: float = 800e3
    inclination_deg: float = 85.0
    phasing: int = 1             # inter-plane phasing factor F

    @property
    def sats_per_plane(self) -> int:
        return self.n_sats // self.n_planes

    @property
    def semi_major(self) -> float:
        return R_EARTH + self.altitude

    @property
    def mean_motion(self) -> float:
        return float(np.sqrt(MU_EARTH / self.semi_major ** 3))

    def positions_eci(self, t: np.ndarray) -> np.ndarray:
        """ECI positions, shape (len(t), n_sats, 3)."""
        t = np.atleast_1d(np.asarray(t, dtype=np.float64))
        inc = np.deg2rad(self.inclination_deg)
        S, P = self.sats_per_plane, self.n_planes
        # Star pattern: RAAN spread over 180 degrees.
        raan = np.pi * np.arange(P) / P                      # (P,)
        base_u = 2 * np.pi * np.arange(S) / S                # (S,)
        phase = 2 * np.pi * self.phasing / self.n_sats
        u0 = base_u[None, :] + phase * np.arange(P)[:, None]  # (P,S)
        u = u0[None, :, :] + self.mean_motion * t[:, None, None]  # (T,P,S)
        a = self.semi_major
        # position in orbital plane -> ECI
        cos_u, sin_u = np.cos(u), np.sin(u)
        x_orb = a * cos_u
        y_orb = a * sin_u
        ci, si = np.cos(inc), np.sin(inc)
        cr, sr = np.cos(raan), np.sin(raan)                  # (P,)
        cr = cr[None, :, None]
        sr = sr[None, :, None]
        x = x_orb * cr - y_orb * ci * sr
        y = x_orb * sr + y_orb * ci * cr
        z = y_orb * si
        pos = np.stack([x, y, z], axis=-1)                   # (T,P,S,3)
        return pos.reshape(len(t), self.n_sats, 3)


def target_eci(lat_deg: float, lon_deg: float, t: np.ndarray) -> np.ndarray:
    """ECI position of a ground target on the rotating Earth, (len(t),3)."""
    t = np.atleast_1d(np.asarray(t, dtype=np.float64))
    lat = np.deg2rad(lat_deg)
    lon = np.deg2rad(lon_deg) + OMEGA_EARTH * t
    return np.stack([
        R_EARTH * np.cos(lat) * np.cos(lon),
        R_EARTH * np.cos(lat) * np.sin(lon),
        np.full_like(t, R_EARTH * np.sin(lat)),
    ], axis=-1)


def elevation_angles(constellation: WalkerStar, lat_deg: float,
                     lon_deg: float, t: np.ndarray) -> np.ndarray:
    """Elevation (rad) of every satellite seen from the target, (T, n_sats)."""
    sats = constellation.positions_eci(t)                    # (T,N,3)
    tgt = target_eci(lat_deg, lon_deg, t)[:, None, :]        # (T,1,3)
    rel = sats - tgt
    up = tgt / np.linalg.norm(tgt, axis=-1, keepdims=True)
    rel_norm = np.linalg.norm(rel, axis=-1)
    sin_elev = np.sum(rel * up, axis=-1) / rel_norm
    return np.arcsin(np.clip(sin_elev, -1.0, 1.0))


@dataclasses.dataclass
class AccessInterval:
    sat: int
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


def access_intervals(constellation: WalkerStar, lat_deg: float = 40.0,
                     lon_deg: float = -86.0, t_end: float = 6 * 3600.0,
                     dt: float = 10.0,
                     min_elevation_deg: float = 15.0) -> List[AccessInterval]:
    """MATLAB ``accessIntervals`` equivalent: per-satellite coverage windows.

    Delegates to the vectorized multi-region engine in
    ``repro.sim.propagation`` (same boundary conventions and ordering as
    the original per-satellite loop, which survives there as
    ``access_intervals_loop`` for equivalence tests and benchmarks).
    """
    from repro.sim.propagation import access_intervals_vec
    return access_intervals_vec(constellation, lat_deg, lon_deg, t_end=t_end,
                                dt=dt, min_elevation_deg=min_elevation_deg)


def serving_sequence(intervals: Sequence[AccessInterval], t0: float,
                     max_sats: int = 8) -> List[AccessInterval]:
    """Greedy chain of serving satellites starting at wall-clock ``t0``.

    Picks, at each handover instant, the visible satellite with the longest
    remaining coverage; returns up to ``max_sats`` legs. These supply the
    T_i^{(r)} values for the round's latency model.
    """
    chain: List[AccessInterval] = []
    t = t0
    for _ in range(max_sats):
        candidates = [iv for iv in intervals if iv.start <= t < iv.end]
        if not candidates:
            upcoming = [iv for iv in intervals if iv.start >= t]
            if not upcoming:
                break
            nxt = min(upcoming, key=lambda iv: iv.start)
            t = nxt.start
            candidates = [nxt]
        best = max(candidates, key=lambda iv: iv.end)
        if chain and best.sat == chain[-1].sat and best.end == chain[-1].end:
            break
        chain.append(best)
        t = best.end
    return chain
