"""SAGIN network model: nodes, channels, and transmission rates.

Implements the system model of Section II and the channel/rate models of
Section III-D (eqs. 14-15) of the paper. All rates are in bits/sec, times in
seconds, data sizes in #samples (converted to bits via ``q_bits``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Paper constants (Section VI-A) --------------------------------------------
# ---------------------------------------------------------------------------
F_GROUND = 1e8          # Hz, f_{G,k}
F_AIR = 1e9             # Hz, f_{A,n}
F_SAT_RANGE = (1e9, 1e10)  # Hz, f_{S,i} ~ U[1,10]e9
M_CYCLES = 3e9          # cycles/sample, m_{G}=m_{A}=m_{S}
P_GROUND = 0.1          # W
P_AIR = 1.0             # W
P_SAT = 10.0            # W
Z_ISL = 3.125e6         # bits/s, inter-satellite link rate (paper constant)
N0 = 3.98e-21           # W/Hz noise PSD
B_G2A = 1e6             # Hz per-device uplink bandwidth (paper leaves B implicit)
B_A2S = 1e7             # Hz air->satellite bandwidth
BETA0 = 1e-4            # channel gain at reference distance 1 m (-40 dB, standard)
GAMMA_G2A = 2.4         # ground-air pathloss exponent under obstacles
AIR_ALTITUDE = 20e3     # m
SAT_ALTITUDE = 800e3    # m
REGION_SIZE = 1200.0    # m (square side)


@dataclasses.dataclass
class GroundDevice:
    """A terrestrial device k in the target region."""
    index: int
    position: np.ndarray            # (2,) position in the region, meters
    f: float = F_GROUND             # CPU frequency (cycles/s)
    m: float = M_CYCLES             # cycles per sample
    p: float = P_GROUND             # transmit power (W)
    n_samples: int = 0              # |D_{G,k}^{(r)}|
    n_sensitive: int = 0            # |D_k^l| (never leaves the device)

    @property
    def n_offloadable(self) -> int:
        return max(0, self.n_samples - self.n_sensitive)


@dataclasses.dataclass
class AirNode:
    """A UAV n hovering above its cluster of ground devices."""
    index: int
    position: np.ndarray            # (2,) horizontal position, meters
    altitude: float = AIR_ALTITUDE
    f: float = F_AIR
    m: float = M_CYCLES
    p: float = P_AIR
    n_samples: int = 0              # |D_{A,n}^{(r)}|


@dataclasses.dataclass
class Satellite:
    """The i-th satellite covering the region during round r."""
    index: int
    f: float                        # CPU frequency (time-varying per paper)
    m: float = M_CYCLES
    p: float = P_SAT
    coverage_end: float = np.inf    # T_i^{(r)}: seconds from round start


@dataclasses.dataclass
class ChannelModel:
    """Channel/rate model (eq. 15 and footnote 2)."""
    bandwidth_g2a: float = B_G2A
    bandwidth_a2s: float = B_A2S
    n0: float = N0
    beta0: float = BETA0
    gamma_g2a: float = GAMMA_G2A
    rayleigh: bool = True           # False -> free-space path loss (Fig. 7)
    mc_samples: int = 4096          # Monte-Carlo samples for E[.] in eq. (15)
    seed: int = 0

    def g2a_rate(self, device: GroundDevice, air: AirNode) -> float:
        """Uplink rate Z_{k,n}^{G2A} (eq. 15), bits/s."""
        d = float(np.sqrt(np.sum((device.position - air.position) ** 2)
                          + air.altitude ** 2))
        b = self.bandwidth_g2a
        if self.rayleigh:
            rng = np.random.default_rng(self.seed + 7919 * device.index
                                        + 104729 * air.index)
            g = rng.exponential(1.0, self.mc_samples)  # |Rayleigh|^2 ~ Exp(1)
            gain = self.beta0 / d ** self.gamma_g2a * g
        else:
            gain = np.asarray([self.beta0 / d ** 2])   # LoS free-space
        snr = device.p * gain / (b * self.n0)
        return float(np.mean(b * np.log2(1.0 + snr)))

    def a2s_rate(self, air: AirNode, sat_altitude: float = SAT_ALTITUDE) -> float:
        """Air->satellite rate Z_{n,S}^{A2S}, free-space (always LoS)."""
        d = sat_altitude - air.altitude
        b = self.bandwidth_a2s
        gain = self.beta0 / d ** 2
        snr = air.p * gain / (b * self.n0)
        return float(b * np.log2(1.0 + snr))

    def s2a_rate(self, air: AirNode, sat_power: float = P_SAT,
                 sat_altitude: float = SAT_ALTITUDE) -> float:
        """Satellite->air downlink rate Z_{S,n}^{S2A} (symmetric geometry)."""
        d = sat_altitude - air.altitude
        b = self.bandwidth_a2s
        gain = self.beta0 / d ** 2
        snr = sat_power * gain / (b * self.n0)
        return float(b * np.log2(1.0 + snr))


def isl_rate(p_tx: float = P_SAT, bandwidth: float = B_A2S,
             tx_gain: float = 1e4, rx_gain: float = 1e4,
             distance: float = 2000e3, n0: float = N0,
             wavelength: float = 0.015) -> float:
    """ISL rate Z_{i,i+1} = B log2(1 + p A_tx A_rx / (C N0 B)).

    C is free-space path loss (4 pi d / lambda)^2. Defaults give ~Mbps range,
    consistent with the paper's Z_ISL = 3.125 Mbps operating point.
    """
    c = (4.0 * np.pi * distance / wavelength) ** 2
    snr = p_tx * tx_gain * rx_gain / (c * n0 * bandwidth)
    return float(bandwidth * np.log2(1.0 + snr))


@dataclasses.dataclass
class SAGIN:
    """Full network state at the start of a global round."""
    devices: List[GroundDevice]
    air_nodes: List[AirNode]
    clusters: Dict[int, List[int]]      # air index -> list of device indices
    satellites: List[Satellite]         # current + incoming, ordered
    channel: ChannelModel
    q_bits: float                       # bits per data sample
    model_bits: float                   # Q(w)
    n_sat_samples: int = 0              # |D_S^{(r)}|
    z_isl: float = Z_ISL

    # cached rates -----------------------------------------------------------
    def __post_init__(self):
        self._g2a = {}
        self._a2s = {}
        self._s2a = {}
        for n, ks in self.clusters.items():
            air = self.air_nodes[n]
            self._a2s[n] = self.channel.a2s_rate(air)
            self._s2a[n] = self.channel.s2a_rate(air)
            for k in ks:
                self._g2a[(k, n)] = self.channel.g2a_rate(self.devices[k], air)

    def g2a_rate(self, k: int, n: int) -> float:
        return self._g2a[(k, n)]

    def a2s_rate(self, n: int) -> float:
        return self._a2s[n]

    def s2a_rate(self, n: int) -> float:
        return self._s2a[n]

    def cluster_of(self, k: int) -> int:
        for n, ks in self.clusters.items():
            if k in ks:
                return n
        raise KeyError(k)

    @property
    def total_samples(self) -> int:
        return (sum(d.n_samples for d in self.devices)
                + sum(a.n_samples for a in self.air_nodes)
                + self.n_sat_samples)


def build_default_sagin(n_devices: int = 50, n_air: int = 5,
                        samples_per_device: int = 1200,
                        alpha: float = 0.8,
                        q_bits: float = 28 * 28 * 8,
                        model_bits: float = 1e6 * 32,
                        rayleigh: bool = True,
                        sat_f_list: Sequence[float] | None = None,
                        coverage_times: Sequence[float] | None = None,
                        seed: int = 0) -> SAGIN:
    """Construct the paper's Section VI-A setup."""
    rng = np.random.default_rng(seed)
    devices = []
    for k in range(n_devices):
        pos = rng.uniform(0.0, REGION_SIZE, size=2)
        ns = samples_per_device
        devices.append(GroundDevice(index=k, position=pos, n_samples=ns,
                                    n_sensitive=int(round((1 - alpha) * ns))))
    air_nodes = []
    per = n_devices // n_air
    clusters: Dict[int, List[int]] = {}
    # assign devices to air nodes by simple geographic stripes
    order = sorted(range(n_devices), key=lambda k: devices[k].position[0])
    for n in range(n_air):
        ks = order[n * per:(n + 1) * per]
        cx = float(np.mean([devices[k].position[0] for k in ks]))
        cy = float(np.mean([devices[k].position[1] for k in ks]))
        air_nodes.append(AirNode(index=n, position=np.array([cx, cy])))
        clusters[n] = list(ks)
    if sat_f_list is None:
        sat_f_list = rng.uniform(*F_SAT_RANGE, size=4)
    if coverage_times is None:
        coverage_times = [120.0 * (i + 1) for i in range(len(sat_f_list))]
    sats = [Satellite(index=i, f=float(f), coverage_end=float(t))
            for i, (f, t) in enumerate(zip(sat_f_list, coverage_times))]
    channel = ChannelModel(rayleigh=rayleigh, seed=seed)
    return SAGIN(devices=devices, air_nodes=air_nodes, clusters=clusters,
                 satellites=sats, channel=channel, q_bits=q_bits,
                 model_bits=model_bits)
