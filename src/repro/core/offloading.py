"""Adaptive inter-layer data offloading (Section IV, Algorithms 1 & 2).

The paper solves ``min max{tau_S, max_n (tau_A,n + tau_A2S)}`` with
hierarchical bisection: an outer bisection on the amount of data moved
between the space and air layers, and inner bisections equalizing per-node
completion times. We implement the same fixed point organized as a single
bisection on the achieved round latency ``T`` with closed-form per-node
"absorb capacity" / "shed need" inverses of the piecewise-linear latency
functions (eqs. 21, 24-25, 30, 33-34). This produces the same solution to
within the bisection tolerance while keeping the control plane fast; the
literal nested pseudocode of Algorithms 1-2 is provided in
``algorithm1_literal`` and cross-validated in tests.

Directions follow Section IV-A:
  Case I  (tau_S > tau_air):  space -> air -> (possibly) ground
  Case II (tau_S < tau_air):  ground -> air -> space
Within a cluster the transfer direction between the air node and its ground
devices is chosen by the paper's per-cluster test.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import latency as lat
from .handover import space_latency
from .network import SAGIN

_EPS = 1e-6


# ---------------------------------------------------------------------------
# Generic bisection helpers --------------------------------------------------
# ---------------------------------------------------------------------------
def bisect_min_feasible(pred, lo: float, hi: float, tol: float,
                        max_iter: int = 80) -> float:
    """Smallest x in [lo,hi] with pred(x) True (pred monotone in x)."""
    if pred(lo):
        return lo
    if not pred(hi):
        return hi
    for _ in range(max_iter):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        if pred(mid):
            hi = mid
        else:
            lo = mid
    return hi


def bisect_max_feasible(pred, lo: float, hi: float, tol: float,
                        max_iter: int = 80) -> float:
    """Largest x in [lo,hi] with pred(x) True (pred anti-monotone in x)."""
    if not pred(lo):
        return lo
    if pred(hi):
        return hi
    for _ in range(max_iter):
        if hi - lo <= tol:
            break
        mid = 0.5 * (lo + hi)
        if pred(mid):
            lo = mid
        else:
            hi = mid
    return lo


# ---------------------------------------------------------------------------
# Closed-form per-device absorb / shed inverses ------------------------------
# ---------------------------------------------------------------------------
def _ground_absorb(a: float, b: float, c: float, e: float, up: float,
                   target: float, cap: float) -> float:
    """Max d with  max(a, b + c*d) + e*d + up <= target,  0 <= d <= cap.

    a: own computation time; b: pre-delay before samples arrive; c: per-sample
    receive delay; e: per-sample compute time; up: model upload delay.
    Closed-form inverse of eq. (25) / its intra-cluster variants.
    """
    t = target - up
    if max(a, b) > t + _EPS:
        return 0.0
    d1 = (t - a) / e if e > 0 else math.inf
    if b + c * d1 <= a + _EPS:
        d = d1
    else:
        d = (t - b) / (c + e) if (c + e) > 0 else math.inf
    return max(0.0, min(d, cap))


def _ground_shed_need(own_t: float, c_send: float, e: float, up: float,
                      target: float, n_samples: float,
                      cap: float) -> Tuple[float, bool]:
    """Min d with  max(e*(n-d), c_send*d) + up <= target,  d <= cap.

    Inverse of eq. (34) + upload. Returns (d, feasible).
    own_t = e*n is the no-shed computation time.
    """
    t = target - up
    if own_t <= t + _EPS:
        return 0.0, True
    if e <= 0:
        return 0.0, False
    d = n_samples - t / e          # from e*(n-d) = t
    if d > cap + _EPS:
        return min(d, cap), False
    if c_send * d > t + _EPS:      # sending that much already misses target
        return d, False
    return max(0.0, d), True


# ---------------------------------------------------------------------------
# Plans ----------------------------------------------------------------------
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ClusterPlan:
    n: int
    d_space_air: float = 0.0                 # + : satellite -> air node n
    d_air_space: float = 0.0                 # + : air node n -> satellite
    d_air_ground: Dict[int, float] = dataclasses.field(default_factory=dict)
    d_ground_air: Dict[int, float] = dataclasses.field(default_factory=dict)
    latency: float = 0.0                     # tau_A,n-bar + tau_A2S


@dataclasses.dataclass
class OffloadPlan:
    case: int                                # 0: none, 1: S->A/G, 2: A/G->S
    clusters: List[ClusterPlan]
    new_sat_samples: float
    space_latency: float
    round_latency: float
    baseline_latency: float                  # eq. (16), no offloading

    def new_sizes(self, sagin: SAGIN):
        """(ground sizes, air sizes, sat size) after applying the plan."""
        g = [d.n_samples for d in sagin.devices]
        a = [x.n_samples for x in sagin.air_nodes]
        s = float(sagin.n_sat_samples)
        for cp in self.clusters:
            a[cp.n] += cp.d_space_air - cp.d_air_space
            s += cp.d_air_space - cp.d_space_air
            for k, d in cp.d_air_ground.items():
                g[k] += d
                a[cp.n] -= d
            for k, d in cp.d_ground_air.items():
                g[k] -= d
                a[cp.n] += d
        # clip numerical dust (sub-sample negatives) back onto the satellite
        for i, v in enumerate(a):
            if -1.0 < v < 0.0:
                s += v
                a[i] = 0.0
        for i, v in enumerate(g):
            if -1.0 < v < 0.0:
                s += v
                g[i] = 0.0
        return g, a, s


# ---------------------------------------------------------------------------
# Intra-cluster balancing, Case I (Algorithm 1) ------------------------------
# ---------------------------------------------------------------------------
def cluster_case1(sagin: SAGIN, n: int, d_s2a: float,
                  tol: float = 1e-3) -> ClusterPlan:
    """Optimal intra-cluster allocation given ``d_s2a`` samples arriving
    from the satellite (Algorithm 1 + the symmetric ground->air sub-case)."""
    air = sagin.air_nodes[n]
    ks = sagin.clusters[n]
    recv_sat = lat.tx_time(sagin.q_bits * d_s2a, sagin.s2a_rate(n)) \
        if d_s2a > 0 else 0.0
    own_air = lat.comp_time(air.m, air.n_samples, air.f)
    e_air = air.m / air.f

    def air_delay_shed(y: float) -> float:
        # eq. (24): air forwards y of (its own + received) samples to ground
        new_size = air.n_samples + d_s2a - y
        if new_size <= air.n_samples:
            return lat.comp_time(air.m, max(0.0, new_size), air.f)
        return max(own_air, recv_sat) + e_air * (d_s2a - y)

    ground0 = 0.0
    for k in ks:
        dev = sagin.devices[k]
        up = lat.model_upload_time(sagin.model_bits, sagin.g2a_rate(k, n))
        ground0 = max(ground0, lat.comp_time(dev.m, dev.n_samples, dev.f) + up)
    air0 = air_delay_shed(0.0)

    plan = ClusterPlan(n=n, d_space_air=d_s2a)
    if air0 >= ground0:
        # --- air -> ground (Algorithm 1 as written) -----------------------
        max_shed = air.n_samples + d_s2a

        def absorb_total(t: float) -> Dict[int, float]:
            out = {}
            for k in ks:
                dev = sagin.devices[k]
                up = lat.model_upload_time(sagin.model_bits,
                                           sagin.g2a_rate(k, n))
                a = lat.comp_time(dev.m, dev.n_samples, dev.f)
                c = sagin.q_bits / sagin.g2a_rate(k, n)
                e = dev.m / dev.f
                out[k] = _ground_absorb(a, recv_sat, c, e, up, t,
                                        cap=max_shed)
            return out

        def ok(t: float) -> bool:
            y = min(sum(absorb_total(t).values()), max_shed)
            return air_delay_shed(y) <= t

        t_star = bisect_min_feasible(ok, lo=0.0, hi=air0, tol=tol)
        alloc = absorb_total(t_star)
        total = sum(alloc.values())
        # air only needs to shed enough to meet t_star (paper equalization)
        y_need = bisect_min_feasible(lambda y: air_delay_shed(y) <= t_star,
                                     0.0, max_shed, tol)
        if total > y_need > 0 and total > 0:
            scale = y_need / total
            alloc = {k: v * scale for k, v in alloc.items()}
        plan.d_air_ground = {k: v for k, v in alloc.items() if v > tol}
    else:
        # --- ground -> air (symmetric sub-case) ---------------------------
        def solve(t: float):
            sheds, feas = {}, True
            for k in ks:
                dev = sagin.devices[k]
                up = lat.model_upload_time(sagin.model_bits,
                                           sagin.g2a_rate(k, n))
                c_send = sagin.q_bits / sagin.g2a_rate(k, n)
                e = dev.m / dev.f
                cap = float(dev.n_offloadable)
                d, f = _ground_shed_need(e * dev.n_samples, c_send, e, up,
                                         t, dev.n_samples, cap)
                sheds[k] = d
                feas = feas and f
            return sheds, feas

        def air_delay_recv(sheds: Dict[int, float]) -> float:
            recv_g = max((sagin.q_bits * d / sagin.g2a_rate(k, n)
                          for k, d in sheds.items()), default=0.0)
            extra = d_s2a + sum(sheds.values())
            return max(own_air, recv_sat, recv_g) + e_air * extra

        def ok(t: float) -> bool:
            sheds, feas = solve(t)
            return feas and air_delay_recv(sheds) <= t

        t_star = bisect_min_feasible(ok, lo=0.0, hi=ground0, tol=tol)
        sheds, _ = solve(t_star)
        plan.d_ground_air = {k: v for k, v in sheds.items() if v > tol}

    plan.latency = evaluate_cluster(sagin, plan) \
        + lat.model_upload_time(sagin.model_bits, sagin.a2s_rate(n))
    return plan


# ---------------------------------------------------------------------------
# Intra-cluster balancing, Case II -------------------------------------------
# ---------------------------------------------------------------------------
def cluster_case2(sagin: SAGIN, n: int, d_a2s: float,
                  tol: float = 1e-3) -> ClusterPlan:
    """Optimal intra-cluster allocation given that air node n must also send
    ``d_a2s`` samples up to the satellite (Case II, Section IV-C)."""
    air = sagin.air_nodes[n]
    ks = sagin.clusters[n]
    e_air = air.m / air.f
    send_sat = lat.tx_time(sagin.q_bits * d_a2s, sagin.a2s_rate(n)) \
        if d_a2s > 0 else 0.0

    ground0 = 0.0
    for k in ks:
        dev = sagin.devices[k]
        up = lat.model_upload_time(sagin.model_bits, sagin.g2a_rate(k, n))
        ground0 = max(ground0, lat.comp_time(dev.m, dev.n_samples, dev.f) + up)
    air_own = max(lat.comp_time(air.m, max(0.0, air.n_samples - d_a2s),
                                air.f), send_sat)

    plan = ClusterPlan(n=n, d_air_space=d_a2s)
    if air_own < ground0:
        # --- ground -> air (the sub-case written out in the paper) --------
        def solve(t: float):
            sheds, feas = {}, True
            for k in ks:
                dev = sagin.devices[k]
                up = lat.model_upload_time(sagin.model_bits,
                                           sagin.g2a_rate(k, n))
                c_send = sagin.q_bits / sagin.g2a_rate(k, n)
                e = dev.m / dev.f
                cap = float(dev.n_offloadable)          # eq. (35)
                d, f = _ground_shed_need(e * dev.n_samples, c_send, e, up,
                                         t, dev.n_samples, cap)
                sheds[k] = d
                feas = feas and f
            return sheds, feas

        def air_delay(sheds: Dict[int, float]) -> float:
            # eq. (33)
            recv_g = max((sagin.q_bits * d / sagin.g2a_rate(k, n)
                          for k, d in sheds.items()), default=0.0)
            own = lat.comp_time(air.m, air.n_samples, air.f)
            extra = sum(sheds.values()) - d_a2s
            if extra <= 0:
                return max(lat.comp_time(
                    air.m, air.n_samples + extra, air.f), send_sat, recv_g)
            return max(max(own, recv_g) + e_air * extra, send_sat)

        def ok(t: float) -> bool:
            sheds, feas = solve(t)
            return feas and air_delay(sheds) <= t

        t_star = bisect_min_feasible(ok, 0.0, max(ground0, air_own), tol)
        sheds, _ = solve(t_star)
        # repair: the air node must actually hold d_a2s samples to forward
        deficit = d_a2s - air.n_samples - sum(sheds.values())
        if deficit > 0:
            caps = {k: max(0.0, sagin.devices[k].n_offloadable - sheds[k])
                    for k in ks}
            r = sum(caps.values())
            if r > 0:
                give = min(deficit, r)
                for k in ks:
                    sheds[k] += give * caps[k] / r
        plan.d_ground_air = {k: v for k, v in sheds.items() if v > tol}
    else:
        # --- air -> ground -------------------------------------------------
        max_shed = max(0.0, air.n_samples - d_a2s)

        def air_delay_shed(y: float) -> float:
            return max(lat.comp_time(air.m,
                                     max(0.0, air.n_samples - d_a2s - y),
                                     air.f), send_sat)

        def absorb_total(t: float) -> Dict[int, float]:
            out = {}
            for k in ks:
                dev = sagin.devices[k]
                up = lat.model_upload_time(sagin.model_bits,
                                           sagin.g2a_rate(k, n))
                a = lat.comp_time(dev.m, dev.n_samples, dev.f)
                c = sagin.q_bits / sagin.g2a_rate(k, n)
                e = dev.m / dev.f
                out[k] = _ground_absorb(a, 0.0, c, e, up, t, cap=max_shed)
            return out

        def ok(t: float) -> bool:
            y = min(sum(absorb_total(t).values()), max_shed)
            return air_delay_shed(y) <= t

        t_star = bisect_min_feasible(ok, 0.0, max(air_own, ground0), tol)
        alloc = absorb_total(t_star)
        total = sum(alloc.values())
        y_need = bisect_min_feasible(lambda y: air_delay_shed(y) <= t_star,
                                     0.0, max_shed, tol)
        if total > y_need > 0 and total > 0:
            scale = y_need / total
            alloc = {k: v * scale for k, v in alloc.items()}
        plan.d_air_ground = {k: v for k, v in alloc.items() if v > tol}

    plan.latency = evaluate_cluster(sagin, plan) \
        + lat.model_upload_time(sagin.model_bits, sagin.a2s_rate(n))
    return plan


# ---------------------------------------------------------------------------
# Faithful evaluation of a cluster plan (eqs. 19, 24-25, 33-34) --------------
# ---------------------------------------------------------------------------
def evaluate_cluster(sagin: SAGIN, cp: ClusterPlan,
                     offline: Sequence[int] = ()) -> float:
    """tau_A,n-bar (eq. 19): completion of air node n + its devices.

    Devices in ``offline`` (churned out for the round) neither train nor
    upload, so they do not bound the cluster's completion time.
    """
    n = cp.n
    offline = set(offline)
    air = sagin.air_nodes[n]
    ks = sagin.clusters[n]
    recv_sat = lat.tx_time(sagin.q_bits * cp.d_space_air, sagin.s2a_rate(n)) \
        if cp.d_space_air > 0 else 0.0
    send_sat = lat.tx_time(sagin.q_bits * cp.d_air_space,
                           sagin.a2s_rate(n)) if cp.d_air_space > 0 else 0.0
    recv_g = max((sagin.q_bits * d / sagin.g2a_rate(k, n)
                  for k, d in cp.d_ground_air.items()), default=0.0)
    sent = sum(cp.d_air_ground.values())
    recvd = sum(cp.d_ground_air.values())
    new_air = air.n_samples + cp.d_space_air - cp.d_air_space + recvd - sent
    own = lat.comp_time(air.m, air.n_samples, air.f)
    if new_air <= air.n_samples:
        t_air = max(lat.comp_time(air.m, max(0.0, new_air), air.f),
                    send_sat, recv_sat if sent > 0 else 0.0)
    else:
        extra = new_air - air.n_samples
        t_air = max(max(own, recv_sat, recv_g)
                    + lat.comp_time(air.m, extra, air.f), send_sat)

    t_ground = 0.0
    for k in ks:
        if k in offline:
            continue
        dev = sagin.devices[k]
        up = lat.model_upload_time(sagin.model_bits, sagin.g2a_rate(k, n))
        d_in = cp.d_air_ground.get(k, 0.0)
        d_out = cp.d_ground_air.get(k, 0.0)
        if d_in > 0:
            a = lat.comp_time(dev.m, dev.n_samples, dev.f)
            recv = recv_sat + sagin.q_bits * d_in / sagin.g2a_rate(k, n)
            t = max(a, recv) + lat.comp_time(dev.m, d_in, dev.f)
        else:
            comp = lat.comp_time(dev.m, dev.n_samples - d_out, dev.f)
            send = sagin.q_bits * d_out / sagin.g2a_rate(k, n)
            t = max(comp, send)
        t_ground = max(t_ground, t + up)
    return max(t_air, t_ground)


def evaluate_plan(sagin: SAGIN, plan: OffloadPlan) -> float:
    """Full round latency (eq. 18) for a candidate plan."""
    t_space = space_latency(plan.new_sat_samples, sagin)
    t_air = 0.0
    for cp in plan.clusters:
        t = evaluate_cluster(sagin, cp) + lat.model_upload_time(
            sagin.model_bits, sagin.a2s_rate(cp.n))
        t_air = max(t_air, t)
    return max(t_space, t_air)


# ---------------------------------------------------------------------------
# Global optimization (Algorithm 2 organized by latency target) --------------
# ---------------------------------------------------------------------------
def optimize_offloading(sagin: SAGIN, tol: float = 1e-2) -> OffloadPlan:
    """Main entry point: decide the case, then jointly optimize inter-layer
    transfer amounts and intra-cluster allocations (Algorithms 1 & 2)."""
    t_space0 = space_latency(sagin.n_sat_samples, sagin)
    t_clusters0 = {
        n: lat.air_cluster_latency_no_offload(sagin, n)
        + lat.model_upload_time(sagin.model_bits, sagin.a2s_rate(n))
        for n in sagin.clusters
    }
    t_air0 = max(t_clusters0.values())
    baseline = max(t_space0, t_air0)

    if abs(t_space0 - t_air0) <= tol:
        plan = OffloadPlan(case=0, clusters=[
            ClusterPlan(n=n, latency=t_clusters0[n]) for n in sagin.clusters],
            new_sat_samples=sagin.n_sat_samples, space_latency=t_space0,
            round_latency=baseline, baseline_latency=baseline)
        return plan

    if t_space0 > t_air0:
        plan = _solve_case1(sagin, baseline, tol)
    else:
        plan = _solve_case2(sagin, baseline, tol)
    plan.baseline_latency = baseline
    # Safety net: adaptive must never be worse than no offloading.
    if plan.round_latency > baseline + tol:
        plan = OffloadPlan(case=0, clusters=[
            ClusterPlan(n=n, latency=t_clusters0[n]) for n in sagin.clusters],
            new_sat_samples=sagin.n_sat_samples, space_latency=t_space0,
            round_latency=baseline, baseline_latency=baseline)
    return plan


def _space_shed_need(sagin: SAGIN, target: float, tol: float) -> float:
    """Min X with tau_S(|D_S| - X) <= target."""
    total = float(sagin.n_sat_samples)
    return bisect_min_feasible(
        lambda x: space_latency(total - x, sagin) <= target,
        0.0, total, tol)


def _space_absorb_cap(sagin: SAGIN, target: float, tol: float,
                      hi: float) -> float:
    """Max X with tau_S(|D_S| + X) <= target."""
    total = float(sagin.n_sat_samples)
    return bisect_max_feasible(
        lambda x: space_latency(total + x, sagin) <= target,
        0.0, hi, tol)


_GRID = 33


def _latency_grid(sagin: SAGIN, n: int, case: int, hi: float, tol: float):
    """Cluster latency (incl. A2S model upload) over a grid of transfer
    amounts — evaluated once so the hierarchical bisections of Algorithm 2
    become interpolations instead of nested exact solves."""
    import numpy as _np
    ds = _np.linspace(0.0, max(hi, 1.0), _GRID)
    fn = cluster_case1 if case == 1 else cluster_case2
    ls = _np.array([fn(sagin, n, float(d), tol).latency for d in ds])
    return ds, ls


def _grid_min_d(ds, ls, nu: float):
    """Smallest d on the grid with latency <= nu (inf if infeasible)."""
    import numpy as _np
    ok = ls <= nu
    if not ok.any():
        return float("inf")
    i = int(_np.argmax(ok))
    if i == 0:
        return float(ds[0])
    # linear interpolation between the bracketing grid points
    d0, d1, l0, l1 = ds[i - 1], ds[i], ls[i - 1], ls[i]
    if l0 == l1:
        return float(d1)
    return float(d0 + (d1 - d0) * (l0 - nu) / (l0 - l1))


def _grid_max_d(ds, ls, nu: float):
    """Largest d on the grid with latency <= nu (-inf if infeasible)."""
    import numpy as _np
    ok = ls <= nu
    if not ok.any():
        return float("-inf")
    i = len(ls) - 1 - int(_np.argmax(ok[::-1]))
    if i == len(ls) - 1:
        return float(ds[-1])
    d0, d1, l0, l1 = ds[i], ds[i + 1], ls[i], ls[i + 1]
    if l0 == l1:
        return float(d0)
    return float(d0 + (d1 - d0) * (nu - l0) / (l1 - l0))


def _solve_case1(sagin: SAGIN, baseline: float, tol: float) -> OffloadPlan:
    """Case I: offload from space to air/ground.

    Outer bisection on the total amount X shed by the satellite until
    tau_S(|D_S| - X) meets the air-layer completion time (Algorithm 2);
    the inner level spreads X across clusters at a common latency level
    (Algorithm 2 line 8 + Algorithm 1 via cluster_case1)."""
    total = float(sagin.n_sat_samples)
    ns = list(sagin.clusters)
    grids = {n: _latency_grid(sagin, n, 1, total, tol) for n in ns}
    nu_lo = max(float(g[1].min()) for g in grids.values())
    nu_hi = max(float(g[1].max()) for g in grids.values())

    def distribute(x: float):
        """Spread x across clusters equalizing latency; return (alloc, nu)."""
        def cap_total(nu: float) -> float:
            return sum(max(0.0, _grid_max_d(*grids[n], nu)) for n in ns)

        nu = bisect_min_feasible(lambda v: cap_total(v) >= x,
                                 nu_lo, nu_hi, max(tol, nu_hi * 1e-4),
                                 max_iter=40)
        caps = {n: max(0.0, _grid_max_d(*grids[n], nu)) for n in ns}
        s = sum(caps.values())
        scale = min(1.0, x / s) if s > 0 else 0.0
        return {n: caps[n] * scale for n in ns}, nu

    lo, hi = 0.0, total
    for _ in range(40):
        x = 0.5 * (lo + hi)
        _, t_air = distribute(x)
        if space_latency(total - x, sagin) >= t_air:
            lo = x
        else:
            hi = x
    alloc, _ = distribute(0.5 * (lo + hi))
    clusters = [cluster_case1(sagin, n, alloc[n], tol) for n in ns]
    new_sat = total - sum(alloc.values())
    plan = OffloadPlan(case=1, clusters=clusters, new_sat_samples=new_sat,
                       space_latency=space_latency(new_sat, sagin),
                       round_latency=0.0, baseline_latency=baseline)
    plan.round_latency = evaluate_plan(sagin, plan)
    return plan


def _solve_case2(sagin: SAGIN, baseline: float, tol: float) -> OffloadPlan:
    """Case II: offload from air/ground to space."""
    ns = list(sagin.clusters)
    max_shed = {}
    for n in ns:
        air = sagin.air_nodes[n]
        cap = float(air.n_samples) + sum(
            sagin.devices[k].n_offloadable for k in sagin.clusters[n])
        max_shed[n] = cap

    grids = {n: _latency_grid(sagin, n, 2, max_shed[n], tol) for n in ns}
    total0 = float(sagin.n_sat_samples)

    def distribute(x: float):
        """Spread x across clusters: each sheds its minimum need at the
        common latency level nu with sum(needs) = x; leftover (when the
        satellite absorbs more than the clusters *need*) goes to clusters
        with remaining offloadable data. Returns (alloc, t_air)."""
        nu_lo = max(float(g[1].min()) for g in grids.values())
        nu_hi = max(float(g[1][0]) for g in grids.values())

        def need_total(nu: float) -> float:
            t = 0.0
            for n in ns:
                d = _grid_min_d(*grids[n], nu)
                t += max_shed[n] if d == float("inf") else d
            return t

        # smallest nu whose total need fits within x (need decreasing in nu)
        nu = bisect_min_feasible(lambda v: need_total(v) <= x,
                                 nu_lo, nu_hi, max(tol, nu_hi * 1e-4),
                                 max_iter=40)
        alloc = {}
        for n in ns:
            d = _grid_min_d(*grids[n], nu)
            alloc[n] = max_shed[n] if d == float("inf") else d
        leftover = x - sum(alloc.values())
        if leftover > 0:
            room = {n: max(0.0, _grid_max_d(*grids[n], nu) - alloc[n])
                    for n in ns}
            r = sum(room.values())
            if r > 0:
                give = min(leftover, r)
                for n in ns:
                    alloc[n] += give * room[n] / r
        t_air = max(float(np.interp(alloc[n], grids[n][0], grids[n][1]))
                    for n in ns)
        return alloc, t_air

    lo, hi = 0.0, sum(max_shed.values())
    for _ in range(40):
        x = 0.5 * (lo + hi)
        _, t_air = distribute(x)
        if space_latency(total0 + x, sagin) >= t_air:
            hi = x   # satellite overloaded -> shed less to space
        else:
            lo = x   # satellite under-used -> shed more (eq. of Alg. 2)
    alloc, _ = distribute(0.5 * (lo + hi))
    clusters = [cluster_case2(sagin, n, alloc[n], tol) for n in ns]
    shed_total = sum(alloc.values())
    new_sat = float(sagin.n_sat_samples) + shed_total
    plan = OffloadPlan(case=2, clusters=clusters, new_sat_samples=new_sat,
                       space_latency=space_latency(new_sat, sagin),
                       round_latency=0.0, baseline_latency=baseline)
    plan.round_latency = evaluate_plan(sagin, plan)
    return plan


# ---------------------------------------------------------------------------
# Literal Algorithm 1 (pseudocode-faithful, for cross-validation) ------------
# ---------------------------------------------------------------------------
def algorithm1_literal(sagin: SAGIN, n: int, d_s2a: float,
                       eps1: float = 1e-2, eps2: float = 5e-2,
                       max_iter: int = 40) -> Dict[int, float]:
    """Algorithm 1 exactly as printed: outer bisection on Y_n, inner
    bisection on the per-device latency level, per-device bisection on
    |D_{n,k}^{A2G}|. Returns the air->ground allocation."""
    air = sagin.air_nodes[n]
    ks = sagin.clusters[n]
    recv_sat = lat.tx_time(sagin.q_bits * d_s2a, sagin.s2a_rate(n)) \
        if d_s2a > 0 else 0.0
    e_air = air.m / air.f
    own_air = lat.comp_time(air.m, air.n_samples, air.f)

    def tau_g(k: int, d: float) -> float:
        dev = sagin.devices[k]
        up = lat.model_upload_time(sagin.model_bits, sagin.g2a_rate(k, n))
        a = lat.comp_time(dev.m, dev.n_samples, dev.f)
        recv = recv_sat + sagin.q_bits * d / sagin.g2a_rate(k, n)
        return max(a, recv) + lat.comp_time(dev.m, d, dev.f) + up

    def tau_a(y: float) -> float:
        new_size = air.n_samples + d_s2a - y
        if new_size <= air.n_samples:
            return lat.comp_time(air.m, max(0.0, new_size), air.f)
        return max(own_air, recv_sat) + e_air * (d_s2a - y)

    max_y = air.n_samples + d_s2a
    nu_l1, nu_u1 = 0.0, max_y
    alloc = {k: 0.0 for k in ks}
    it = 0
    while nu_u1 - nu_l1 >= eps1 and it < max_iter:
        it += 1
        y_n = 0.5 * (nu_u1 + nu_l1)
        # inner: find per-device allocation summing to ~y_n
        lvl_lo, lvl_hi = 0.0, max(tau_g(k, max_y) for k in ks) if ks else 0.0
        inner = 0
        while inner < max_iter:
            inner += 1
            lvl = 0.5 * (lvl_lo + lvl_hi)
            for k in ks:
                d_lo, d_hi = 0.0, min(air.n_samples + d_s2a, y_n)
                for _ in range(40):
                    d_mid = 0.5 * (d_lo + d_hi)
                    if tau_g(k, d_mid) < lvl:
                        d_lo = d_mid
                    else:
                        d_hi = d_mid
                alloc[k] = d_lo
            s = sum(alloc.values())
            if s < (1 - eps2) * y_n:
                lvl_lo = lvl
            elif s > (1 + eps2) * y_n:
                lvl_hi = lvl
            else:
                break
        t_ground = max(tau_g(k, alloc[k]) for k in ks) if ks else 0.0
        if tau_a(sum(alloc.values())) >= t_ground:
            nu_l1 = y_n
        else:
            nu_u1 = y_n
    return alloc


def algorithm2_literal(sagin: SAGIN, eps1: float = 1e-2, eps2: float = 5e-2,
                       max_iter: int = 30) -> Dict[int, float]:
    """Algorithm 2 exactly as printed (Case I direction): outer bisection
    on nu_{L,1}/nu_{U,1} over the total amount X shed by the satellite,
    inner bisection on the latency level distributing X across air nodes,
    per-node bisection on |D_{S,n}^{S2A}| (via the cluster-level solve).
    Returns {n: d_s2a_n}. Used to cross-validate the grid-based fast path.
    """
    ns = list(sagin.clusters)
    total = float(sagin.n_sat_samples)
    nu_l1, nu_u1 = 0.0, total
    alloc = {n: 0.0 for n in ns}
    it = 0
    while nu_u1 - nu_l1 >= max(eps1, total * 1e-3) and it < max_iter:
        it += 1
        x = 0.5 * (nu_u1 + nu_l1)
        # inner: distribute x across air nodes at a common latency level
        lvl_lo = 0.0
        lvl_hi = max(cluster_case1(sagin, n, total, 1e-2).latency
                     for n in ns)
        inner = 0
        while inner < max_iter:
            inner += 1
            lvl = 0.5 * (lvl_lo + lvl_hi)
            for n in ns:
                d_lo, d_hi = 0.0, total
                for _ in range(25):
                    d_mid = 0.5 * (d_lo + d_hi)
                    if cluster_case1(sagin, n, d_mid, 1e-1).latency < lvl:
                        d_lo = d_mid
                    else:
                        d_hi = d_mid
                alloc[n] = d_lo
            sx = sum(alloc.values())
            if sx < (1 - eps2) * x:
                lvl_lo = lvl
            elif sx > (1 + eps2) * x:
                lvl_hi = lvl
            else:
                break
        t_space = space_latency(total - sum(alloc.values()), sagin)
        t_air = max(cluster_case1(sagin, n, alloc[n], 1e-1).latency
                    for n in ns)
        if t_space >= t_air:
            nu_l1 = x
        else:
            nu_u1 = x
    return alloc
