"""Core contribution of the paper: SAGIN FL orchestration.

Latency model (eqs. 5-19), Walker-Star constellation + coverage windows,
satellite data/model handover (eqs. 7-12), adaptive offloading optimizer
(Algorithms 1-2), round orchestrator, and the Theorem-1 bound.
"""
from .network import (SAGIN, AirNode, ChannelModel, GroundDevice, Satellite,
                      build_default_sagin)
from .constellation import WalkerStar, access_intervals, serving_sequence
from .handover import SpaceSchedule, space_latency, space_schedule
from .offloading import (ClusterPlan, OffloadPlan, evaluate_plan,
                         optimize_offloading)
from .scheduler import RoundRecord, SAGINOrchestrator
from .strategies import STRATEGIES, register_strategy, resolve_strategy
from .convergence import ConvergenceConfig, max_learning_rate, theorem1_bound

__all__ = [
    "SAGIN", "AirNode", "ChannelModel", "GroundDevice", "Satellite",
    "build_default_sagin", "WalkerStar", "access_intervals",
    "serving_sequence", "SpaceSchedule", "space_latency", "space_schedule",
    "ClusterPlan", "OffloadPlan", "evaluate_plan", "optimize_offloading",
    "RoundRecord", "SAGINOrchestrator", "STRATEGIES", "register_strategy",
    "resolve_strategy", "ConvergenceConfig", "max_learning_rate",
    "theorem1_bound",
]
