"""Per-round orchestration: ties constellation, offloading and handover
together (Section III overview; Remark 1 gateway role)."""
from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from . import network as net
from .constellation import WalkerStar, access_intervals, serving_sequence
from .handover import SpaceSchedule, space_schedule
from .network import SAGIN, Satellite
from .offloading import OffloadPlan, optimize_offloading


@dataclasses.dataclass
class RoundRecord:
    round_index: int
    plan: OffloadPlan
    schedule: SpaceSchedule
    latency: float                 # realized round latency (eq. 18)
    wall_clock_start: float        # cumulative time when round started
    ground_sizes: List[int]
    air_sizes: List[int]
    sat_size: int


class SAGINOrchestrator:
    """Simulates the full multi-round FL orchestration of the paper.

    Each round: (1) refresh the serving-satellite chain from the
    constellation at the current wall-clock; (2) run the adaptive offloading
    optimizer; (3) apply the plan (moving integer sample counts with
    conservation repair); (4) advance the wall clock by the realized
    latency. Strategy hooks let the baselines reuse the same machinery.
    """

    def __init__(self, sagin: SAGIN,
                 constellation: Optional[WalkerStar] = None,
                 lat_deg: float = 40.0, lon_deg: float = -86.0,
                 sat_f_seed: int = 0, horizon: float = 48 * 3600.0,
                 strategy: str = "adaptive"):
        self.sagin = sagin
        self.constellation = constellation
        self.strategy = strategy
        self._static_plan: Optional[OffloadPlan] = None
        self._rng = np.random.default_rng(sat_f_seed)
        self.wall_clock = 0.0
        self.records: List[RoundRecord] = []
        if constellation is not None:
            self._intervals = access_intervals(constellation, lat_deg,
                                               lon_deg, t_end=horizon)
        else:
            self._intervals = None

    # -- satellite chain ----------------------------------------------------
    def _refresh_satellites(self):
        if self._intervals is None:
            return  # static satellite list supplied by the user
        chain = serving_sequence(self._intervals, self.wall_clock)
        sats = []
        for i, iv in enumerate(chain):
            f = float(self._rng.uniform(*net.F_SAT_RANGE))
            sats.append(Satellite(index=iv.sat, f=f,
                                  coverage_end=max(0.0,
                                                   iv.end - self.wall_clock)))
        if not sats:
            sats = [Satellite(index=-1,
                              f=float(self._rng.uniform(*net.F_SAT_RANGE)),
                              coverage_end=np.inf)]
        self.sagin.satellites = sats

    # -- strategies ---------------------------------------------------------
    def _plan_round(self, r: int) -> OffloadPlan:
        from .offloading import ClusterPlan
        from .handover import space_latency
        from . import latency as lat
        sagin = self.sagin
        if self.strategy == "adaptive":
            return optimize_offloading(sagin)
        if self.strategy == "static":
            if self._static_plan is None:
                self._static_plan = optimize_offloading(sagin)
            if r == 0:
                return self._static_plan
            # keep datasets fixed: no further transfers
            return self._null_plan()
        if self.strategy == "none":
            return self._null_plan()
        if self.strategy == "air_ground":
            # zero-out space transfers: per-cluster balancing only
            from .offloading import cluster_case1
            clusters = [cluster_case1(sagin, n, 0.0) for n in sagin.clusters]
            plan = OffloadPlan(case=1, clusters=clusters,
                               new_sat_samples=sagin.n_sat_samples,
                               space_latency=space_latency(
                                   sagin.n_sat_samples, sagin),
                               round_latency=0.0, baseline_latency=0.0)
            from .offloading import evaluate_plan
            plan.round_latency = evaluate_plan(sagin, plan)
            return plan
        if self.strategy == "ground_space":
            # bypass air compute: use full optimizer but forbid air nodes
            # from keeping samples (they only relay). Implemented by
            # temporarily zeroing air compute attractiveness.
            saved = [a.f for a in sagin.air_nodes]
            for a in sagin.air_nodes:
                a.f = 1.0  # effectively no compute at air layer
            try:
                plan = optimize_offloading(sagin)
            finally:
                for a, f in zip(sagin.air_nodes, saved):
                    a.f = f
            return plan
        if self.strategy == "proportional":
            return self._proportional_plan()
        raise ValueError(f"unknown strategy {self.strategy!r}")

    def _null_plan(self) -> OffloadPlan:
        from .offloading import ClusterPlan, evaluate_plan
        from .handover import space_latency
        from . import latency as lat
        sagin = self.sagin
        clusters = [ClusterPlan(n=n) for n in sagin.clusters]
        plan = OffloadPlan(case=0, clusters=clusters,
                           new_sat_samples=sagin.n_sat_samples,
                           space_latency=space_latency(sagin.n_sat_samples,
                                                       sagin),
                           round_latency=0.0, baseline_latency=0.0)
        for cp in plan.clusters:
            cp.latency = (lat.air_cluster_latency_no_offload(sagin, cp.n)
                          + lat.model_upload_time(sagin.model_bits,
                                                  sagin.a2s_rate(cp.n)))
        plan.round_latency = evaluate_plan(sagin, plan)
        return plan

    def _proportional_plan(self) -> OffloadPlan:
        """Baseline: allocation proportional to each node's compute power."""
        from .offloading import ClusterPlan, evaluate_plan
        from .handover import space_latency
        sagin = self.sagin
        f_sat = sagin.satellites[0].f
        f_total = (sum(d.f for d in sagin.devices)
                   + sum(a.f for a in sagin.air_nodes) + f_sat)
        total = sagin.total_samples
        # target sizes
        tgt_sat = total * f_sat / f_total
        clusters = []
        sat_delta = tgt_sat - sagin.n_sat_samples
        # distribute the satellite delta across clusters proportionally to
        # their offloadable mass; within each cluster move between air/ground
        offloadable = {n: sum(sagin.devices[k].n_offloadable
                              for k in sagin.clusters[n])
                       + sagin.air_nodes[n].n_samples
                       for n in sagin.clusters}
        off_total = max(1.0, sum(offloadable.values()))
        for n in sagin.clusters:
            cp = ClusterPlan(n=n)
            air = sagin.air_nodes[n]
            ks = sagin.clusters[n]
            if sat_delta > 0:  # clusters send up
                share = sat_delta * offloadable[n] / off_total
                cp.d_air_space = min(share, offloadable[n])
                # take from devices proportionally to their offloadable data
                need = max(0.0, cp.d_air_space - air.n_samples)
                dev_off = max(1.0, sum(sagin.devices[k].n_offloadable
                                       for k in ks))
                for k in ks:
                    cp.d_ground_air[k] = (need * sagin.devices[k].n_offloadable
                                          / dev_off)
            else:  # satellite sends down
                share = -sat_delta / len(sagin.clusters)
                cp.d_space_air = share
            # air target: proportional within cluster
            f_cluster = air.f + sum(sagin.devices[k].f for k in ks)
            clusters.append(cp)
        plan = OffloadPlan(case=2 if sat_delta > 0 else 1, clusters=clusters,
                           new_sat_samples=sagin.n_sat_samples + sum(
                               c.d_air_space - c.d_space_air
                               for c in clusters),
                           space_latency=0.0, round_latency=0.0,
                           baseline_latency=0.0)
        plan.space_latency = space_latency(plan.new_sat_samples, sagin)
        for cp in plan.clusters:
            from .offloading import evaluate_cluster
            from . import latency as lat
            cp.latency = evaluate_cluster(sagin, cp) + lat.model_upload_time(
                sagin.model_bits, sagin.a2s_rate(cp.n))
        plan.round_latency = evaluate_plan(sagin, plan)
        return plan

    # -- application --------------------------------------------------------
    def _apply_plan(self, plan: OffloadPlan):
        sagin = self.sagin
        g, a, s = plan.new_sizes(sagin)
        # integer rounding with conservation repair
        total_before = sagin.total_samples
        g = [int(round(x)) for x in g]
        a = [int(round(x)) for x in a]
        s = int(round(s))
        drift = total_before - (sum(g) + sum(a) + s)
        s += drift
        if s < 0:
            a[0] += s
            s = 0
        for k, dev in enumerate(sagin.devices):
            moved_away = dev.n_samples - g[k]
            dev.n_samples = max(dev.n_sensitive, g[k])
        for n, air in enumerate(sagin.air_nodes):
            air.n_samples = max(0, a[n])
        sagin.n_sat_samples = max(0, s)

    # -- main loop ----------------------------------------------------------
    def step(self, r: int) -> RoundRecord:
        self._refresh_satellites()
        plan = self._plan_round(r)
        schedule = space_schedule(plan.new_sat_samples, self.sagin)
        rec = RoundRecord(
            round_index=r, plan=plan, schedule=schedule,
            latency=plan.round_latency, wall_clock_start=self.wall_clock,
            ground_sizes=[d.n_samples for d in self.sagin.devices],
            air_sizes=[a.n_samples for a in self.sagin.air_nodes],
            sat_size=self.sagin.n_sat_samples)
        self._apply_plan(plan)
        rec.ground_sizes = [d.n_samples for d in self.sagin.devices]
        rec.air_sizes = [a.n_samples for a in self.sagin.air_nodes]
        rec.sat_size = self.sagin.n_sat_samples
        self.wall_clock += plan.round_latency
        self.records.append(rec)
        return rec

    def run(self, n_rounds: int) -> List[RoundRecord]:
        return [self.step(r) for r in range(n_rounds)]
