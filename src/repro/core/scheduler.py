"""Per-round orchestration: ties constellation, offloading and handover
together (Section III overview; Remark 1 gateway role)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from . import latency as lat
from . import network as net
from .constellation import (AccessInterval, WalkerStar, access_intervals,
                            serving_sequence)
from .handover import SpaceSchedule, space_latency, space_schedule
from .network import SAGIN, Satellite
from .offloading import OffloadPlan, evaluate_cluster
from .strategies import resolve_strategy


@dataclasses.dataclass
class RoundRecord:
    round_index: int
    plan: OffloadPlan
    schedule: SpaceSchedule
    latency: float                 # analytic round latency (eq. 18)
    wall_clock_start: float        # cumulative time when round started
    ground_sizes: List[int]
    air_sizes: List[int]
    sat_size: int
    realized_latency: float = 0.0  # latency after stochastic events
    events: Optional[object] = None        # sim.dynamics.RoundEvents
    offline_devices: tuple = ()            # churned-out this round


class SAGINOrchestrator:
    """Simulates the full multi-round FL orchestration of the paper.

    Each round: (1) refresh the serving-satellite chain from the
    constellation at the current wall-clock; (2) sample this round's
    network events (outages, weather, jitter, churn) when a dynamics
    process is attached; (3) run the data-placement strategy hook;
    (4) apply the plan (moving integer sample counts with conservation
    repair); (5) advance the wall clock by the *realized* latency — the
    plan is made against nominal rates, then re-priced under the round's
    realized channel/ISL conditions, so dynamics hit the trajectory the
    way unforecast weather hits a real deployment.

    ``strategy`` is a registered name from ``core.strategies`` (the
    Section VI-A schemes) or any ``(orchestrator, round) -> OffloadPlan``
    callable.  All randomness (satellite CPU draws) flows from the
    explicit ``rng`` generator; pass one spawned per region for
    reproducible multi-region simulations.
    """

    def __init__(self, sagin: SAGIN,
                 constellation: Optional[WalkerStar] = None,
                 lat_deg: float = 40.0, lon_deg: float = -86.0,
                 sat_f_seed: int = 0, horizon: float = 48 * 3600.0,
                 strategy: str = "adaptive",
                 rng: Optional[np.random.Generator] = None,
                 dynamics: Optional[object] = None,
                 intervals: Optional[Sequence[AccessInterval]] = None,
                 min_elevation_deg: float = 15.0):
        self.sagin = sagin
        self.constellation = constellation
        self.strategy = strategy
        self._strategy_fn = resolve_strategy(strategy)
        self._static_plan: Optional[OffloadPlan] = None
        self._rng = rng if rng is not None else np.random.default_rng(
            sat_f_seed)
        self.dynamics = dynamics
        self.wall_clock = 0.0
        self.records: List[RoundRecord] = []
        if intervals is not None:
            self._intervals = list(intervals)
        elif constellation is not None:
            self._intervals = access_intervals(
                constellation, lat_deg, lon_deg, t_end=horizon,
                min_elevation_deg=min_elevation_deg)
        else:
            self._intervals = None
        # static satellite lists keep their nominal frequencies so that
        # per-round jitter never compounds across rounds
        self._base_sat_f = ([s.f for s in sagin.satellites]
                            if self._intervals is None else None)

    # -- satellite chain ----------------------------------------------------
    def _refresh_satellites(self):
        if self._intervals is None:
            if self._base_sat_f is not None:
                for sat, f in zip(self.sagin.satellites, self._base_sat_f):
                    sat.f = f
            return  # static satellite list supplied by the user
        chain = serving_sequence(self._intervals, self.wall_clock)
        sats = []
        for iv in chain:
            f = float(self._rng.uniform(*net.F_SAT_RANGE))
            sats.append(Satellite(index=iv.sat, f=f,
                                  coverage_end=max(0.0,
                                                   iv.end - self.wall_clock)))
        if not sats:
            sats = [Satellite(index=-1,
                              f=float(self._rng.uniform(*net.F_SAT_RANGE)),
                              coverage_end=np.inf)]
        self.sagin.satellites = sats

    # -- strategies ---------------------------------------------------------
    def _plan_round(self, r: int) -> OffloadPlan:
        return self._strategy_fn(self, r)

    # -- dynamics -----------------------------------------------------------
    def _sample_events(self, r: int):
        if self.dynamics is None:
            return None
        events = self.dynamics.sample_round(
            r, n_sats=len(self.sagin.satellites),
            n_clusters=len(self.sagin.clusters),
            n_devices=len(self.sagin.devices))
        # compute jitter is observable: the planner sees the jittered f
        for sat, scale in zip(self.sagin.satellites, events.sat_freq_scale):
            sat.f *= float(scale)
        return events

    def _strip_offline(self, plan: OffloadPlan, offline: Sequence[int]):
        """Offline devices neither send nor receive data this round.

        Dropping a churned device's ground->air feed can leave the air
        node promising the satellite more than it will actually hold, so
        the upward transfer is clamped to the realizable mass and the
        plan's satellite target is re-derived from the surviving moves.
        """
        off = set(offline)
        sagin = self.sagin
        for cp in plan.clusters:
            cp.d_ground_air = {k: d for k, d in cp.d_ground_air.items()
                               if k not in off}
            cp.d_air_ground = {k: d for k, d in cp.d_air_ground.items()
                               if k not in off}
            realizable = (sagin.air_nodes[cp.n].n_samples + cp.d_space_air
                          + sum(cp.d_ground_air.values())
                          - sum(cp.d_air_ground.values()))
            cp.d_air_space = min(cp.d_air_space, max(0.0, realizable))
        plan.new_sat_samples = sagin.n_sat_samples + sum(
            cp.d_air_space - cp.d_space_air for cp in plan.clusters)

    def _realized_latency(self, plan: OffloadPlan, events) -> float:
        """Re-price the committed plan under the round's realized
        channel/ISL conditions (the planner only saw nominal rates)."""
        if events.quiet:
            return plan.round_latency
        sagin = self.sagin
        saved = (sagin._g2a, sagin._a2s, sagin._s2a, sagin.z_isl)
        try:
            rs = events.rate_scale
            sagin._g2a = {k: v * rs for k, v in saved[0].items()}
            sagin._a2s = {k: v * rs for k, v in saved[1].items()}
            sagin._s2a = {k: v * rs for k, v in saved[2].items()}
            sagin.z_isl = saved[3] * events.isl_scale
            t_space = space_latency(plan.new_sat_samples, sagin)
            t_air = 0.0
            for cp in plan.clusters:
                t = (evaluate_cluster(sagin, cp,
                                      offline=events.offline_devices)
                     + lat.model_upload_time(sagin.model_bits,
                                             sagin.a2s_rate(cp.n))
                     + events.uplink_delays.get(cp.n, 0.0))
                t_air = max(t_air, t)
            return max(t_space, t_air)
        finally:
            sagin._g2a, sagin._a2s, sagin._s2a, sagin.z_isl = saved

    # -- application --------------------------------------------------------
    def _apply_plan(self, plan: OffloadPlan):
        sagin = self.sagin
        g, a, s = plan.new_sizes(sagin)
        # integer rounding with conservation repair
        total_before = sagin.total_samples
        g = [int(round(x)) for x in g]
        a = [int(round(x)) for x in a]
        s = int(round(s))
        drift = total_before - (sum(g) + sum(a) + s)
        s += drift
        if s < 0:
            a[0] += s
            s = 0
        for k, dev in enumerate(sagin.devices):
            dev.n_samples = max(dev.n_sensitive, g[k])
        for n, air in enumerate(sagin.air_nodes):
            air.n_samples = max(0, a[n])
        sagin.n_sat_samples = max(0, s)

    # -- main loop ----------------------------------------------------------
    def step(self, r: int) -> RoundRecord:
        self._refresh_satellites()
        events = self._sample_events(r)
        plan = self._plan_round(r)
        if events is not None and events.offline_devices:
            self._strip_offline(plan, events.offline_devices)
        schedule = space_schedule(plan.new_sat_samples, self.sagin)
        realized = (plan.round_latency if events is None
                    else self._realized_latency(plan, events))
        rec = RoundRecord(
            round_index=r, plan=plan, schedule=schedule,
            latency=plan.round_latency, wall_clock_start=self.wall_clock,
            ground_sizes=[d.n_samples for d in self.sagin.devices],
            air_sizes=[a.n_samples for a in self.sagin.air_nodes],
            sat_size=self.sagin.n_sat_samples,
            realized_latency=realized, events=events,
            offline_devices=(events.offline_devices if events else ()))
        self._apply_plan(plan)
        rec.ground_sizes = [d.n_samples for d in self.sagin.devices]
        rec.air_sizes = [a.n_samples for a in self.sagin.air_nodes]
        rec.sat_size = self.sagin.n_sat_samples
        self.wall_clock += realized
        self.records.append(rec)
        return rec

    def run(self, n_rounds: int) -> List[RoundRecord]:
        return [self.step(r) for r in range(n_rounds)]
