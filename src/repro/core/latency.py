"""Latency model of the paper: eqs. (5), (7)-(12), (14), (16)-(19).

Pure-Python/NumPy control-plane code (Remark 1: runs at the gateway).
All helpers take explicit scalars so the offloading optimizer can evaluate
candidate allocations cheaply.
"""
from __future__ import annotations

from typing import Sequence

from .network import SAGIN


# ---------------------------------------------------------------------------
# Elementary delays ----------------------------------------------------------
# ---------------------------------------------------------------------------
def comp_time(m: float, n_samples: float, f: float) -> float:
    """Local computation time m*|D|/f (eq. 5)."""
    return m * n_samples / f


def tx_time(bits: float, rate: float) -> float:
    """Transmission delay for ``bits`` over a link of ``rate`` bits/s."""
    return bits / rate


def model_upload_time(model_bits: float, rate: float) -> float:
    """eq. (14): tau^{G2A} = Q(w)/Z."""
    return model_bits / rate


def handover_delay(model_bits: float, q_bits: float, n_samples: float,
                   z_isl: float) -> float:
    """eq. (7): (Q(w) + q|D_S|)/Z_ISL."""
    return (model_bits + q_bits * n_samples) / z_isl


# ---------------------------------------------------------------------------
# Cross-region merge pricing over the ISL topology ---------------------------
# ---------------------------------------------------------------------------
MERGE_TOPOLOGIES = ("ring", "star")


def isl_path_hops(topology: str, src: int, dst: int, n_regions: int) -> int:
    """One-way ISL hops between the serving satellites of two regions.

    * ``"star"`` — every serving satellite has a direct ISL to every
      other (one aggregation plane): 1 hop between distinct regions.
    * ``"ring"`` — serving satellites form a ring in region order (the
      natural Walker-Star cross-plane layout): circular distance.
    """
    for label, idx in (("src", src), ("dst", dst)):
        if not 0 <= idx < n_regions:
            raise ValueError(f"{label}={idx} out of range for "
                             f"{n_regions} region(s)")
    if src == dst:
        return 0
    if topology == "star":
        return 1
    if topology == "ring":
        d = abs(src - dst)
        return min(d, n_regions - d)
    raise ValueError(f"unknown merge topology {topology!r}; "
                     f"expected one of {MERGE_TOPOLOGIES}")


def isl_merge_hops(topology: str, region_index: int, n_regions: int,
                   hub: int = 0) -> int:
    """ISL hops region ``region_index``'s model travels for one global
    merge: up to the aggregating satellite (the one serving region
    ``hub``) and back down with the merged model — twice the one-way
    :func:`isl_path_hops` distance; the hub region pays 0.
    """
    if not 0 <= region_index < n_regions:
        raise ValueError(f"region_index={region_index} out of range for "
                         f"{n_regions} region(s)")
    if n_regions <= 1:
        return 0
    return 2 * isl_path_hops(topology, region_index, hub % n_regions,
                             n_regions)


def global_merge_latency(model_bits: float, z_isl: float, topology: str,
                         region_index: int, n_regions: int,
                         hub: int = 0) -> float:
    """ISL price of one global merge for a region: eq. (7) with a
    model-only payload (no raw data rides along), once per hop."""
    hops = isl_merge_hops(topology, region_index, n_regions, hub=hub)
    return hops * tx_time(model_bits, z_isl)


# ---------------------------------------------------------------------------
# Space-layer latency with handover (eqs. 8-12) ------------------------------
# ---------------------------------------------------------------------------
def space_layer_latency(n_samples: float, sagin: SAGIN) -> float:
    """tau_S^{(r)}: latency for the space layer to process ``n_samples``.

    Walks the ordered list of covering satellites; each satellite processes
    until its coverage window T_i ends, then hands (model + remaining data)
    to the next satellite over the ISL (eq. 7). Faithful to eqs. (8)-(12).
    """
    from .handover import space_schedule
    return space_schedule(n_samples, sagin).total_latency


# ---------------------------------------------------------------------------
# Round latency without offloading (eqs. 16-17) ------------------------------
# ---------------------------------------------------------------------------
def air_cluster_latency_no_offload(sagin: SAGIN, n: int) -> float:
    """eq. (17): completion of air node n incl. its ground devices."""
    air = sagin.air_nodes[n]
    t_air = comp_time(air.m, air.n_samples, air.f)
    t_ground = 0.0
    for k in sagin.clusters[n]:
        dev = sagin.devices[k]
        t = (comp_time(dev.m, dev.n_samples, dev.f)
             + model_upload_time(sagin.model_bits, sagin.g2a_rate(k, n)))
        t_ground = max(t_ground, t)
    return max(t_air, t_ground)


def round_latency_no_offload(sagin: SAGIN) -> float:
    """eq. (16): overall round latency with the *current* datasets."""
    t_space = space_layer_latency(sagin.n_sat_samples, sagin)
    t_air = max(
        air_cluster_latency_no_offload(sagin, n)
        + model_upload_time(sagin.model_bits, sagin.a2s_rate(n))
        for n in sagin.clusters
    )
    return max(t_space, t_air)


# ---------------------------------------------------------------------------
# Post-offloading latencies, Case I (space -> air/ground), eqs. (21)-(25) ----
# ---------------------------------------------------------------------------
def case1_air_local_delay(sagin: SAGIN, n: int, d_s2a: float,
                          d_a2g: Sequence[float]) -> float:
    """eq. (24): air node n's local completion time under Case I."""
    air = sagin.air_nodes[n]
    sent = sum(d_a2g)
    new_size = air.n_samples + d_s2a - sent
    if new_size <= air.n_samples:
        return comp_time(air.m, new_size, air.f)
    recv_delay = tx_time(sagin.q_bits * d_s2a, sagin.s2a_rate(n))
    own = comp_time(air.m, air.n_samples, air.f)
    extra = comp_time(air.m, d_s2a - sent, air.f)
    return max(own, recv_delay) + extra


def case1_ground_local_delay(sagin: SAGIN, k: int, n: int, d_s2a: float,
                             d_a2g_k: float) -> float:
    """eq. (25): ground device k's completion time under Case I."""
    dev = sagin.devices[k]
    own = comp_time(dev.m, dev.n_samples, dev.f)
    recv = (tx_time(sagin.q_bits * d_s2a, sagin.s2a_rate(n))
            + tx_time(sagin.q_bits * d_a2g_k, sagin.g2a_rate(k, n)))
    extra = comp_time(dev.m, d_a2g_k, dev.f)
    return max(own, recv) + extra


# ---------------------------------------------------------------------------
# Post-offloading latencies, Case II (air/ground -> space), eqs. (30)-(34) ---
# ---------------------------------------------------------------------------
def case2_air_local_delay(sagin: SAGIN, n: int, d_a2s: float,
                          d_g2a: Sequence[float]) -> float:
    """eq. (33): air node n's completion time under Case II."""
    air = sagin.air_nodes[n]
    recv_total = sum(d_g2a)
    new_size = air.n_samples - d_a2s + recv_total
    send_delay = tx_time(sagin.q_bits * d_a2s, sagin.a2s_rate(n))
    if new_size <= air.n_samples:
        return max(comp_time(air.m, new_size, air.f), send_delay)
    ks = sagin.clusters[n]
    recv_delay = max(
        tx_time(sagin.q_bits * d, sagin.g2a_rate(k, n))
        for k, d in zip(ks, d_g2a)
    ) if ks else 0.0
    own = comp_time(air.m, air.n_samples, air.f)
    extra = comp_time(air.m, recv_total - d_a2s, air.f)
    return max(max(own, recv_delay) + extra, send_delay)


def case2_ground_local_delay(sagin: SAGIN, k: int, n: int,
                             d_g2a_k: float) -> float:
    """eq. (34): ground device k's completion time under Case II."""
    dev = sagin.devices[k]
    comp = comp_time(dev.m, dev.n_samples - d_g2a_k, dev.f)
    send = tx_time(sagin.q_bits * d_g2a_k, sagin.g2a_rate(k, n))
    return max(comp, send)


# ---------------------------------------------------------------------------
# Aggregate cluster/global latencies (eqs. 18-19) ----------------------------
# ---------------------------------------------------------------------------
def cluster_latency(sagin: SAGIN, n: int, air_local: float,
                    ground_locals: Sequence[float]) -> float:
    """eq. (19): max of air local delay and ground completion+upload."""
    t_ground = 0.0
    for k, t in zip(sagin.clusters[n], ground_locals):
        t_ground = max(t_ground,
                       t + model_upload_time(sagin.model_bits,
                                             sagin.g2a_rate(k, n)))
    return max(air_local, t_ground)


def round_latency(sagin: SAGIN, space_latency: float,
                  cluster_latencies: Sequence[float]) -> float:
    """eq. (18): overall post-offloading round latency."""
    t_air = max(
        t + model_upload_time(sagin.model_bits, sagin.a2s_rate(n))
        for n, t in zip(sagin.clusters, cluster_latencies)
    )
    return max(space_latency, t_air)
