"""Model-zoo demo: train + decode a reduced variant of every assigned
architecture through the same public API used by the production launcher.

    PYTHONPATH=src python examples/multiarch_demo.py [--arch qwen3-32b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def run(arch: str):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    if cfg.input_mode == "tokens":
        inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                             jnp.int32)
    else:
        inputs = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                             jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    step = jax.jit(T.make_train_step(cfg, lr=1e-3))
    t0 = time.time()
    for i in range(3):
        params, m = step(params, {"inputs": inputs, "labels": labels})
    # decode 4 tokens greedily
    cache = T.init_cache(cfg, b, 64)
    tok = inputs[:, :1] if cfg.input_mode == "tokens" else inputs[:, :1, :]
    toks = []
    for pos in range(4):
        logits, cache = T.serve_step(params, cfg, cache, tok, jnp.int32(pos))
        nxt = jnp.argmax(logits, -1)[:, None]
        toks.append(np.asarray(nxt[0, 0]))
        tok = nxt if cfg.input_mode == "tokens" else jnp.zeros(
            (b, 1, cfg.d_model), jnp.float32)
    full = get_config(arch)
    print(f"{arch:24s} loss={float(m['loss']):6.3f} "
          f"decoded={toks} "
          f"[full: {full.param_count()/1e9:6.1f}B params, "
          f"{full.n_layers}L] ({time.time()-t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        run(arch)


if __name__ == "__main__":
    main()
