"""Walkthrough of the paper's analytics on a single round:
constellation -> coverage windows -> handover schedule -> offloading plan.

    PYTHONPATH=src python examples/offloading_walkthrough.py
"""
import numpy as np

from repro.core import (WalkerStar, access_intervals, build_default_sagin,
                        optimize_offloading, serving_sequence, space_schedule)
from repro.core.network import Satellite


def main():
    # 1. constellation + coverage (replaces MATLAB walkerStar)
    ws = WalkerStar()  # 80 sats, 5 planes, 800 km, 85 deg
    ivs = access_intervals(ws, t_end=4 * 3600.0)
    print(f"coverage windows in 4h over (40N, 86W): {len(ivs)}")
    chain = serving_sequence(ivs, t0=0.0, max_sats=5)
    for iv in chain:
        print(f"  sat {iv.sat:2d} serves [{iv.start:6.0f}, {iv.end:6.0f}] s"
              f"  ({iv.duration/60:.1f} min)")

    # 2. a SAGIN round with those windows
    rng = np.random.default_rng(0)
    sagin = build_default_sagin(n_devices=10, n_air=2, seed=0)
    sagin.satellites = [
        Satellite(iv.sat, f=float(rng.uniform(1e9, 1e10)),
                  coverage_end=iv.end) for iv in chain]
    plan = optimize_offloading(sagin)
    print(f"\ncase {plan.case} plan: round latency "
          f"{plan.round_latency:.0f} s (baseline {plan.baseline_latency:.0f} s)")
    for cp in plan.clusters:
        moves = []
        if cp.d_space_air > 0:
            moves.append(f"sat->air {cp.d_space_air:.0f}")
        if cp.d_air_space > 0:
            moves.append(f"air->sat {cp.d_air_space:.0f}")
        if cp.d_ground_air:
            moves.append(f"ground->air {sum(cp.d_ground_air.values()):.0f}")
        if cp.d_air_ground:
            moves.append(f"air->ground {sum(cp.d_air_ground.values()):.0f}")
        print(f"  cluster {cp.n}: {', '.join(moves) or 'no transfer'}"
              f"  (latency {cp.latency:.0f} s)")

    # 3. the space-layer handover schedule for the plan (eqs. 8-12)
    sch = space_schedule(plan.new_sat_samples, sagin)
    print(f"\nspace layer processes {plan.new_sat_samples:.0f} samples "
          f"with {sch.n_handovers} handover(s):")
    for leg in sch.legs:
        print(f"  sat {leg.sat_index:2d}: start {leg.start_time:7.0f} s "
              f"(handover {leg.handover_delay:5.1f} s), "
              f"{leg.samples_processed:7.0f} samples, "
              f"ends {leg.end_time:7.0f} s")


if __name__ == "__main__":
    main()
