"""Quickstart: one adaptive-offloading round + a few FL rounds, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import build_default_sagin, optimize_offloading
from repro.core.latency import round_latency_no_offload
from repro.fl import FLConfig, run_fl


def main():
    # --- 1. the paper's core: one adaptive data-offloading decision -------
    sagin = build_default_sagin(n_devices=10, n_air=2, seed=0)
    baseline = round_latency_no_offload(sagin)
    plan = optimize_offloading(sagin)
    print(f"round latency without offloading : {baseline:10.0f} s")
    print(f"round latency with adaptive plan : {plan.round_latency:10.0f} s"
          f"  (case {plan.case}, {baseline / plan.round_latency:.1f}x faster)")
    g, a, s = plan.new_sizes(sagin)
    total = sum(g) + sum(a) + s
    print(f"data placement  ground/air/space : "
          f"{sum(g)/total:.0%} / {sum(a)/total:.0%} / {s/total:.0%}")

    # --- 2. a short federated training run with the orchestrator ----------
    cfg = FLConfig(dataset="mnist", n_rounds=4, n_devices=10, n_air=2,
                   h_local=3, train_fraction=0.02, eval_size=512,
                   strategy="adaptive")
    res = run_fl(cfg)
    print("\nFL run (adaptive offloading):")
    for r, (t, acc) in enumerate(zip(res.times, res.accuracies)):
        print(f"  round {r}: training time {t:8.0f} s   accuracy {acc:.3f}")


if __name__ == "__main__":
    main()
