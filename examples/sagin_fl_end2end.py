"""End-to-end driver (deliverable b): federated training of the paper's
MNIST CNN over a Walker-Star constellation for a few hundred rounds,
comparing the adaptive scheme against the no-offloading baseline.

    PYTHONPATH=src python examples/sagin_fl_end2end.py [--rounds N]

Reduced defaults keep CPU runtime reasonable; raise --rounds/--devices and
--fraction for the paper-scale experiment.
"""
import argparse

from repro.fl import FLConfig, run_fl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--air", type=int, default=2)
    ap.add_argument("--fraction", type=float, default=0.02)
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--constellation", action="store_true",
                    help="drive coverage windows from Walker-Star geometry")
    args = ap.parse_args()

    for strategy in ("adaptive", "none"):
        cfg = FLConfig(dataset=args.dataset, iid=not args.noniid,
                       n_rounds=args.rounds, n_devices=args.devices,
                       n_air=args.air, train_fraction=args.fraction,
                       strategy=strategy, h_local=3, eval_size=1024,
                       use_constellation=args.constellation)
        res = run_fl(cfg)
        best = max(res.accuracies)
        tta = res.time_to_accuracy(0.8)
        print(f"[{strategy:9s}] {args.rounds} rounds | "
              f"training time {res.times[-1]:9.0f} s | "
              f"best acc {best:.3f} | "
              f"time-to-80% {'%.0f s' % tta if tta else 'not reached'}")
        if strategy == "adaptive":
            p = res.layer_portions[-1]
            print(f"            final placement ground/air/space: "
                  f"{p['ground']:.0%}/{p['air']:.0%}/{p['space']:.0%}; "
                  f"cases used: {sorted(set(res.cases))}")


if __name__ == "__main__":
    main()
